"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (EtherONDriver, EthernetFrame, LambdaFS, LockHeld,
                        PagedKVCache, SHARABLE_NS, UPCALL_SLOTS)
from repro.core.ether_on import DockerSSDEndpoint
from repro.kernels import ref
from repro.models.rwkv6 import wkv_chunked
from repro.optim import compression as comp

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# λFS inode-lock protocol: mutual exclusion between host and containers
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.sampled_from(
    ["host_open", "host_close", "bind_a", "bind_b", "rel_a", "rel_b"]),
    ), min_size=1, max_size=40))
def test_inode_lock_mutual_exclusion(ops):
    fs = LambdaFS()
    fs.write("/d/f", b"x", SHARABLE_NS)
    host_refs = 0
    holder = None
    for (op,) in ops:
        try:
            if op == "host_open":
                fs.host_open("/d/f")
                host_refs += 1
            elif op == "host_close" and host_refs > 0:
                fs.host_close("/d/f")
                host_refs -= 1
            elif op == "bind_a":
                fs.container_bind("/d/f", "a")
                holder = "a"
            elif op == "bind_b":
                fs.container_bind("/d/f", "b")
                holder = "b"
            elif op == "rel_a" and holder == "a":
                fs.container_release("/d/f", "a")
                holder = None
            elif op == "rel_b" and holder == "b":
                fs.container_release("/d/f", "b")
                holder = None
        except (LockHeld, Exception) as e:
            if not isinstance(e, LockHeld):
                raise
        node = fs._get(SHARABLE_NS, "/d/f")
        # THE invariant: never both host openers and a container holder
        assert not (node.host_refcount > 0 and
                    node.container_holder is not None)
        assert node.host_refcount == host_refs
        assert node.container_holder == holder


# ---------------------------------------------------------------------------
# Ether-oN: payload integrity + upcall slot conservation
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.binary(min_size=0, max_size=8000))
def test_etheron_payload_integrity(payload):
    drv = EtherONDriver("10.0.0.1")
    dev = DockerSSDEndpoint("10.0.0.2")
    drv.attach(dev)
    echoed = []
    dev.set_handler(lambda fr: fr.payload)      # echo back via upcall
    drv.transmit(EthernetFrame("10.0.0.1", "10.0.0.2", payload))
    chunks = []
    while True:
        fr = drv.poll()
        if fr is None:
            break
        chunks.append(fr.payload)
    assert b"".join(chunks) == payload
    assert drv.outstanding_slots("10.0.0.2") == UPCALL_SLOTS


@settings(**SETTINGS)
@given(st.lists(st.integers(1, 4000), min_size=1, max_size=12))
def test_etheron_slot_invariant_under_bursts(sizes):
    drv = EtherONDriver("10.0.0.1")
    dev = DockerSSDEndpoint("10.0.0.2")
    drv.attach(dev)
    total = 0
    for n in sizes:
        dev.send_to_host(b"z" * n, "10.0.0.1")
        total += n
        assert 0 <= drv.outstanding_slots("10.0.0.2") <= UPCALL_SLOTS
    got = 0
    while (fr := drv.poll()) is not None:
        got += len(fr.payload)
    assert got == total


# ---------------------------------------------------------------------------
# gradient compression: error feedback preserves the accumulated signal
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["int8", "bf16"]))
def test_error_feedback_accumulation(seed, mode):
    rng = np.random.default_rng(seed)
    g_true = [rng.normal(size=(8, 16)).astype(np.float32) for _ in range(12)]
    params = {"w": jnp.zeros((8, 16))}
    res = comp.init_residuals(params)
    acc_dec = np.zeros((8, 16), np.float32)
    for g in g_true:
        dec, res = comp.compress_grads({"w": jnp.asarray(g)}, res, mode)
        acc_dec += np.asarray(dec["w"])
    acc_true = np.sum(g_true, axis=0)
    # with error feedback the *accumulated* update tracks the true sum to
    # within one step's quantization error
    step_err = np.abs(np.asarray(res["w"])).max()
    assert np.abs(acc_dec - acc_true).max() <= step_err + 1e-4


# ---------------------------------------------------------------------------
# tiered KV cache: paged view always equals a dense reference
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3), st.integers(4, 24))
def test_kv_tier_consistency(seed, n_seqs, n_tokens):
    """Interleaved appends under eviction pressure; per-seq kernel views
    (pinned) must always reconstruct the dense reference."""
    rng = np.random.default_rng(seed)
    hkv, hd, page = 2, 8, 4
    pages_per_seq = -(-n_tokens // page)
    # window holds one sequence's view (+1) but not all sequences -> spill
    hbm_pages = pages_per_seq + 1
    cache = PagedKVCache(page_size=page, hbm_pages=hbm_pages,
                         n_kv_heads=hkv, head_dim=hd, dtype=jnp.float32)
    dense = {s: [] for s in range(n_seqs)}
    for s in range(n_seqs):
        cache.add_sequence(s)
    for t in range(n_tokens):
        for s in range(n_seqs):
            k = rng.normal(size=(hkv, hd)).astype(np.float32)
            v = rng.normal(size=(hkv, hd)).astype(np.float32)
            cache.append_token(s, jnp.asarray(k), jnp.asarray(v))
            dense[s].append(k)
    for s in range(n_seqs):
        kp, vp, pt, lens = cache.kernel_view([s])
        kp = np.asarray(kp)
        assert int(lens[0]) == n_tokens
        got = kp[np.asarray(pt[0])].reshape(-1, hkv, hd)[:n_tokens]
        np.testing.assert_allclose(got, np.stack(dense[s]), atol=1e-6)
    if n_seqs * pages_per_seq > hbm_pages:
        assert cache.stats.page_outs > 0      # spill path exercised
    assert cache.residency() <= 1.0


# ---------------------------------------------------------------------------
# rwkv chunked form == per-token recurrence, for arbitrary chunk splits
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 8, 16]),
       st.sampled_from([16, 32, 48]))
def test_wkv_chunked_equals_scan(seed, chunk, s):
    if s % chunk:
        s = (s // chunk) * chunk or chunk
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    b, h, dk = 1, 2, 8
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dk))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk))
    s0 = jax.random.normal(ks[5], (b, h, dk, dk))
    o1, s1 = wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    o2, s2 = ref.wkv_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-4,
                               rtol=3e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-4,
                               rtol=3e-3)


# ---------------------------------------------------------------------------
# data pipeline determinism across resharding
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]))
def test_pipeline_determinism(seed, n_shards):
    from repro.data.pipeline import synthetic_stream
    full = [synthetic_stream(seed, step, s, batch=4, seq_len=8, vocab=97)
            for step in range(3) for s in range(n_shards)]
    again = [synthetic_stream(seed, step, s, batch=4, seq_len=8, vocab=97)
             for step in range(3) for s in range(n_shards)]
    for a, b in zip(full, again):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
