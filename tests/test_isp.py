"""In-storage analytics: ExtentStore, scan kernel, job/result frames,
the docker-cli front door, and the offload planner."""
import json
import urllib.parse

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (AnalyticsJob, ContainerError, ContainerOOM,
                        DockerSSDNode, EthernetFrame, ExtentStore,
                        ExtentStoreError, ImageManifest, SHARABLE_NS,
                        StoragePool, analytics_blob, from_jsonable,
                        make_blob, register_app)
from repro.core.analytical import data_plane_terms
from repro.core.ether_on import EtherONError
from repro.kernels import ops

EXT_CFG = {"n_pages": 16, "page_rows": 8, "n_cols": 16}


def _ref(data, threshold=0.0, *, filter_col=0, filter_op="all",
         page_rows=8, width=16):
    """Host fold at store width (matches device page zero-padding)."""
    data = np.asarray(data, np.float32)
    if data.shape[1] < width:
        data = np.pad(data, ((0, 0), (0, width - data.shape[1])))
    return np.asarray(ops.scan_filter_reduce_host(
        jnp.asarray(data), threshold, page_rows=page_rows,
        filter_col=filter_col, filter_op=filter_op))


def _pool(n=1):
    pool = StoragePool(n, extent_cfg=EXT_CFG)
    pool.broadcast_pull("isp-analytics", analytics_blob())
    return pool


# ---------------------------------------------------------------------------
# scan/filter/reduce kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("filter_op,col,thresh", [
    ("all", 0, 0.0), ("ge", 2, 0.1), ("lt", 5, -0.3), ("eq", 0, 0.0),
    ("ne", 1, 0.25),
])
def test_scan_kernel_matches_reference(filter_op, col, thresh):
    rng = np.random.default_rng(0)
    store = ExtentStore(**EXT_CFG)
    data = np.round(rng.normal(size=(43, 16)) * 2).astype(np.float32) / 4
    store.put("t", data)
    out = np.asarray(ops.scan_filter_reduce(
        store.pages, store.page_table("t"), 43, thresh,
        filter_col=col, filter_op=filter_op))
    ref = _ref(data, thresh, filter_col=col, filter_op=filter_op)
    assert np.array_equal(out, ref)          # bit-identical, not allclose
    # count row cross-checked against plain numpy
    mask = {"all": np.ones(43, bool), "ge": data[:, col] >= thresh,
            "lt": data[:, col] < thresh, "eq": data[:, col] == thresh,
            "ne": data[:, col] != thresh}[filter_op]
    assert out[0, 0] == mask.sum()


def test_scan_kernel_pow2_page_table_padding():
    """A non-pow2 extent pads its page table; padded iterations are
    masked out by the row count."""
    rng = np.random.default_rng(1)
    store = ExtentStore(**EXT_CFG)
    data = rng.normal(size=(3 * 8, 16)).astype(np.float32)   # 3 pages
    store.put("t", data)
    out = np.asarray(ops.scan_filter_reduce(
        store.pages, store.page_table("t"), data.shape[0], 0.0,
        filter_op="ge"))
    assert np.array_equal(out, _ref(data, 0.0, filter_op="ge"))


def test_scan_kernel_empty_filter_result():
    store = ExtentStore(**EXT_CFG)
    store.put("t", np.ones((10, 16), np.float32))
    out = np.asarray(ops.scan_filter_reduce(
        store.pages, store.page_table("t"), 10, 100.0, filter_op="ge"))
    assert out[0, 0] == 0.0
    assert np.all(out[2] > 1e29) and np.all(out[3] < -1e29)

    with pytest.raises(ValueError):
        ops.scan_filter_reduce(store.pages, store.page_table("t"), 10,
                               0.0, filter_op="between")


# ---------------------------------------------------------------------------
# ExtentStore
# ---------------------------------------------------------------------------


def test_extent_store_roundtrip_and_allocation():
    store = ExtentStore(**EXT_CFG)
    a = np.arange(20 * 16, dtype=np.float32).reshape(20, 16)
    b = np.ones((5, 10), np.float32)                 # narrow extent
    store.put("a", a)
    store.put("b", b)
    assert np.array_equal(store.get("a"), a)
    assert np.array_equal(store.get("b"), b)
    assert store.free_pages() == 16 - 3 - 1
    # page ids never overlap between extents
    assert not (set(store.extents["a"].page_ids) &
                set(store.extents["b"].page_ids))
    with pytest.raises(ExtentStoreError):
        store.put("a", a)                            # duplicate name
    store.drop("a")
    assert store.free_pages() == 16 - 1
    store.put("a2", a)                               # reuses freed pages


def test_extent_store_enospc_and_shape_errors():
    store = ExtentStore(**EXT_CFG)
    with pytest.raises(ExtentStoreError):
        store.put("big", np.zeros((17 * 8, 16), np.float32))
    with pytest.raises(ExtentStoreError):
        store.put("wide", np.zeros((4, 17), np.float32))
    with pytest.raises(ExtentStoreError):
        store.put("flat", np.zeros((8,), np.float32))
    with pytest.raises(ExtentStoreError):
        store.get("missing")


# ---------------------------------------------------------------------------
# docker-cli front door (query parsing + lifecycle round trip)
# ---------------------------------------------------------------------------


@register_app("echo-isp")
def _echo(ctx, value=41):
    ctx.log("running")
    return value + 1


def _node():
    return DockerSSDNode("10.0.0.2", extent_cfg=EXT_CFG)


def test_handle_http_query_parsing_robust():
    node = _node()
    d = node.docker
    # valueless key must not crash (the old dict(kv.split("=")) did)
    out = json.loads(d.handle_http("POST /containers/create?detach"))
    assert out["status"] == 400 and "image" in out["error"]
    # '=' inside a value survives
    out = json.loads(d.handle_http(
        "POST /containers/nope/start?job=a=b"))
    assert out["status"] == 400
    # bad paths/actions are 400-shaped errors, never raises
    for req in ("GET /", "GET /bogus/path", "POST /containers/1/fly",
                "totally broken", "GET /images/create"):
        out = json.loads(d.handle_http(req))
        assert out["status"] == 400 and out["error"]


def test_handle_http_lifecycle_roundtrip():
    """pull/create/run/stop/restart/kill/rm/logs/ps entirely through the
    HTTP front door."""
    node = _node()
    d = node.docker
    blob = make_blob(ImageManifest("img", "echo-isp", ["base"]),
                     {"base": b"\x00"})
    out = json.loads(d.handle_http("POST /images/create?fromImage=img",
                                   body=blob))
    assert out == {"status": "pulled", "name": "img"}
    assert json.loads(d.handle_http("GET /images/json")) == ["img"]

    cid = json.loads(d.handle_http(
        "POST /containers/create?image=img&mem=1048576"))["Id"]
    out = json.loads(d.handle_http(f"POST /containers/{cid}/start"))
    assert out["result"] == 42
    assert json.loads(d.handle_http(f"POST /containers/{cid}/stop")) == \
        {"status": "exited"}
    out = json.loads(d.handle_http(f"POST /containers/{cid}/restart"))
    assert out["result"] == 42
    logs = d.handle_http(f"GET /containers/{cid}/logs")
    assert b"exit code=0" in logs
    ps = json.loads(d.handle_http("GET /containers/json"))
    assert ps[0]["id"] == cid and ps[0]["state"] == "exited"
    assert json.loads(d.handle_http(f"DELETE /containers/{cid}")) == \
        {"status": "removed"}
    assert json.loads(d.handle_http("GET /containers/json")) == []

    # run = create + start in one request
    out = json.loads(d.handle_http("POST /containers/run?image=img"))
    assert out["result"] == 42 and out["Id"]
    d.handle_http(f"POST /containers/{out['Id']}/kill")
    assert json.loads(d.handle_http("GET /containers/json")
                      )[0]["state"] == "dead"


def test_mem_budget_enforced_as_container_error():
    @register_app("hog-isp")
    def hog(ctx):
        ctx.alloc(2 << 20)

    node = _node()
    node.docker.cmd_pull("hog", make_blob(
        ImageManifest("hog", "hog-isp", []), {}))
    cid = node.docker.cmd_create("hog", mem_budget=1 << 20)
    # the budget violation is a ContainerError AND a MemoryError
    with pytest.raises(ContainerError) as ei:
        node.docker.cmd_start(cid)
    assert isinstance(ei.value, ContainerOOM)
    assert isinstance(ei.value, MemoryError)
    ps = node.docker.cmd_ps()
    assert ps[0]["state"] == "dead" and ps[0]["exit_code"] == 137
    # through the front door the violation surfaces as a 400 error
    cid2 = node.docker.cmd_create("hog", mem_budget=1 << 20)
    out = json.loads(node.docker.handle_http(
        f"POST /containers/{cid2}/start"))
    assert out["status"] == 400 and "budget" in out["error"]


def test_analytics_container_respects_mem_budget():
    pool = _pool()
    ip = pool.alive_nodes()[0]
    node = pool.nodes[ip]
    node.extents.put("t", np.ones((8, 16), np.float32))
    # a budget smaller than one page + aggregate must OOM-kill the app
    cid = node.docker.cmd_create("isp-analytics", mem_budget=16)
    with pytest.raises(ContainerOOM):
        node.docker.cmd_start(cid, jobs=[AnalyticsJob(extent="t")])
    assert node.docker.cmd_ps()[0]["state"] == "dead"


# ---------------------------------------------------------------------------
# embed_agg validation (satellite)
# ---------------------------------------------------------------------------


def test_embed_agg_validates_before_kernel():
    table = jnp.asarray(np.random.default_rng(0).normal(size=(32, 8)),
                        jnp.float32)
    good = jnp.asarray([[0, 31, 5, 7]], jnp.int32)
    out = ops.embed_agg(table, good)
    ref = np.asarray(ops.ref.embed_agg_ref(table, good))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)

    with pytest.raises(TypeError):
        ops.embed_agg(table, jnp.asarray([[0.0, 1.0]], jnp.float32))
    with pytest.raises(ValueError):
        ops.embed_agg(table, jnp.asarray([[0, 32]], jnp.int32))   # == V
    with pytest.raises(ValueError):
        ops.embed_agg(table, jnp.asarray([[-1, 3]], jnp.int32))
    with pytest.raises(ValueError):
        ops.embed_agg(table, jnp.asarray([0, 1, 2], jnp.int32))   # 1-D


# ---------------------------------------------------------------------------
# Ether-oN job/result data plane
# ---------------------------------------------------------------------------


def test_job_frames_end_to_end_bit_identical():
    pool = _pool()
    ip = pool.alive_nodes()[0]
    rng = np.random.default_rng(2)
    data = rng.normal(size=(50, 16)).astype(np.float32)
    pool.nodes[ip].extents.put("t", data)
    jobs = [AnalyticsJob(extent="t", filter_col=2, filter_op="ge",
                         job_id=1),
            AnalyticsJob(extent="t", filter_col=0, filter_op="lt",
                         threshold=0.5, job_id=2)]
    out = from_jsonable(pool.driver.submit_jobs(
        ip, [j.to_dict() for j in jobs]))
    assert len(out) == 2
    assert np.array_equal(out[0], _ref(data, 0.0, filter_col=2,
                                       filter_op="ge"))
    assert np.array_equal(out[1], _ref(data, 0.5, filter_col=0,
                                       filter_op="lt"))
    # one batched frame, result bytes accounted
    assert pool.driver.stats.job_frames == 1
    assert pool.driver.stats.result_bytes > 0


def test_job_frames_release_node_resources():
    """A JOB frame must not leak: the batch's container is reclaimed,
    the ISP-pool job buffers are freed, and λFS space/inodes come back
    when the batch retires."""
    pool = _pool()
    ip = pool.alive_nodes()[0]
    node = pool.nodes[ip]
    node.extents.put("t", np.ones((8, 16), np.float32))
    job = AnalyticsJob(extent="t").to_dict()
    pool.driver.submit_jobs(ip, [job])
    n_containers = len(node.docker.cmd_ps())
    isp_pages = len(node.fw.pools.isp_pool)
    fs_used = node.fs.used
    n_inodes = len(node.fs._inodes)
    for _ in range(3):
        pool.driver.submit_jobs(ip, [job])
    assert len(node.docker.cmd_ps()) == n_containers
    assert len(node.fw.pools.isp_pool) == isp_pages
    assert node.fs.used == fs_used
    assert len(node.fs._inodes) == n_inodes


def test_job_frames_accept_sparse_dicts_and_stale_inbox():
    """Clients may send sparse job dicts (defaults fill in), and a stale
    un-drained frame from earlier traffic must not poison the next
    request."""
    pool = _pool()
    ip = pool.alive_nodes()[0]
    node = pool.nodes[ip]
    data = np.ones((8, 16), np.float32)
    node.extents.put("t", data)
    # leave a stale response chunk on the inbox (logs read, never drained)
    node.docker.cmd_run("isp-analytics", jobs=[AnalyticsJob(extent="t")])
    pool.driver.transmit(EthernetFrame("10.0.0.1", ip,
                                       b"GET /containers/1/logs"))
    out = from_jsonable(pool.driver.submit_jobs(ip, [{"extent": "t"}]))
    assert np.array_equal(out[0], _ref(data))


def test_pull_with_body_over_etheron():
    """docker pull over the wire: the blob rides after a blank line,
    HTTP-style."""
    pool = _pool()
    ip = pool.alive_nodes()[0]
    blob = make_blob(ImageManifest("wire-img", "echo-isp", []), {})
    pool.driver.transmit(EthernetFrame(
        "10.0.0.1", ip,
        b"POST /images/create?fromImage=wire-img\n\n" + blob))
    chunks = []
    while (fr := pool.driver.poll()) is not None:
        chunks.append(fr.payload)
    assert json.loads(b"".join(chunks)) == {"status": "pulled",
                                            "name": "wire-img"}
    assert "wire-img" in pool.nodes[ip].docker.images()


def test_job_frame_errors_surface():
    pool = _pool()
    ip = pool.alive_nodes()[0]
    with pytest.raises(EtherONError):
        pool.driver.submit_jobs(ip, [AnalyticsJob(extent="nope").to_dict()])
    with pytest.raises(EtherONError):
        pool.driver.fetch_extent(ip, "nope")


def test_job_frame_cost_accounting_matches_analytical_terms():
    """The data plane pays the same per-operation costs the Fig-3 model
    charges: recompute the expected microseconds from the stats deltas
    and the Costs constants."""
    pool = _pool()
    ip = pool.alive_nodes()[0]
    data = np.random.default_rng(3).normal(size=(40, 16)).astype(np.float32)
    pool.nodes[ip].extents.put("t", data)
    job = AnalyticsJob(extent="t", filter_op="ge")
    pool.driver.submit_jobs(ip, [job.to_dict()])      # warm the kernel

    s = pool.driver.stats
    before = (s.tx_commands, s.rx_completions, s.reposts,
              s.pages_allocated, s.bytes_tx, s.bytes_rx, s.time_us)
    pool.driver.submit_jobs(ip, [job.to_dict()])
    dtx = s.tx_commands - before[0]
    drx = s.rx_completions - before[1]
    drepost = s.reposts - before[2]
    dpages = s.pages_allocated - before[3]
    dbytes_tx = s.bytes_tx - before[4]
    dbytes_rx = s.bytes_rx - before[5]
    dus = s.time_us - before[6]

    c = pool.driver.costs
    tx_pages = dpages - drepost              # reposts alloc 1 page each
    expected = (
        # transmit: copy + doorbell + DMA + completion
        c.page_copy_per_kb * dbytes_tx / 1024 + dtx * (
            c.doorbell + c.completion_msi) + c.dma_per_page * tx_pages
        # upcalls: DMA (1 page each) + completion + copy
        + drx * (c.dma_per_page + c.completion_msi)
        + c.page_copy_per_kb * dbytes_rx / 1024
        # slot re-posts: doorbell each
        + drepost * c.doorbell)
    assert dus == pytest.approx(expected, rel=1e-9)

    terms = data_plane_terms(s, bytes_scanned=data.nbytes, n_jobs=2)
    assert terms["wire_bytes"] == s.bytes_tx + s.bytes_rx
    assert terms["us_per_job"] == pytest.approx(s.time_us / 2)
    assert terms["job_frames"] == s.job_frames == 2
    assert terms["reduction_ratio"] > 0


def test_front_door_over_etheron_matches_host_reference():
    """The acceptance path: an analytics job through the docker-cli
    front door, over Ether-oN frames, onto a pool node — bit-identical
    to the host-side reference fold."""
    pool = _pool(2)
    ip = pool.alive_nodes()[1]
    node = pool.nodes[ip]
    data = np.random.default_rng(4).normal(size=(30, 16)).astype(np.float32)
    node.fs.write("/data/t.bin", data.tobytes(), SHARABLE_NS, actor="host")
    node.ingest_extent("t", "/data/t.bin", 16)

    pool.driver.transmit(EthernetFrame(
        "10.0.0.1", ip, b"POST /containers/create?image=isp-analytics"))
    cid = json.loads(pool.driver.poll().payload)["Id"]
    job = AnalyticsJob(extent="t", filter_col=1, filter_op="ge",
                       threshold=0.0, reduce="count")
    q = urllib.parse.quote(json.dumps([job.to_dict()]))
    pool.driver.transmit(EthernetFrame(
        "10.0.0.1", ip,
        f"POST /containers/{cid}/start?job={q}".encode()))
    chunks = []
    while (fr := pool.driver.poll()) is not None:
        chunks.append(fr.payload)
    resp = from_jsonable(json.loads(b"".join(chunks)))
    block = resp["result"][0]
    assert np.array_equal(block, _ref(data, 0.0, filter_col=1,
                                      filter_op="ge"))
    assert block[0, 0] == (data[:, 1] >= 0.0).sum()


# ---------------------------------------------------------------------------
# offload planner
# ---------------------------------------------------------------------------


def _planner_pool():
    pool = _pool(2)
    rng = np.random.default_rng(5)
    for i, ip in enumerate(pool.alive_nodes()):
        pool.nodes[ip].extents.put(
            f"e{i}", rng.normal(size=(60, 16)).astype(np.float32))
    return pool


def test_planner_decision_follows_cost_model():
    from repro.runtime.offload import OffloadPlanner
    pool = _planner_pool()
    job = AnalyticsJob(extent="e0", filter_op="ge")
    # I/O-bound scan: storage savings dominate -> device
    io_bound = OffloadPlanner(pool).estimate(job)
    assert io_bound.choice == "device"
    # compute-bound operator: the 2.2 GHz frontend penalty dominates ->
    # host (the Fig-11 flip)
    cpu_bound = OffloadPlanner(pool, scan_gbs=0.05).estimate(job)
    assert cpu_bound.choice == "host"
    assert cpu_bound.node_ip == io_bound.node_ip == pool.locate_extent("e0")
    # the per-request intensity hint flips a single job under one
    # planner — the decision is per request, not per deployment
    planner = OffloadPlanner(pool)
    heavy = AnalyticsJob(extent="e0", filter_op="ge", scan_gbs=0.05)
    assert planner.estimate(heavy).choice == "host"
    assert planner.estimate(job).choice == "device"
    with pytest.raises(KeyError):
        OffloadPlanner(pool).estimate(AnalyticsJob(extent="missing"))


def test_planner_batches_per_node_and_matches_reference():
    from repro.runtime.offload import OffloadPlanner
    pool = _planner_pool()
    planner = OffloadPlanner(pool)
    jobs = [AnalyticsJob(extent="e0", filter_op="ge", job_id=0),
            AnalyticsJob(extent="e1", filter_op="lt", job_id=1),
            AnalyticsJob(extent="e0", filter_op="eq", job_id=2,
                         reduce="count")]
    before = pool.driver.stats.job_frames
    recs = planner.execute(jobs)
    # 3 jobs, 2 nodes -> 2 batched JOB frames
    assert pool.driver.stats.job_frames - before == 2
    assert [r["job"].job_id for r in recs] == [0, 1, 2]
    for rec in recs:
        assert rec["where"] == "device"
        data = pool.nodes[rec["est"].node_ip].extents.get(rec["job"].extent)
        ref = _ref(data, rec["job"].threshold,
                   filter_col=rec["job"].filter_col,
                   filter_op=rec["job"].filter_op)
        assert np.array_equal(rec["block"], ref)
    assert recs[2]["result"] == recs[2]["block"][0, 0]

    # forced host path produces the same blocks bit-for-bit
    host_recs = planner.execute(jobs, force="host")
    for dev, host in zip(recs, host_recs):
        assert host["where"] == "host"
        assert np.array_equal(dev["block"], host["block"])


def test_planner_shares_admission_with_router():
    """A serving node with no window headroom falls back to the host
    path instead of stealing the node from the router."""
    from repro.runtime.offload import OffloadPlanner
    pool = _planner_pool()
    ip0 = pool.locate_extent("e0")

    class BusyRouter:
        def node_headroom(self):
            return {0: 0, 1: 7}        # shard 0 saturated

    # bind a fake serving frontend: shard 0 = the node holding e0
    pool._server = object()
    pool._serve_ips = [ip0]
    planner = OffloadPlanner(pool, router=BusyRouter())
    recs = planner.execute([AnalyticsJob(extent="e0", filter_op="ge")])
    assert recs[0]["where"] == "host-admission"
    data = pool.nodes[ip0].extents.get("e0")
    assert np.array_equal(recs[0]["block"],
                          _ref(data, 0.0, filter_op="ge"))
    # an explicit force="device" is a pin — admission never reroutes it
    forced = planner.execute([AnalyticsJob(extent="e0", filter_op="ge")],
                             force="device")
    assert forced[0]["where"] == "device"
    assert np.array_equal(forced[0]["block"], recs[0]["block"])


def test_pool_router_node_headroom_surface():
    from repro.runtime.scheduler import PoolRouter

    class FakeServer:
        policy = "placed"
        pages_per_node = 10
        n_nodes = 2

        def alive_nodes(self):
            return [0, 1]

        def node_of(self, rid):
            return 0

        def pages_needed(self, n):
            return 2

    router = PoolRouter(FakeServer())
    assert router.node_headroom() == {0: 10, 1: 10}
