"""Training integration: loss decreases, grad-accum equivalence,
checkpoint restart, compression path."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig, get_arch
from repro.data.pipeline import synthetic_stream
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.optim import compression as comp
from repro.runtime.train import make_train_step


def _setup(arch="granite_3_2b", lr=3e-3):
    cfg = get_arch(arch).reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
    init_fn, upd_fn = adamw(lr=lr)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, init_fn, upd_fn


def _batches(cfg, n, batch=8, seq=32):
    return [
        {k: jnp.asarray(v) for k, v in synthetic_stream(
            0, i, 0, batch=batch, seq_len=seq, vocab=cfg.vocab_size,
            kind="learnable").items()}
        for i in range(n)]


def test_loss_decreases_on_learnable_data():
    cfg, model, params, init_fn, upd_fn = _setup()
    tstep = jax.jit(make_train_step(model, upd_fn), donate_argnums=(0, 1))
    opt = init_fn(params)
    losses = []
    for batch in _batches(cfg, 40):
        params, opt, m = tstep(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.6, losses[::10]


def test_grad_accum_equivalence():
    cfg, model, params, init_fn, upd_fn = _setup()
    batch = _batches(cfg, 1, batch=8, seq=32)[0]
    s1 = jax.jit(make_train_step(model, upd_fn, grad_accum=1))
    s4 = jax.jit(make_train_step(model, upd_fn, grad_accum=4))
    p1, _, m1 = s1(params, init_fn(params), batch)
    p4, _, m4 = s4(params, init_fn(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-4)


def test_compression_training_runs():
    cfg, model, params, init_fn, upd_fn = _setup()
    tstep = jax.jit(make_train_step(model, upd_fn, compression="int8"))
    opt = init_fn(params)
    res = comp.init_residuals(params)
    losses = []
    for batch in _batches(cfg, 25):
        params, opt, res, m = tstep(params, opt, res, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8
    assert np.isfinite(losses).all()


def test_checkpoint_restart_exact(tmp_path):
    """Crash/restart: resumed training is bit-identical to uninterrupted."""
    cfg, model, params0, init_fn, upd_fn = _setup()
    tstep = jax.jit(make_train_step(model, upd_fn))
    batches = _batches(cfg, 8)

    # uninterrupted
    p, o = params0, init_fn(params0)
    for b in batches:
        p, o, _ = tstep(p, o, b)
    ref_leaves = [np.asarray(x) for x in jax.tree.leaves(p)]

    # interrupted at step 4 + restored
    mgr = CheckpointManager(str(tmp_path))
    p, o = params0, init_fn(params0)
    for b in batches[:4]:
        p, o, _ = tstep(p, o, b)
    mgr.save(4, {"params": p, "opt": o})
    del p, o
    state = mgr.restore({"params": params0, "opt": init_fn(params0)})
    p, o = state["params"], state["opt"]
    for b in batches[4:]:
        p, o, _ = tstep(p, o, b)
    for a, r in zip(jax.tree.leaves(p), ref_leaves):
        np.testing.assert_allclose(np.asarray(a), r, atol=1e-6)


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for step in (1, 2, 3):
        mgr.save(step, tree, blocking=False)
    mgr.wait()
    assert mgr.steps() == [2, 3]            # GC keeps 2
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    out = mgr.restore(tree, step=3)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_into_lambdafs():
    from repro.core import LambdaFS
    fs = LambdaFS()
    mgr = CheckpointManager("/unused", fs=fs)
    tree = {"w": jnp.ones((4, 4)), "step": jnp.asarray(7)}
    mgr.save(11, tree)
    assert mgr.latest_step() == 11
    out = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))


def test_straggler_backup_fetch():
    import time
    from repro.data import ShardedLoader
    calls = {"n": 0}

    def slow_once(step):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.4)
        return synthetic_stream(0, step, 0, batch=2, seq_len=4, vocab=11)

    loader = ShardedLoader(global_batch=2, seq_len=4, vocab=11, n_shards=1,
                           shard=0, fetch_fn=slow_once, backup_after_ms=30)
    batch = next(loader)
    assert batch["tokens"].shape == (2, 4)
    assert loader.stats["backups_issued"] >= 1
    loader.close()
