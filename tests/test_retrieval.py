"""In-storage vector retrieval: the scored top-k scan kernel, the
``reduce="topk"`` analytics job over the Ether-oN wire, planner pricing
and admission, and the RetrievalFrontend feeding prefix-cached serving."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (AnalyticsJob, ExtentStore, StoragePool,
                        analytics_blob, from_jsonable)
from repro.core.extent_store import project
from repro.kernels import ops
from repro.kernels.isp_scan import (BIG_ID, MAX_TOPK, NEG_INF, REDUCE_ROWS,
                                    topk_pad)

EXT_CFG = {"n_pages": 16, "page_rows": 8, "n_cols": 16}


def _pool(n=1, **over):
    pool = StoragePool(n, extent_cfg=dict(EXT_CFG, **over))
    pool.broadcast_pull("isp-analytics", analytics_blob())
    return pool


def _store_topk(data, query, k, metric="dot", **over):
    """Run the kernel path over an ExtentStore holding ``data``."""
    cfg = dict(EXT_CFG, **over)
    store = ExtentStore(**cfg)
    store.put("e", data)
    return np.asarray(ops.topk_scan(
        store.pages, store.page_table("e"), data.shape[0],
        jnp.asarray(np.asarray(query, np.float32)), k=k, metric=metric,
        scales=store.scales))


def _host_topk(data, query, k, metric="dot", page_rows=8, width=16):
    data = np.asarray(data, np.float32)
    if data.shape[1] < width:
        data = np.pad(data, ((0, 0), (0, width - data.shape[1])))
    return np.asarray(ops.topk_scan_host(
        jnp.asarray(data), jnp.asarray(np.asarray(query, np.float32)),
        page_rows=page_rows, k=k, metric=metric))


# ---------------------------------------------------------------------------
# top-k scan kernel vs page-sequential reference fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_rows", [1, 7, 8, 9, 40, 43])
@pytest.mark.parametrize("metric", ["dot", "cosine"])
def test_topk_kernel_matches_reference(n_rows, metric):
    """Bit-identical (not allclose) across pow2-padded page counts: the
    kernel and the host fold share one page-fold function, so every
    score, id, and tie-break decision must agree exactly."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(n_rows, 16)).astype(np.float32)
    q = rng.normal(size=16).astype(np.float32)
    out = _store_topk(data, q, 5, metric)
    ref = _host_topk(data, q, 5, metric)
    assert out.shape == (REDUCE_ROWS, topk_pad(5))
    assert np.array_equal(out, ref)


def test_topk_order_matches_numpy_on_exact_scores():
    """Integer-valued rows make the f32 dot products exact, so the
    kernel's ranking must equal the numpy oracle's (score descending,
    row id ascending on ties)."""
    rng = np.random.default_rng(1)
    data = rng.integers(-3, 4, size=(43, 16)).astype(np.float32)
    q = rng.integers(-3, 4, size=16).astype(np.float32)
    out = _store_topk(data, q, 10)
    s = (data * q).sum(axis=1)
    order = np.lexsort((np.arange(len(s)), -s))[:10]
    assert np.array_equal(out[1, :10].astype(np.int64), order)
    assert np.array_equal(out[0, :10], s[order])


def test_topk_duplicate_scores_tiebreak_on_row_id():
    """All rows identical -> every score ties; winners must come out in
    ascending row-id order (the deterministic tie-break)."""
    data = np.tile(np.arange(16, dtype=np.float32), (12, 1))
    q = np.ones(16, np.float32)
    out = _store_topk(data, q, 4)
    assert np.array_equal(out[1, :4], [0.0, 1.0, 2.0, 3.0])
    assert np.array_equal(out, _host_topk(data, q, 4))


def test_topk_k_exceeds_rows_pads_with_sentinels():
    """k > n_rows: the real rows rank first, the tail keeps the empty
    (NEG_INF, BIG_ID) sentinel, and ``project`` drops it."""
    rng = np.random.default_rng(2)
    data = rng.normal(size=(5, 16)).astype(np.float32)
    q = rng.normal(size=16).astype(np.float32)
    out = _store_topk(data, q, 8)
    assert np.array_equal(out, _host_topk(data, q, 8))
    assert set(out[1, :5].astype(np.int64)) == set(range(5))
    assert np.all(out[0, 5:8] == NEG_INF)
    assert np.all(out[1, 5:8] == BIG_ID)
    job = AnalyticsJob(extent="e", reduce="topk", query=[0.0] * 16, k=8)
    pairs = project(out, job)
    assert len(pairs) == 5 and all(i < 5 for i, _ in pairs)


def test_topk_cosine_ranking_invariant_to_query_scale():
    """Cosine normalizes rows only, so scaling the query scales every
    score by one constant — the returned ids must not move."""
    rng = np.random.default_rng(3)
    data = rng.normal(size=(30, 16)).astype(np.float32)
    q = rng.normal(size=16).astype(np.float32)
    a = _store_topk(data, q, 6, "cosine")
    b = _store_topk(data, 4.0 * q, 6, "cosine")
    assert np.array_equal(a[1, :6], b[1, :6])


@pytest.mark.parametrize("page_dtype", ["int8", "fp8"])
def test_topk_quantized_extents_bit_identical(page_dtype):
    """int8/fp8 extents: the kernel dequantizes per page in VMEM with
    the same elementwise multiply ``ExtentStore.get`` applies host-side,
    so the folds stay bit-identical."""
    if page_dtype == "fp8":
        from repro.core.kv_tier import _fp8_dtype
        if _fp8_dtype() is None:
            pytest.skip("no fp8 dtype in this jax build")
    rng = np.random.default_rng(4)
    data = rng.normal(size=(43, 16)).astype(np.float32)
    q = rng.normal(size=16).astype(np.float32)
    store = ExtentStore(**dict(EXT_CFG, page_dtype=page_dtype))
    store.put("e", data)
    out = np.asarray(ops.topk_scan(
        store.pages, store.page_table("e"), 43, jnp.asarray(q), k=5,
        scales=store.scales))
    ref = _host_topk(store.get("e"), q, 5)
    assert np.array_equal(out, ref)


def test_topk_validates_arguments():
    rng = np.random.default_rng(5)
    data = rng.normal(size=(8, 16)).astype(np.float32)
    store = ExtentStore(**EXT_CFG)
    store.put("e", data)
    q = jnp.zeros(16)
    with pytest.raises(ValueError):
        ops.topk_scan(store.pages, store.page_table("e"), 8, q, k=0)
    with pytest.raises(ValueError):
        ops.topk_scan(store.pages, store.page_table("e"), 8, q,
                      k=MAX_TOPK + 1)
    with pytest.raises(ValueError):
        ops.topk_scan(store.pages, store.page_table("e"), 8, q, k=3,
                      metric="euclid")


# ---------------------------------------------------------------------------
# batched multi-query embed gather
# ---------------------------------------------------------------------------


def test_embed_gather_batched_matches_numpy():
    rng = np.random.default_rng(6)
    table = rng.integers(0, 500, size=(64, 8)).astype(np.int32)
    idx = rng.integers(0, 64, size=(3, 4)).astype(np.int32)
    out = np.asarray(ops.embed_gather(jnp.asarray(table),
                                      jnp.asarray(idx)))
    assert out.shape == (3, 4, 8)
    assert np.array_equal(out, table[idx])
    # same shape, different content: one jit serves the whole batch
    idx2 = rng.integers(0, 64, size=(3, 4)).astype(np.int32)
    out2 = np.asarray(ops.embed_gather(jnp.asarray(table),
                                       jnp.asarray(idx2)))
    assert np.array_equal(out2, table[idx2])


# ---------------------------------------------------------------------------
# topk AnalyticsJob: validation, wire round-trip, planner pricing
# ---------------------------------------------------------------------------


def test_topk_job_validation():
    from repro.core import ContainerError
    ok = AnalyticsJob(extent="e", reduce="topk", query=[0.0] * 4, k=3)
    ok.validate()
    with pytest.raises(ContainerError):
        AnalyticsJob(extent="e", reduce="topk", k=3).validate()  # no query
    with pytest.raises(ContainerError):
        AnalyticsJob(extent="e", reduce="topk", query=[0.0], k=0).validate()
    with pytest.raises(ContainerError):
        AnalyticsJob(extent="e", reduce="topk", query=[0.0],
                     k=MAX_TOPK + 1).validate()
    with pytest.raises(ContainerError):
        AnalyticsJob(extent="e", reduce="topk", query=[0.0], k=2,
                     metric="euclid").validate()
    with pytest.raises(ContainerError):
        AnalyticsJob(extent="e", reduce="sum", query=[0.0]).validate()


def test_topk_job_over_the_wire_matches_host_fold():
    """JOB frame in, RESULTS frame out: the containerized kernel's block
    survives the JSON round-trip bit-for-bit and projects to k (id,
    score) pairs."""
    pool = _pool()
    ip = pool.alive_nodes()[0]
    rng = np.random.default_rng(7)
    data = rng.normal(size=(43, 16)).astype(np.float32)
    pool.nodes[ip].extents.put("emb", data)
    q = rng.normal(size=16).astype(np.float32)
    job = AnalyticsJob(extent="emb", reduce="topk",
                       query=[float(x) for x in q], k=4)
    block = from_jsonable(pool.driver.submit_jobs(ip, [job.to_dict()]))[0]
    assert np.array_equal(block, _host_topk(data, q, 4))
    pairs = project(block, job)
    assert len(pairs) == 4
    assert all(isinstance(i, int) and isinstance(s, float)
               for i, s in pairs)


def test_planner_prices_topk_result_frame():
    """The planner's modeled RESULTS frame for a topk job is the padded
    (scores, ids) block — k pairs, not the extent."""
    from repro.runtime.offload import OffloadPlanner
    pool = _pool()
    ip = pool.alive_nodes()[0]
    rng = np.random.default_rng(8)
    pool.nodes[ip].extents.put(
        "emb", rng.normal(size=(120, 16)).astype(np.float32))
    job = AnalyticsJob(extent="emb", reduce="topk", query=[0.0] * 16, k=4)
    est = OffloadPlanner(pool).estimate(job)
    assert est.result_bytes == REDUCE_ROWS * topk_pad(4) * 4
    assert est.result_bytes < est.bytes_scanned


# ---------------------------------------------------------------------------
# RetrievalFrontend: admission, assembly, prefix-cached serving
# ---------------------------------------------------------------------------


def _tiny_model():
    from repro.configs.base import get_arch
    from repro.models.api import get_model
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _frontend(pool, server=None, *, n_docs=10, k=3, ingest=True, **kw):
    from repro.runtime.retrieval import RetrievalFrontend
    rng = np.random.default_rng(9)
    corpus = rng.integers(0, 64, size=(n_docs, 4)).astype(np.int32)
    emb = rng.normal(size=(n_docs, 16)).astype(np.float32)
    fe = RetrievalFrontend(pool, server, corpus_tokens=corpus, k=k,
                           template=np.arange(6, dtype=np.int32), **kw)
    if ingest:
        fe.ingest(emb)
    return fe, emb


def test_frontend_retrieve_device_matches_host():
    pool = _pool()
    fe, emb = _frontend(pool)
    q = np.random.default_rng(10).normal(size=16).astype(np.float32)
    dev = fe.retrieve([q], force="device")[0]
    host = fe.retrieve([q], force="host")[0]
    assert dev["where"] == "device" and host["where"] == "host"
    assert dev["ids"] == host["ids"]
    assert dev["scores"] == host["scores"]
    assert fe.stats["device"] == 1 and fe.stats["host"] == 1


def test_frontend_saturated_node_falls_back_to_host():
    """A serving node with no window headroom must not take the scoring
    job: the planner reroutes it to the host fold (same bits), counted
    as "host-admission"."""
    pool = _pool()
    ip = pool.alive_nodes()[0]

    class BusyRouter:
        def node_headroom(self):
            return {0: 0}               # the only shard: saturated

    pool._server = object()             # fake serving frontend binding
    pool._serve_ips = [ip]
    from repro.runtime.offload import OffloadPlanner
    # corpus big enough that the cost model on its own says "device" —
    # only the admission surface forces the reroute
    fe, emb = _frontend(pool, n_docs=60, planner=OffloadPlanner(
        pool, router=BusyRouter()))
    assert fe.planner.estimate(AnalyticsJob(
        extent=fe.extent, reduce="topk", query=[0.0] * 16,
        k=3)).choice == "device"
    q = np.random.default_rng(11).normal(size=16).astype(np.float32)
    hit = fe.retrieve([q])[0]
    assert hit["where"] == "host-admission"
    assert fe.stats["host-admission"] == 1
    # the fallback ranks identically to the pinned device path
    pinned = fe.retrieve([q], force="device")[0]
    assert pinned["ids"] == hit["ids"]
    assert pinned["scores"] == hit["scores"]


def test_frontend_fallback_never_stalls_inflight_decode():
    """Retrieval scoring arriving mid-decode on a saturated pool routes
    to the host path, and the in-flight horizons finish token-identical
    to a run with no analytics at all."""
    from repro.runtime.offload import OffloadPlanner
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request

    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for i in range(2)]

    def run(with_retrieval):
        srv = PoolServer(model, params, n_nodes=1, page_size=4,
                         hbm_pages_per_node=8, dtype=jnp.float32)
        pool = _pool()
        pool.attach_server(srv)
        fe, emb = _frontend(pool, n_docs=60)    # big enough for "device"
        router = PoolRouter(srv, pool, max_active=2, horizon=4)
        fe.planner = OffloadPlanner(pool, router=router)
        for i, p in enumerate(prompts):
            router.submit(Request(rid=i, prompt=p, max_tokens=8))
        router.step()                   # decode in flight, window busy
        where = None
        if with_retrieval:
            assert router.node_headroom()[0] <= 0
            q = np.random.default_rng(13).normal(size=16)
            where = fe.retrieve([q.astype(np.float32)])[0]["where"]
        st = router.run_to_completion()
        assert st["requests"] == 2
        return where, {r.rid: r.output for r in router.finished}

    where, with_ret = run(True)
    assert where == "host-admission"
    _, without = run(False)
    assert with_ret == without


def test_frontend_prompt_assembly_rank_order():
    pool = _pool()
    fe, emb = _frontend(pool, k=2)
    q = emb[7] + 0.01 * np.ones(16, np.float32)   # doc 7 dominates
    prompts, hits = fe.build_prompts([q], [np.asarray([9, 9],
                                                      np.int32)])
    assert hits[0]["ids"][0] == 7
    chunks = np.concatenate(
        [np.asarray(fe.corpus_tokens)[i] for i in hits[0]["ids"]])
    expect = np.concatenate([fe.template, chunks,
                             np.asarray([9, 9], np.int32)])
    assert np.array_equal(prompts[0], expect)


def test_frontend_warm_serving_token_identical():
    """End to end on a PagedServer: device-retrieval prompts admitted
    through the prefix cache decode token-identically to the host-side
    retrieval baseline on a cache-ablated server, and the second wave
    actually rides prefix pages."""
    from repro.runtime.serve import PagedServer
    cfg, model, params = _tiny_model()
    pool = _pool()
    warm = PagedServer(model, params, page_size=4, hbm_pages=32,
                       dtype=jnp.float32)
    cold = PagedServer(model, params, page_size=4, hbm_pages=32,
                       dtype=jnp.float32, prefix_cache=False)
    fe_w, emb = _frontend(pool, warm)
    fe_c, _ = _frontend(pool, cold, ingest=False)   # shared extent
    rng = np.random.default_rng(14)
    q = rng.normal(size=16).astype(np.float32)
    gen = 4

    def wave(fe, force):
        outs = {}
        for i in range(2):
            qt = np.asarray([i + 1, i + 2], np.int32)
            _, prompt, _ = fe.submit(i, q, qt, force=force)
            outs[i] = prompt
        dec = fe.server.decode(gen)
        got = {i: (list(outs[i]), dec[i]) for i in range(2)}
        for i in range(2):
            fe.server.free_sequence(i)
        return got

    base = wave(fe_c, "host")           # host retrieval, no cache
    first = wave(fe_w, "device")        # seeds template+chunks
    s0 = warm.table.stats.prefix_tokens
    second = wave(fe_w, "device")       # rides the shared prefix
    assert [p for p, _ in first.values()] == [p for p, _ in base.values()]
    assert first == base == second
    assert warm.table.stats.prefix_tokens > s0, \
        "second wave admitted without prefix hits"
