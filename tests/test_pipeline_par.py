"""Pipeline parallelism over the pod axis: GPipe loss/grads must equal
the monolithic reference (subprocess, 8 forced host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

# subprocess with 8 forced host devices: heavy
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_loss_and_grads_match_reference():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import auto_axis_kwargs
        from repro.configs.base import get_arch
        from repro.models.api import get_model
        from repro.runtime.pipeline_par import make_pipeline_loss

        cfg = get_arch("granite_3_2b").reduced()   # 2 layers -> 2 stages
        mesh = jax.make_mesh((2, 4), ("pod", "data"),
                             **auto_axis_kwargs(("pod", "data")))
        model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (16, 16), 0,
                                  cfg.vocab_size, jnp.int32)
        labs = jnp.concatenate(
            [toks[:, 1:], jnp.full((16, 1), -1, jnp.int32)], 1)
        batch = {"tokens": toks, "labels": labs}
        ref, _ = model.loss(params, batch)
        g_ref = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        loss_pp = make_pipeline_loss(model, mesh, n_microbatches=4)
        with mesh:
            got = jax.jit(loss_pp)(params, batch)
            g = jax.jit(jax.grad(loss_pp))(params, batch)
        lerr = abs(float(got) - float(ref))
        gerr = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree.leaves(g),
                                   jax.tree.leaves(g_ref)))
        print("PP_ERRS", lerr, gerr)
    """)
    parts = stdout.strip().split()
    assert float(parts[-2]) < 1e-5 and float(parts[-1]) < 1e-4
