"""Chaos suite: deterministic fault injection at the Ether-oN boundary.

Fast lane: FaultPlan round trip and validation, the delivery state
machine in isolation (NACK/dup/reorder/gap), byte-identical fabric
transfer under the canned lossy/storm plans, the zero-fault cost pin
(reliable delivery must cost exactly what unconditional delivery cost),
graceful degradation (scheduled crashes, straggler -> suspect steering,
the analytics retry ladder), explicit load shedding, and the sampled
failover-reproducibility contract on one device.

Slow lane (subprocess with forced host devices): an end-to-end chaos
run — lossy fabric + a mid-run node kill + a straggler, at
temperature > 0 — token-identical to the fault-free reference, a
requeue-storm shedding run, and a randomized-seed fabric sweep.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import AnalyticsJob, StoragePool, analytics_blob
from repro.core.ether_on import (Costs, DockerSSDEndpoint, EtherONDriver,
                                 EtherONError, EthernetFrame, NVMeCommand,
                                 OPC_TRANSMIT)
from repro.core.faults import (PRESET_PLANS, FaultInjector, FaultPlan,
                               load_plan)
from repro.runtime.offload import OffloadPlanner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HOST = "10.0.0.1"
EXT_CFG = {"n_pages": 16, "page_rows": 8, "n_cols": 16}


# ---------------------------------------------------------------------------
# FaultPlan: declarative, validated, JSON-round-trippable
# ---------------------------------------------------------------------------


def test_fault_plan_roundtrip_and_presets(tmp_path):
    plan = FaultPlan(seed=3, p_drop=0.1, p_corrupt=0.02, p_dup=0.05,
                     p_delay=0.04, delay_ops=2,
                     crashes={"10.0.1.2": 5}, stragglers={"10.0.1.3": 4.0})
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert plan.lossy and not FaultPlan().lossy
    # --fault-plan accepts a preset name, inline JSON, or a file path
    assert load_plan("lossy") == PRESET_PLANS["lossy"]
    assert load_plan(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    path.write_text(plan.to_json())
    assert load_plan(str(path)) == plan


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="p_drop"):
        FaultPlan(p_drop=1.5)
    with pytest.raises(ValueError, match="delay_ops"):
        FaultPlan(delay_ops=0)


def test_injector_replay_is_deterministic():
    """Same plan + same traffic => the exact same fault decisions."""
    plan = FaultPlan(seed=9, p_drop=0.2, p_corrupt=0.1, p_dup=0.1,
                     p_delay=0.1)

    def run():
        inj = FaultInjector(plan)
        seen = []
        for i in range(40):
            f = EthernetFrame(HOST, "10.0.1.2", b"m%d" % i).seal()
            f.seq = i
            seen += [(g.seq, g.verify()) for g in
                     inj.transit(f, "down", "10.0.1.2")]
        return seen, inj.stats.as_dict()

    assert run() == run()
    delivered, stats = run()
    assert stats["frames_seen"] == 40
    assert stats["dropped"] > 0 and stats["corrupted"] > 0
    # corrupted copies fail CRC; the sender's original is never damaged
    assert any(not ok for _, ok in delivered)


# ---------------------------------------------------------------------------
# delivery state machine in isolation
# ---------------------------------------------------------------------------


def _cmd(frame, cid=1):
    return NVMeCommand(OPC_TRANSMIT, cid, sq_id=0, prp=[0], n_pages=1,
                       frame=frame)


def test_receive_nack_dup_and_gap():
    """Device-side 0xE0 receive: CRC mismatch NACKs without side
    effects, a duplicate acks without re-running the handler, and a seq
    gap (stop-and-wait sender gave up) accepts and advances."""
    dev = DockerSSDEndpoint("10.0.1.2")
    got = []
    dev.set_handler(lambda fr: got.append(fr.payload))
    good = EthernetFrame(HOST, dev.ip, b"hello").seal()
    good.seq = 0
    bad = dataclasses.replace(good, payload=b"hellx")
    bad.checksum = good.checksum            # payload no longer matches
    assert dev._receive_from_host(_cmd(bad)) == "nack"
    assert got == [] and dev.rx_frames == 0
    assert dev._receive_from_host(_cmd(good)) == "ack"
    assert dev._receive_from_host(_cmd(good)) == "dup"
    assert got == [b"hello"]                # handler ran exactly once
    late = EthernetFrame(HOST, dev.ip, b"later").seal()
    late.seq = 5                            # seqs 1-4 were given up on
    assert dev._receive_from_host(_cmd(late)) == "ack"
    assert dev._rx_expected == 6


def test_upcall_reorder_stash_and_dedup():
    """Host-side 0xE1 receive: out-of-order frames stash until the gap
    fills, duplicates and corruption are counted, and the inbox always
    yields the original byte order."""
    drv = EtherONDriver(HOST)
    drv.attach(DockerSSDEndpoint("10.0.1.2"))

    def fr(seq, payload):
        f = EthernetFrame("10.0.1.2", HOST, payload).seal()
        f.seq = seq
        return f

    assert drv._upcall_rx("10.0.1.2", fr(1, b"B")) == "ack"   # early: stash
    assert drv.poll() is None
    bad = fr(0, b"A")
    bad.payload = b"Z"                       # checksum now stale
    assert drv._upcall_rx("10.0.1.2", bad) == "nack"
    assert drv._upcall_rx("10.0.1.2", fr(0, b"A")) == "ack"   # flushes stash
    assert drv._upcall_rx("10.0.1.2", fr(0, b"A")) == "dup"
    assert [drv.poll().payload, drv.poll().payload] == [b"A", b"B"]
    assert drv.stats.nacks == 1 and drv.stats.dup_frames == 1


def test_dead_node_transmit_raises_after_bounded_retries():
    drv = EtherONDriver(HOST, max_retries=2)
    dev = DockerSSDEndpoint("10.0.1.2")
    drv.attach(dev)
    dev.alive = False
    with pytest.raises(EtherONError, match="failed after 3 attempts"):
        drv.transmit(EthernetFrame(HOST, dev.ip, b"ping"))
    assert drv.stats.retransmits == 3
    # backoff doubled per attempt: 25 + 50 + 100
    assert drv.stats.backoff_us == pytest.approx(
        Costs().retransmit_timeout_us * 7)


# ---------------------------------------------------------------------------
# fabric invariants under fault plans
# ---------------------------------------------------------------------------


def _fabric(plan=None):
    drv = EtherONDriver(HOST)
    dev = DockerSSDEndpoint("10.0.1.2")
    rec = []
    dev.set_handler(lambda fr: rec.append(fr.payload))
    drv.attach(dev)
    inj = None
    if plan is not None:
        inj = FaultInjector(plan)
        drv.attach_faults(inj)
    return drv, dev, rec, inj


def _exercise(drv, dev, n_down=12, up_bytes=5000):
    """A bidirectional workload: n_down host->SSD frames, then one
    multi-MTU SSD->host burst.  Returns (sent, reassembled)."""
    sent = [b"msg-%03d" % i for i in range(n_down)]
    for p in sent:
        drv.transmit(EthernetFrame(HOST, dev.ip, p))
    blob = np.random.default_rng(0).integers(
        0, 256, up_bytes, dtype=np.uint8).tobytes()
    dev.send_to_host(blob, HOST)
    chunks = []
    while (f := drv.poll()) is not None:
        chunks.append(f.payload)
    return sent, blob, b"".join(chunks)


@pytest.mark.parametrize("preset", ["lossy", "storm"])
def test_fabric_byte_identity_under_preset_plans(preset):
    """The tentpole invariant at the fabric layer: under drop + corrupt
    + dup + reorder, both directions reassemble byte-identically, every
    recovery action is visible in the stats, and the whole run replays
    deterministically."""

    def run():
        drv, dev, rec, inj = _fabric(PRESET_PLANS[preset])
        sent, blob, up = _exercise(drv, dev)
        assert rec == sent, "host->SSD payloads reordered or damaged"
        assert up == blob, "SSD->host burst did not reassemble"
        return vars(drv.stats), inj.stats.as_dict()

    stats, inj = run()
    assert (stats, inj) == run()            # replayable bit for bit
    assert stats["retransmits"] > 0 and stats["backoff_us"] > 0
    # every corruption the injector made was caught by CRC and NACKed
    assert inj["corrupted"] > 0 and stats["nacks"] == inj["corrupted"]
    # every injected duplicate (plus any retransmit crossing a stashed
    # original) was deduped at the receiver
    assert stats["dup_frames"] >= inj["duplicated"] > 0


def test_zero_fault_plan_costs_byte_identical():
    """With an attached injector whose probabilities are all zero, the
    reliable path must cost *exactly* what the no-injector fabric
    costs — and every reliability counter must be exactly zero."""
    a = _fabric(None)
    b = _fabric(FaultPlan())
    for drv, dev, rec, _ in (a, b):
        sent, blob, up = _exercise(drv, dev)
        assert rec == sent and up == blob
    sa, sb = vars(a[0].stats), vars(b[0].stats)
    assert sa == sb
    for k in ("retransmits", "nacks", "dup_frames", "backoff_us"):
        assert sb[k] == 0, (k, sb[k])


def test_straggler_latency_is_charged_to_the_fabric_clock():
    fast, _, _, _ = _fabric(FaultPlan())
    slow, _, _, inj = _fabric(FaultPlan(stragglers={"10.0.1.2": 4.0}))
    for drv, dev in ((fast, fast._devices["10.0.1.2"]),
                     (slow, slow._devices["10.0.1.2"])):
        _exercise(drv, dev, n_down=4, up_bytes=100)
    assert inj.latency_mult("10.0.1.2") == 4.0
    assert slow.stats.time_us > fast.stats.time_us * 2


# ---------------------------------------------------------------------------
# pool degradation: crashes, suspects, the analytics retry ladder
# ---------------------------------------------------------------------------


def _ping(pool, ip, n=1):
    for _ in range(n):
        pool.driver.send_control(ip, "ping", 0)
    pool._drain_acks()


def test_scheduled_crash_fires_pool_failover():
    pool = StoragePool(2)
    ips = pool.alive_nodes()
    inj = pool.attach_faults(FaultPlan(crashes={ips[1]: 3}))
    _ping(pool, ips[0], n=3)                # op clock past the tick
    assert inj.node_crashed(ips[1])
    assert ips[1] not in pool.alive_nodes()
    assert ("fault-crash", ips[1]) in pool.events
    # the dead node's endpoint is dead too: delivery gives up cleanly
    with pytest.raises(EtherONError, match="node down"):
        pool.driver.send_control(ips[1], "ping", 0)


def test_straggler_becomes_suspect_and_clears():
    pool = StoragePool(3, extent_cfg=EXT_CFG)
    ips = pool.alive_nodes()
    pool.attach_faults(FaultPlan(stragglers={ips[0]: 8.0}))
    data = np.random.default_rng(1).normal(size=(8, 16)).astype(np.float32)
    for ip in (ips[0], ips[1]):
        pool.nodes[ip].extents.put("e", data)
    _ping(pool, ips[0], n=6)                # EMA converges toward 8x
    pool.check_heartbeats()
    assert pool.suspect_nodes() == [ips[0]]
    assert ("suspect", ips[0]) in pool.events
    # degraded, not dead: extents stay but new work steers away
    assert pool.locate_extent("e") == ips[1]
    assert set(pool.locate_replicas("e")) == {ips[0], ips[1]}
    pool.nodes[ips[0]].latency_ema_ms = 1.0
    pool.check_heartbeats()
    assert pool.suspect_nodes() == []
    assert ("suspect-cleared", ips[0]) in pool.events
    assert pool.locate_extent("e") == ips[0]


def _analytics_pool(n=3):
    pool = StoragePool(n, extent_cfg=EXT_CFG)
    pool.broadcast_pull("isp-analytics", analytics_blob())
    rng = np.random.default_rng(2)
    data = rng.normal(size=(40, 16)).astype(np.float32)
    ips = pool.alive_nodes()
    for ip in ips[:2]:                      # replicated on two nodes
        pool.nodes[ip].extents.put("e", data)
    job = AnalyticsJob(extent="e", reduce="topk",
                       query=[float(x) for x in rng.normal(size=16)], k=5)
    return pool, ips, job


def test_analytics_device_retry_on_replica_is_bit_identical():
    """Satellite: the extent's node dies between placement and JOB
    submission — the job resubmits on the surviving replica and the
    result is bit-identical to the healthy run."""
    pool, ips, job = _analytics_pool()
    ref = OffloadPlanner(pool).execute([job], force="device")[0]
    assert ref["where"] == "device" and ref["est"].node_ip == ips[0]
    # the node is placed on ips[0], then its endpoint dies before the
    # JOB frame lands (alive=True: the planner still routes there)
    pool.nodes[ips[0]].endpoint.alive = False
    rec = OffloadPlanner(pool).execute([job], force="device")[0]
    assert rec["where"] == "device-retry"
    assert rec["est"].node_ip == ips[1]
    assert np.array_equal(rec["block"], ref["block"])
    assert rec["result"] == ref["result"]
    assert ("unreachable", ips[0]) in pool.events
    assert pool.driver.stats.retransmits > 0


def test_analytics_host_fallback_is_bit_identical():
    """When no replica answers JOB frames either, the ladder drops to
    host execution over the tunnel — still bit-identical."""
    pool, ips, job = _analytics_pool()
    ref = OffloadPlanner(pool).execute([job], force="device")[0]
    pool.nodes[ips[0]].endpoint.alive = False
    real_submit = pool.driver.submit_jobs

    def no_jobs(ip, jobs):
        raise EtherONError(f"node {ip} lost its analytics container")

    pool.driver.submit_jobs = no_jobs
    rec = OffloadPlanner(pool).execute([job], force="device")[0]
    assert rec["where"] == "host-fallback"
    assert np.array_equal(rec["block"], ref["block"])
    assert rec["result"] == ref["result"]
    pool.driver.submit_jobs = real_submit


def test_analytics_raises_only_when_every_replica_is_dead():
    pool, ips, job = _analytics_pool()
    pool.nodes[ips[0]].fail()               # first replica already gone
    pool.nodes[ips[1]].endpoint.alive = False   # second dies in flight
    with pytest.raises(EtherONError, match="every replica"):
        OffloadPlanner(pool).execute([job], force="device")


def test_suspect_node_gets_no_new_analytics():
    pool, ips, job = _analytics_pool()
    host_ref = OffloadPlanner(pool).execute([job], force="host")[0]
    # one suspect replica: placement steers to the healthy one
    pool.nodes[ips[0]].suspect = True
    rec = OffloadPlanner(pool).execute([job])[0]
    assert rec["where"] == "device" and rec["est"].node_ip == ips[1]
    # every replica suspect: the job runs on the host instead
    pool.nodes[ips[1]].suspect = True
    rec = OffloadPlanner(pool).execute([job])[0]
    assert rec["where"] == "host-suspect"
    assert rec["result"] == host_ref["result"]


def test_reliability_terms_reach_the_analytical_model():
    from repro.core.analytical import (control_plane_terms,
                                       data_plane_terms,
                                       reliability_terms)
    pool, ips, job = _analytics_pool()
    pool.attach_faults(PRESET_PLANS["storm"])
    OffloadPlanner(pool).execute([job], force="device")
    st = pool.driver.stats
    terms = reliability_terms(st)
    assert terms["retransmits"] == st.retransmits
    assert terms["nacks"] == st.nacks > 0
    assert 0 < terms["backoff_frac"] < 1
    assert terms["backoff_us"] == pytest.approx(st.backoff_us)
    cp = control_plane_terms(st, n_tokens=100)
    dp = data_plane_terms(st, bytes_scanned=10_000, n_jobs=1)
    for t in (cp, dp):
        assert t["retransmits"] == st.retransmits
        assert t["backoff_us"] == pytest.approx(st.backoff_us)


# ---------------------------------------------------------------------------
# explicit load shedding (scheduler backpressure + rejection)
# ---------------------------------------------------------------------------


def _tiny_server():
    import dataclasses as dc
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_arch
    from repro.models.api import get_model
    from repro.runtime.serve import PagedServer

    cfg = dc.replace(get_arch("granite_3_2b").reduced(),
                     n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, PagedServer(model, params, page_size=4, hbm_pages=16,
                            dtype=jnp.float32)


def test_scheduler_sheds_load_explicitly():
    from repro.runtime.scheduler import ContinuousBatcher, Request

    cfg, server = _tiny_server()
    rng = np.random.default_rng(3)
    sched = ContinuousBatcher(server, max_active=2, max_waiting=2)

    def req(rid, n_prompt=6, max_tokens=3):
        return Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, n_prompt, dtype=np.int32),
            max_tokens=max_tokens)

    # capacity-impossible: more pages than the whole window can hold
    assert sched.submit(req(0, n_prompt=6, max_tokens=200)) is False
    assert "pages" in sched.rejected[0].reject_reason
    # backpressure: the queue cap rejects at the door, never silently
    assert sched.submit(req(1)) and sched.submit(req(2))
    assert sched.submit(req(3)) is False
    assert "queue full" in sched.rejected[1].reject_reason
    stats = sched.run_to_completion()
    assert stats["requests"] == 2 and stats["rejected"] == 2
    by_id = {r.rid: r for r in sched.finished}
    assert len(by_id[1].output) == 3 and len(by_id[2].output) == 3


# ---------------------------------------------------------------------------
# sampled failover reproducibility (one device)
# ---------------------------------------------------------------------------


def test_sampled_decode_is_pass_schedule_invariant():
    """Draws are a pure function of (seed, sequence, position): the
    same request decoded in one call or split across calls — the shape
    of a failover requeue resuming mid-stream — yields the same
    tokens."""
    from repro.runtime.serve import PagedServer, SamplingConfig

    cfg, server = _tiny_server()
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    samp = SamplingConfig(temperature=0.8, top_p=0.9, seed=11)
    server.add_request(0, prompt)
    whole = server.decode(8, horizon=4, sampling=samp)[0]
    _, server2 = _tiny_server()
    server2.add_request(0, prompt)
    split = server2.decode(4, horizon=4, sampling=samp)[0]
    split += server2.decode(4, horizon=4, sampling=samp)[0]
    assert split == whole


def test_speculative_sampled_matches_plain_sampled():
    """Gumbel-coupled acceptance: speculative decode at temperature > 0
    emits exactly the tokens plain sampled decode would."""
    from repro.runtime.serve import SamplingConfig

    cfg, server = _tiny_server()
    rng = np.random.default_rng(5)
    # a repetitive prompt gives the drafter real acceptances
    prompt = np.tile(rng.integers(0, cfg.vocab_size, 3,
                                  dtype=np.int32), 4)
    samp = SamplingConfig(temperature=0.7, seed=21)
    server.add_request(0, prompt)
    plain = server.decode(10, horizon=4, sampling=samp)[0]
    _, server2 = _tiny_server()
    server2.add_request(0, prompt)
    spec = server2.decode(10, horizon=4, sampling=samp,
                          speculative=True)[0]
    assert spec == plain


# ---------------------------------------------------------------------------
# slow lane: end-to-end chaos on a real multi-node pool
# ---------------------------------------------------------------------------


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_CHAOS_SETUP = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.core.faults import FaultPlan
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request
    from repro.runtime.serve import SamplingConfig

    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(5)]
    gens = [4, 6, 3, 5, 4]
    samp = SamplingConfig(temperature=0.8, top_p=0.9, seed=11)

    def run(plan_of=None, **router_kw):
        srv = PoolServer(model, params, n_nodes=4, page_size=4,
                         hbm_pages_per_node=8, dtype=jnp.float32)
        pool = StoragePool(4, heartbeat_timeout=0.0)
        pool.attach_server(srv)
        if plan_of is not None:
            pool.attach_faults(plan_of(pool))
        router = PoolRouter(srv, pool, max_active=5, horizon=4,
                            sampling=samp, **router_kw)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            router.submit(Request(rid=i, prompt=p, max_tokens=g))
        stats = router.run_to_completion()
        return {r.rid: r.output for r in router.finished}, pool, \\
            router, stats

    ref, ref_pool, _, _ = run()
"""


@pytest.mark.slow
def test_chaos_run_is_token_identical_to_fault_free():
    """THE invariant: a lossy fabric, a scheduled mid-run node kill and
    a straggler — at temperature > 0 — complete with zero unhandled
    exceptions, token-identical outputs, and every recovery action
    visible in the counters."""
    stdout = _run(_CHAOS_SETUP + """
    def plan_of(pool):
        ips = pool.serving_ips()
        return FaultPlan(seed=7, p_drop=0.08, p_corrupt=0.05,
                         p_dup=0.06, p_delay=0.06, delay_ops=2,
                         crashes={ips[1]: 12},
                         stragglers={ips[0]: 8.0})

    out, pool, router, stats = run(plan_of)
    assert out == ref, (out, ref)
    victim = pool.serving_ips()[1]
    assert victim not in pool.alive_nodes()
    assert any(e == ("fault-crash", victim) for e in pool.events)
    st = pool.driver.stats
    assert st.retransmits > 0 and st.nacks > 0
    assert pool.fault_injector.stats.corrupted > 0
    # fault-free reference kept its counters at exactly zero
    rs = ref_pool.driver.stats
    assert rs.retransmits == rs.nacks == rs.dup_frames == 0
    assert rs.backoff_us == 0.0
    print("CHAOS_OK", st.retransmits, st.nacks, st.dup_frames)
    """)
    assert "CHAOS_OK" in stdout


@pytest.mark.slow
def test_requeue_storm_sheds_instead_of_spinning():
    """With the per-request failover budget at zero, a node kill sheds
    the victims explicitly; the survivors still finish identically."""
    stdout = _run(_CHAOS_SETUP + """
    srv = PoolServer(model, params, n_nodes=4, page_size=4,
                     hbm_pages_per_node=8, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=0.0)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5, horizon=4,
                        sampling=samp, max_requeues=0)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        router.submit(Request(rid=i, prompt=p, max_tokens=g))
    router.step()
    rid = next(iter(router.active))         # a still-running request
    victim = srv.node_of(rid)
    pool.nodes[pool.serving_ips()[victim]].fail()
    stats = router.run_to_completion()
    assert stats["rejected"] >= 1
    shed = {r.rid for r in router.rejected}
    assert all("lost its node" in r.reject_reason
               for r in router.rejected)
    for r in router.finished:
        assert r.output == ref[r.rid], (r.rid, r.output)
    assert shed | {r.rid for r in router.finished} == set(range(5))
    print("SHED_OK", sorted(shed))
    """)
    assert "SHED_OK" in stdout


@pytest.mark.slow
def test_node_death_during_chunked_admission_requeues():
    """A node can die after an admission *opened* on it (placement
    recorded at begin_request) but before its first prefill chunk
    allocated any pages.  fail_node must count that sequence as a
    victim too — otherwise the router keeps prefilling onto a dead
    shard — and the requeued request must finish identically."""
    stdout = _run(_CHAOS_SETUP + """
    srv = PoolServer(model, params, n_nodes=4, page_size=4,
                     hbm_pages_per_node=8, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=0.0)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5, horizon=4,
                        sampling=samp, prefill_chunk=4)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        router.submit(Request(rid=i, prompt=p, max_tokens=g))
    # one _admit opens every admission but chunks only the first: the
    # rest are placed with zero pages allocated
    router._admit()
    rid = [r for r in router.prefilling if srv.table.length(r) == 0][0]
    victim = srv.node_of(rid)
    assert victim is not None
    pool.nodes[pool.serving_ips()[victim]].fail()
    router.run_to_completion()
    out = {r.rid: r.output for r in router.finished}
    assert out == ref, (out, ref)
    assert router.requeues >= 1
    print("ADMIT_KILL_OK", rid, victim)
    """)
    assert "ADMIT_KILL_OK" in stdout


@pytest.mark.slow
def test_randomized_seed_sweep_keeps_byte_identity():
    """Chaos sweep: many random seeds, same invariant — the reliable
    fabric reassembles byte-identically every time."""
    seeds = np.random.default_rng(0).integers(0, 2**31, 25)
    for s in seeds:
        plan = FaultPlan(seed=int(s), p_drop=0.1, p_corrupt=0.08,
                         p_dup=0.08, p_delay=0.08, delay_ops=2)
        drv, dev, rec, inj = _fabric(plan)
        sent, blob, up = _exercise(drv, dev, n_down=8, up_bytes=4000)
        assert rec == sent, f"seed {s}: down-path divergence"
        assert up == blob, f"seed {s}: up-path divergence"
        if inj.stats.corrupted:
            assert drv.stats.nacks == inj.stats.corrupted
