"""Tests for the §Perf beyond-paper optimizations: int8 KV cache,
TP-only serving specs, bf16 gather casting, shard_map MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.models.layers import quantize_kv


def test_int8_kv_decode_matches_fp():
    cfg = get_arch("qwen2_72b").reduced()
    m_fp = get_model(cfg, compute_dtype=jnp.float32)
    m_q8 = get_model(cfg, compute_dtype=jnp.float32, kv_quant="int8")
    p = m_fp.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    _, cache_fp = m_fp.prefill(p, {"tokens": toks[:, :16]},
                               cache_dtype=jnp.float32)
    pad = S - 16
    widths = [(0, 0)] * 3 + [(0, pad), (0, 0)]
    cache_fp["k"] = jnp.pad(cache_fp["k"], widths)
    cache_fp["v"] = jnp.pad(cache_fp["v"], widths)
    kq, ks = quantize_kv(cache_fp["k"])
    vq, vs = quantize_kv(cache_fp["v"])
    cache_q8 = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                "index": cache_fp["index"]}
    for t in range(16, S):
        lf, cache_fp = m_fp.decode_step(p, cache_fp, toks[:, t])
        lq, cache_q8 = m_q8.decode_step(p, cache_q8, toks[:, t])
        pf, pq = jax.nn.softmax(lf), jax.nn.softmax(lq)
        assert float(jnp.abs(pf - pq).max()) < 5e-3
        # greedy tokens must agree wherever fp32 clearly prefers one
        # (random-init reduced configs produce near-uniform logits, so a
        # sub-quantization-noise top-2 tie may legitimately flip)
        top2 = jnp.sort(lf, axis=-1)[:, -2:]
        decisive = np.asarray(top2[:, 1] - top2[:, 0] > 0.05)
        am_f = np.asarray(jnp.argmax(lf, -1))
        am_q = np.asarray(jnp.argmax(lq, -1))
        np.testing.assert_array_equal(am_f[decisive], am_q[decisive])


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 8, 64))
    q, s = quantize_kv(x)
    deq = q.astype(jnp.float32) * s[..., None]
    # error bounded by half an LSB of the per-token scale
    assert float(jnp.abs(deq - x).max()) <= float(jnp.max(s)) * 0.51
    assert q.dtype == jnp.int8


def test_serve_param_specs_strip_fsdp():
    import os
    from jax.sharding import PartitionSpec as P
    from repro.runtime import sharding as shd

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    mesh = FakeMesh()
    spec = shd.param_spec(mesh, ("layers", "attn", "wq"), (4, 1024, 2048))
    assert "data" in str(spec)
    # serve specs remove every fsdp axis but keep model
    fa = set(shd.fsdp_axes(mesh))

    def strip(sp):
        out = []
        for ax in sp:
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if a not in fa)
                out.append(kept if len(kept) > 1 else
                           (kept[0] if kept else None))
            else:
                out.append(None if ax in fa else ax)
        return out
    stripped = strip(spec)
    assert "data" not in str(stripped) and "pod" not in str(stripped)
    assert "model" in str(stripped)


def test_gather_dtype_training_equivalent_loss():
    """bf16-gather training should track fp32 training closely."""
    from repro.optim import adamw
    from repro.runtime.train import make_train_step
    from repro.data.pipeline import synthetic_stream
    cfg = get_arch("granite_3_2b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
    init_fn, upd_fn = adamw(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in synthetic_stream(
        0, 0, 0, batch=4, seq_len=32, vocab=cfg.vocab_size).items()}
    s32 = jax.jit(make_train_step(model, upd_fn))
    sbf = jax.jit(make_train_step(model, upd_fn,
                                  gather_dtype=jnp.bfloat16))
    _, _, m32 = s32(params, init_fn(params), batch)
    _, _, mbf = sbf(params, init_fn(params), batch)
    assert abs(float(m32["loss"]) - float(mbf["loss"])) < 0.05


def test_moe_shardmap_fallback_without_mesh():
    """Outside a mesh context the shardmap MoE falls back to dense and
    still computes correctly."""
    cfg = get_arch("phi3_5_moe_42b_a6_6b").reduced()
    m_d = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    m_s = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True,
                    moe_impl="shardmap")
    p = m_d.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size, jnp.int32)
    ref, _ = m_d.forward(p, {"tokens": toks})
    got, _ = m_s.forward(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=1e-5)
