"""Elastic pool tests: autoscaling, live migration, zero-drop drains.

Fast lane (single device, no mesh): the page-migration primitive
(content/sharing/prefix-index carriage, park/unpark semantics), the
``scale_to`` wiring fix, deadline shedding at the scheduler boundary,
and the autoscaler's decision logic against stub router/pool objects.
Slow lane (subprocess with forced host devices): drain and join
concurrent with chunked prefill + speculation + temperature>0 sampling
stay token-identical with zero sheds, including under a lossy-fabric +
straggler chaos plan with migration retransmits visible in the
delivery counters.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.kv_tier import PageStore, PageTableManager
from repro.core.storage_pool import StoragePool
from repro.models.api import get_model
from repro.runtime.autoscaler import Autoscaler, ServingSLO
from repro.runtime.pool import PoolServer
from repro.runtime.scheduler import ContinuousBatcher, Request
from repro.runtime.serve import PagedServer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _store(hbm_pages, n_layers=2, page=4):
    return PageStore(n_layers=n_layers, page_size=page,
                     hbm_pages=hbm_pages, n_kv_heads=2, head_dim=8,
                     dtype=jnp.float32)


# ---------------------------------------------------------------------------
# warm-path migration primitive (PageTableManager.migrate_page)
# ---------------------------------------------------------------------------


def test_migrate_page_moves_bytes_sharers_and_index():
    """One migrated page: identical bytes at the destination, every
    sharer remapped, refcount transferred whole, the prefix-index entry
    re-homed (warm admissions keep hitting it from the new shard), and
    the source slot back on its free list."""
    placement = {1: 0, 2: 0}
    store = _store(8)
    t = PageTableManager(store, n_shards=2,
                         shard_of=lambda s, pi: placement[s])
    t.add_sequence(1)
    t.set_length(1, 8)
    p0 = t.ensure_page(1, 0)
    t.ensure_page(1, 1)
    store.write_page(p0, np.full((2, 4, 2, 8), 3.0, np.float32),
                     np.full((2, 4, 2, 8), 5.0, np.float32))
    toks = np.arange(8, dtype=np.int32)
    t.register_prefix(1, toks)
    t.add_sequence(2)
    assert t.match_prefix(2, toks) > 0          # seq 2 shares page 0
    before = store.read_page(p0)
    new = t.migrate_page(p0, 1)
    after = store.read_page(new)
    assert t.shard_of_phys(new) == 1
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    assert t._resident[(1, 0)] == new and t._resident[(2, 0)] == new
    assert t._rc[new] == 2 and p0 not in t._rc
    assert p0 in t._free[0]
    # the moved page's prefix entry answers from the destination shard
    assert t.prefix_tokens_on_shard(toks, 1) == 4
    assert t.stats.migrated_out == 1 and t.stats.migrated_in == 1
    assert t.shard_stats[0].migrated_out == 1
    assert t.shard_stats[1].migrated_in == 1
    # aggregate-equals-sum-of-nodes holds for the new fields too
    agg = vars(t.stats)
    per = [vars(ss) for ss in t.shard_stats]
    assert all(agg[k] == sum(p[k] for p in per) for k in agg)


def test_migrate_unreferenced_cache_page_and_release():
    """A registered-but-unreferenced cache page migrates (stays
    reclaimable at the destination) or is dropped by
    ``release_shard_cache`` — either way the source window drains."""
    placement = {1: 0}
    store = _store(8)
    t = PageTableManager(store, n_shards=2,
                         shard_of=lambda s, pi: placement[s])
    t.add_sequence(1)
    t.set_length(1, 4)
    t.ensure_page(1, 0)
    t.register_prefix(1, np.arange(4, dtype=np.int32))
    t.free_sequence(1)                          # page -> reclaimable cache
    assert t.cached_pages == 1
    phys = next(iter(t._cached))
    new = t.migrate_page(phys, 1)
    assert t.shard_of_phys(new) == 1 and t.cached_pages == 1
    t.release_shard_cache(1)
    assert t.cached_pages == 0
    assert len(t._free[0]) == 4 and len(t._free[1]) == 4


def test_park_refuses_allocation_until_unpark():
    t = PageTableManager(_store(8), n_shards=2)
    t.park_shard(1)
    t.add_sequence(0)
    with pytest.raises(RuntimeError, match="parked"):
        t.ensure_resident(0, n_tokens=8)        # page 1 -> shard 1
    t.unpark_shard(1)
    assert len(t.ensure_resident(0, n_tokens=8)) == 2
    t.disable_shard(1)
    with pytest.raises(RuntimeError, match="cannot rejoin"):
        t.unpark_shard(1)


def test_migrate_page_rejects_unmapped_source():
    t = PageTableManager(_store(8), n_shards=2)
    with pytest.raises(ValueError, match="not resident"):
        t.migrate_page(0, 1)


# ---------------------------------------------------------------------------
# satellite: scale_to wires serving nodes or rejects
# ---------------------------------------------------------------------------


def test_scale_to_rejects_nodes_beyond_mesh_bucket():
    """With a serving mesh attached, a node that could never serve
    pages is rejected up front — not silently left off the shard map."""
    cfg, model, params = _tiny_model()
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=16, dtype=jnp.float32)
    pool = StoragePool(1)
    pool.attach_server(srv)
    with pytest.raises(RuntimeError, match="could never serve"):
        pool.scale_to(2)
    assert len(pool.nodes) == 1                 # nothing half-attached
    with pytest.raises(ValueError, match="grows the fabric"):
        pool.scale_to(0)


def test_scale_to_without_server_still_grows_fabric():
    """Analytics pools (no serving mesh) keep the plain fabric-join
    behavior."""
    pool = StoragePool(2)
    pool.scale_to(4)
    assert len(pool.nodes) == 4
    assert ("scale", "4") in pool.events


# ---------------------------------------------------------------------------
# satellite: per-request deadline budgets
# ---------------------------------------------------------------------------


def test_deadline_expired_in_queue_is_shed_with_reason():
    cfg, model, params = _tiny_model()
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    sched = ContinuousBatcher(srv, max_active=2)
    rng = np.random.default_rng(0)
    p = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    sched.submit(Request(rid=0, prompt=p, max_tokens=3))
    sched.submit(Request(rid=1, prompt=p, max_tokens=3,
                         deadline_s=0.0))       # expired on arrival
    sched.submit(Request(rid=2, prompt=p, max_tokens=3,
                         deadline_s=60.0))      # comfortably inside
    stats = sched.run_to_completion()
    assert stats["requests"] == 2 and stats["rejected"] == 1
    shed = sched.rejected[0]
    assert shed.rid == 1
    assert "deadline" in shed.reject_reason
    assert {r.rid for r in sched.finished} == {0, 2}


def test_deadline_none_never_sheds():
    """The default (no deadline) must stay byte-for-byte the old
    behavior — the sweep is a no-op without deadlines in the queue."""
    cfg, model, params = _tiny_model()
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    sched = ContinuousBatcher(srv, max_active=1)
    rng = np.random.default_rng(1)
    for i in range(3):
        sched.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 6,
                                       dtype=np.int32), max_tokens=2))
    stats = sched.run_to_completion()
    assert stats["requests"] == 3 and stats["rejected"] == 0


# ---------------------------------------------------------------------------
# autoscaler decision logic (stub router/pool — no devices)
# ---------------------------------------------------------------------------


class _StubReq:
    def __init__(self, t_arrive, t_first=None, t_done=None, n_out=4):
        now = time.monotonic()
        self.t_arrive = now + t_arrive
        self.t_first = now + (t_first if t_first is not None else t_arrive)
        self.t_done = now + (t_done if t_done is not None else t_arrive)
        self.output = [0] * n_out


class _StubTable:
    def __init__(self, free):
        self.free = free

    def shard_free_pages(self, s):
        return self.free[s]


class _StubServer:
    def __init__(self, n_nodes, active, free_per_node):
        self.n_nodes = n_nodes
        self.pages_per_node = 16
        self._alive = list(range(active))
        self.table = _StubTable(free_per_node)

    def alive_nodes(self):
        return list(self._alive)


class _StubPool:
    def __init__(self, server):
        self.server = server
        self.grows = []
        self.drains = []

    def grow_serving(self, n):
        self.grows.append(n)
        self.server._alive = list(range(n))

    def drain_serving_node(self, node):
        self.drains.append(node)
        self.server._alive.remove(node)
        return {"victims": [], "migrated_pages": 0, "cold": [],
                "moved": {}}


class _StubRouter:
    def __init__(self, server):
        self.server = server
        self.waiting = deque()
        self.prefilling = {}
        self.active = {}
        self.finished = []


def test_autoscaler_scales_up_on_queue_breach_with_cooldown():
    srv = _StubServer(4, 2, [16, 16, 16, 16])
    pool = _StubPool(srv)
    router = _StubRouter(srv)
    asc = Autoscaler(router, pool, slo=ServingSLO(queue_depth=3),
                     min_nodes=2, cooldown=3, sustain=100)
    for _ in range(6):
        router.waiting.append(_StubReq(-0.01))
    d = asc.tick()
    assert d is not None and d.kind == "up" and pool.grows == [3]
    assert "queue depth" in d.reason
    # cooldown: the very next ticks must NOT fire again
    assert asc.tick() is None and asc.tick() is None
    assert asc.tick() is not None               # cooldown elapsed
    assert pool.grows == [3, 4]
    # at max capacity: breach persists but no further decision
    assert asc.tick() is None and len(pool.grows) == 2


def test_autoscaler_ttft_breach_recovery_and_drain():
    srv = _StubServer(4, 3, [16, 16, 2, 16])
    pool = _StubPool(srv)
    router = _StubRouter(srv)
    asc = Autoscaler(router, pool,
                     slo=ServingSLO(ttft_p99_s=0.5),
                     min_nodes=1, cooldown=0, sustain=2,
                     headroom_frac=0.5, window=1)
    # slow finished requests breach the TTFT tail
    router.finished = [_StubReq(-2.0, t_first=-0.5) for _ in range(4)]
    d = asc.tick()
    assert d is not None and d.kind == "up" and "p99_ttft_s" in d.reason
    # fast requests land and the slow ones age past the tick window ->
    # the breach episode closes with a recovery stamp
    router.finished.extend(
        _StubReq(-2.0, t_first=-1.9) for _ in range(8))
    asc.tick()
    assert len(asc.recoveries) == 1
    assert asc.recoveries[0]["recovery_s"] >= 0.0
    # sustained idle headroom -> drain the emptiest node (node 0 or 1,
    # whichever frees most; stub node 2 is nearly full and must NOT be
    # picked as candidate... candidate = max free)
    for _ in range(3):
        d = asc.tick()
        if d is not None:
            break
    assert d is not None and d.kind == "down"
    assert pool.drains and pool.drains[0] in (0, 1, 3)


def test_autoscaler_skips_drain_without_absorbing_room():
    """Scale-down must not fire when no surviving window could absorb
    the candidate's resident pages — a drain that would go cold is
    worse than idle capacity."""
    srv = _StubServer(2, 2, [8, 2])             # 8 free vs 14 occupied
    pool = _StubPool(srv)
    router = _StubRouter(srv)
    asc = Autoscaler(router, pool, slo=ServingSLO(),
                     min_nodes=1, cooldown=0, sustain=1,
                     headroom_frac=0.0)
    for _ in range(5):
        assert asc.tick() is None
    assert pool.drains == []


# ---------------------------------------------------------------------------
# multi-node drain/join semantics (subprocess with forced host devices)
# ---------------------------------------------------------------------------


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_ELASTIC_SETUP = """
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request
    from repro.runtime.serve import SamplingConfig

    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
               for _ in range(5)]
    gens = [6, 8, 5, 7, 6]
    samp = SamplingConfig(temperature=0.8, top_p=0.9, seed=11)

    def submit_all(router):
        for i, (p, g) in enumerate(zip(prompts, gens)):
            router.submit(Request(rid=i, prompt=p, max_tokens=g))

    def run_static(active=None, fabric=4):
        srv = PoolServer(model, params, n_nodes=4, active=active,
                         page_size=4, hbm_pages_per_node=16,
                         dtype=jnp.float32)
        pool = StoragePool(fabric, heartbeat_timeout=1e9)
        pool.attach_server(srv)
        router = PoolRouter(srv, pool, max_active=5, horizon=4,
                            prefill_chunk=4, speculative=True,
                            sampling=samp)
        submit_all(router)
        router.run_to_completion()
        return ({r.rid: list(r.output) for r in router.finished},
                router, pool, srv)
"""


@pytest.mark.slow
def test_drain_under_chaos_token_identical_and_counted():
    """THE elastic acceptance criterion: a drain concurrent with active
    decode (chunked prefill + speculation + temperature>0), under a
    seeded lossy-fabric + straggler plan, completes with
    token-identical outputs vs the undisturbed run, zero shed
    requests, warm migrations visible in the MIGRATE counters (with
    chaos retransmits in the delivery counters) — and exactly zero
    MIGRATE frames on the static reference pool."""
    stdout = _run(_ELASTIC_SETUP + """
    from repro.core.faults import FaultPlan

    ref_out, ref_router, ref_pool, _ = run_static()
    assert not ref_router.rejected
    assert ref_pool.driver.stats.migrate_frames == 0
    assert ref_pool.driver.stats.migrate_bytes == 0

    srv = PoolServer(model, params, n_nodes=4, active=4, page_size=4,
                     hbm_pages_per_node=16, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=1e9)
    pool.attach_server(srv)
    pool.attach_faults(FaultPlan(seed=13, p_drop=0.12, p_corrupt=0.15,
                                 p_dup=0.08, p_delay=0.08,
                                 stragglers={"*": 4.0}))
    router = PoolRouter(srv, pool, max_active=5, horizon=4,
                        prefill_chunk=4, speculative=True, sampling=samp)
    submit_all(router)
    for _ in range(4):
        router.step()
    # drain a node that is actively serving sequences
    victim = next(n for n in (srv.node_of(i) for i in range(5))
                  if n is not None)
    rep = pool.drain_serving_node(victim)
    assert rep["migrated_pages"] > 0, rep
    router.run_to_completion()
    out = {r.rid: list(r.output) for r in router.finished}
    assert out == ref_out, (out, ref_out)
    assert not router.rejected                 # zero-drop
    st = pool.driver.stats
    assert st.migrate_frames == rep["migrated_pages"]
    assert st.migrate_bytes == rep["migrated_pages"] * srv.store.page_bytes()
    # the migration traffic rode the reliable tunnel through real
    # chaos: the sender retransmitted, and the injector's ground truth
    # confirms frames were actually damaged in flight
    assert st.retransmits > 0
    fi = pool.fault_injector.stats
    assert fi.dropped + fi.corrupted + fi.delayed > 0, fi.as_dict()
    assert victim in srv.parked_nodes()
    print("CHAOS_DRAIN_OK", st.migrate_frames, st.retransmits)
    """)
    assert "CHAOS_DRAIN_OK" in stdout


@pytest.mark.slow
def test_join_under_load_no_retrace_then_drain_back():
    """Scale 2->4 mid-run (scale_to wires + activates), outputs stay
    token-identical to a fixed-4-node run, no shard_map program is
    rebuilt by membership changes, and draining back to 2 with live
    sequences keeps zero sheds."""
    stdout = _run(_ELASTIC_SETUP + """
    ref_out, ref_router, _, _ = run_static()

    srv = PoolServer(model, params, n_nodes=4, active=2, page_size=4,
                     hbm_pages_per_node=16, dtype=jnp.float32)
    pool = StoragePool(2, heartbeat_timeout=1e9)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5, horizon=4,
                        prefill_chunk=4, speculative=True, sampling=samp)
    assert srv.alive_nodes() == [0, 1]
    submit_all(router)
    router.step(); router.step()
    compiled = dict(srv._sharded_specs); compiled.update(
        {('h', k): v for k, v in srv._sharded_horizons.items()})
    pool.scale_to(4)                    # satellite fix: wire + activate
    assert srv.alive_nodes() == [0, 1, 2, 3]
    assert len(pool.serving_ips()) == 4
    assert all(ip is not None for ip in pool.serving_ips())
    for _ in range(3):
        router.step()
    # membership change reused every compiled program (no retrace)
    for k, fn in compiled.items():
        if isinstance(k, tuple):
            assert srv._sharded_horizons[k[1]] is fn
        else:
            assert srv._sharded_specs[k] is fn
    # drain back down to 2 with sequences still decoding
    for node in (3, 2):
        if node in srv.alive_nodes():
            pool.drain_serving_node(node)
    assert len(srv.alive_nodes()) == 2
    router.run_to_completion()
    out = {r.rid: list(r.output) for r in router.finished}
    assert out == ref_out, (out, ref_out)
    assert not router.rejected
    # a drained node can rejoin: grow back and admit one more request
    pool.grow_serving(3)
    assert len(srv.alive_nodes()) == 3
    router.submit(Request(rid=99, prompt=prompts[0], max_tokens=4))
    router.run_to_completion()
    assert {r.rid for r in router.finished} >= {99}
    print("JOIN_DRAIN_OK")
    """)
    assert "JOIN_DRAIN_OK" in stdout


@pytest.mark.slow
def test_cold_path_requeues_when_nothing_fits():
    """Drain with no absorbing window: every victim takes the cold path
    (freed + requeued through the PR-2 failover machinery) and still
    finishes token-identically — zero requests shed."""
    stdout = _run(_ELASTIC_SETUP + """
    ref_out, ref_router, _, _ = run_static()

    srv = PoolServer(model, params, n_nodes=4, active=4, page_size=4,
                     hbm_pages_per_node=16, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=1e9)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5, horizon=4,
                        prefill_chunk=4, speculative=True, sampling=samp)
    submit_all(router)
    for _ in range(4):
        router.step()
    # a node actually holding pages for a live sequence
    victim = next(n for n in srv.alive_nodes() for i in range(5)
                  if srv.node_of(i) == n
                  and srv.table.resident_on_shard(i, n))
    # saturate every surviving window so nothing can absorb the
    # victim's pages — the warm path must step aside for the cold one
    stash = {}
    for s in srv.alive_nodes():
        if s != victim:
            srv.table.release_shard_cache(s)
            stash[s] = srv.table._free[s][:]
            srv.table._free[s].clear()
    rep = pool.drain_serving_node(victim)
    for s, pages in stash.items():
        srv.table._free[s].extend(pages)
    assert rep["cold"], rep                    # cold path exercised
    router.run_to_completion()
    out = {r.rid: list(r.output) for r in router.finished}
    assert out == ref_out, (out, ref_out)
    assert not router.rejected
    assert router.requeues >= 1
    print("COLD_DRAIN_OK", rep["cold"])
    """)
    assert "COLD_DRAIN_OK" in stdout
