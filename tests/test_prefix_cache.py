"""Shared-prefix KV page cache tests.

The contract: prompts whose token prefix matches an already-resident
sequence share its physical pages (refcount++, zero prefill compute);
any write into a shared page copy-on-writes a private split first;
eviction refuses shared pages until every sharer releases; and warm
(shared-prefix) admissions — one-shot or chunked, interleaved with
decode under eviction pressure — produce greedy outputs token-identical
to a cold start.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.kv_tier import PageStore, PageTableManager
from repro.models.api import get_model
from repro.runtime.scheduler import ContinuousBatcher, Request
from repro.runtime.serve import PagedServer


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _store(hbm_pages, page=4):
    return PageStore(n_layers=2, page_size=page, hbm_pages=hbm_pages,
                     n_kv_heads=2, head_dim=8, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# refcount lifecycle: share -> CoW split -> free (table-manager unit level)
# ---------------------------------------------------------------------------


def test_share_cow_free_lifecycle():
    t = PageTableManager(_store(16))
    toks = np.arange(10, dtype=np.int32)        # 2.5 pages @ page=4
    t.add_sequence(0)
    t.ensure_resident(0, n_tokens=10)
    t.set_length(0, 10)
    t.register_prefix(0, toks)

    # identical prompt: pages 0,1 shared full, tail page shared too
    # (coverage capped at len-1 so admission still computes logits)
    t.add_sequence(1)
    assert t.match_prefix(1, toks) == 9
    for pi in range(3):
        assert t._resident[(1, pi)] == t._resident[(0, pi)]
    assert t.resident_pages == 3                # shared pages count once
    assert t.stats.prefix_hits == 3
    assert t.stats.prefix_tokens == 9

    # CoW: the sharer's first append lands mid-page in the shared tail
    t.prepare_append(1)
    t.unpin_all()
    assert t._resident[(1, 2)] != t._resident[(0, 2)]
    assert t.stats.cow_splits == 1
    assert t.resident_pages == 4                # split added one page

    # free the owner: shared pages stay with the sharer, the owner's
    # private tail is retained as reclaimable cache (registered)
    assert t.free_sequence(0) == 3
    assert t.resident_pages == 3                # sharer still maps 0,1 + split
    assert t.cached_pages == 1                  # owner's registered tail
    # free the sharer: registered pages -> cache, the CoW split (never
    # registered) -> free list; everything is allocatable again
    t.free_sequence(1)
    assert t.resident_pages == 0
    assert t.free_pages == 16


def test_partial_template_share_and_rehit_from_cache():
    t = PageTableManager(_store(16))
    template = np.arange(8, dtype=np.int32)     # exactly 2 full pages
    a = np.concatenate([template, np.array([50, 51, 52], np.int32)])
    b = np.concatenate([template, np.array([60, 61], np.int32)])
    t.add_sequence(0)
    t.ensure_resident(0, n_tokens=a.shape[0])
    t.set_length(0, a.shape[0])
    t.register_prefix(0, a)
    t.add_sequence(1)
    assert t.match_prefix(1, b) == 8            # template pages only
    assert t._resident[(1, 0)] == t._resident[(0, 0)]
    assert t._resident[(1, 1)] == t._resident[(0, 1)]
    # after every sequence retires, the template persists as cache and
    # a later identical prompt still hits warm
    t.free_sequence(0)
    t.free_sequence(1)
    assert t.resident_pages == 0
    t.add_sequence(2)
    assert t.match_prefix(2, b) == 8


# ---------------------------------------------------------------------------
# eviction refuses shared pages until all sharers release
# ---------------------------------------------------------------------------


def test_eviction_refuses_shared_pages():
    t = PageTableManager(_store(6))
    toks = np.arange(8, dtype=np.int32)         # 2 full pages
    t.add_sequence(0)
    t.ensure_resident(0, n_tokens=8)
    t.set_length(0, 8)
    t.register_prefix(0, toks)
    t.add_sequence(1)
    assert t.match_prefix(1, toks) == 7         # shares both pages
    # window has 6 pages: 2 shared + 4 free.  A 5-page demand must spill
    # only unshared pages; the shared ones never leave HBM.
    shared_phys = {t._resident[(0, 0)], t._resident[(0, 1)]}
    t.add_sequence(2)
    t.ensure_resident(2, n_tokens=17)           # 5 pages -> one eviction
    assert t.stats.page_outs >= 1
    for pi in (0, 1):                           # both sharers intact
        assert t._resident[(0, pi)] in shared_phys
        assert t._resident[(1, pi)] in shared_phys
    # the spilled page was the demanding sequence's own, never a shared
    # one — shared pages are not evictable while any sharer holds them
    assert all(k[0] == 2 for k in t._host)

    # once every sharer releases, the pages become reclaimable again
    t.free_sequence(0)
    t.free_sequence(1)
    t.add_sequence(3)
    t.ensure_resident(3, n_tokens=4)            # reclaims cache slots
    assert t.resident_pages == 5                # 4 of seq 2 + 1 of seq 3
    assert t.host_pages == 1


def test_eviction_error_when_only_shared_left():
    t = PageTableManager(_store(2))
    toks = np.arange(8, dtype=np.int32)
    t.add_sequence(0)
    t.ensure_resident(0, n_tokens=8)
    t.set_length(0, 8)
    t.register_prefix(0, toks)
    t.add_sequence(1)
    t.match_prefix(1, toks)                     # both pages shared
    t.add_sequence(2)
    with pytest.raises(RuntimeError, match="pinned working set"):
        t.ensure_resident(2, n_tokens=4)


# ---------------------------------------------------------------------------
# shared-prefix decode == cold start (server level)
# ---------------------------------------------------------------------------


def test_shared_prefix_decode_matches_cold_start():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    template = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    prompts = [np.concatenate([template, rng.integers(
        0, cfg.vocab_size, 5, dtype=np.int32)]) for _ in range(3)]
    gen = 6

    def run(prefix_cache, chunk):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32, prefix_cache=prefix_cache)
        outs = {}
        for i, p in enumerate(prompts):
            outs[i] = [int(jnp.argmax(srv.add_request(i, p, chunk=chunk)))]
        for i, toks in srv.decode(gen).items():
            outs[i] += toks
        return outs, srv

    cold, _ = run(False, None)
    warm, srv = run(True, None)                 # in-run template sharing
    assert warm == cold
    assert srv.table.stats.prefix_hits > 0
    assert srv.prefix_hit_rate() > 0.2
    chunked, srv2 = run(True, 4)                # chunked warm admissions
    assert chunked == cold
    # warm re-admission on a live cache: whole prompt served from pages
    srv2.free_sequence(0)
    computed0 = srv2.prefill_tokens_computed
    out = [int(jnp.argmax(srv2.add_request(0, prompts[0], chunk=4)))]
    assert srv2.prefill_tokens_computed - computed0 == 1
    out += srv2.decode(gen, seqs=[0])[0]
    assert out == cold[0]


def test_cow_isolates_sharers_decode():
    """Two sequences sharing a partially-filled tail page must decode
    independently: the first writer splits the page and neither sees
    the other's appends."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 10, dtype=np.int32)
    gen = 5

    cold_srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                           dtype=jnp.float32, prefix_cache=False)
    cold_srv.add_request(0, prompt)
    cold = cold_srv.decode(gen, seqs=[0])[0]

    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    srv.add_request(0, prompt)
    srv.add_request(1, prompt)                  # shares the tail page
    out0 = srv.decode(gen, seqs=[0])[0]         # writer 0 CoWs
    out1 = srv.decode(gen, seqs=[1])[1]         # writer 1 CoWs its own
    assert srv.table.stats.cow_splits >= 1
    assert out0 == cold and out1 == cold


# ---------------------------------------------------------------------------
# eviction-pressure interleaving with chunked admission
# ---------------------------------------------------------------------------


def test_chunked_admission_interleaves_under_eviction_pressure():
    """A window smaller than two working sets, a shared template,
    chunked warm admissions and fused decode horizons: the idle
    sequence's unshared pages spill and page back, shared template
    pages never leave HBM, and every output matches the cold roomy
    run."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(1)
    template = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    prompts = [np.concatenate([template, rng.integers(
        0, cfg.vocab_size, 4, dtype=np.int32)]) for _ in range(2)]
    gen = 4

    ref = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32, prefix_cache=False)
    srv = PagedServer(model, params, page_size=4, hbm_pages=4,
                      dtype=jnp.float32)
    for i, p in enumerate(prompts):
        la = ref.add_request(i, p)
        lb = srv.add_request(i, p, chunk=4)     # chunked warm admission
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-4)
    assert srv.table.stats.prefix_hits > 0      # template shared
    o_ref1 = ref.decode(gen, seqs=[1])
    o_srv1 = srv.decode(gen, seqs=[1], horizon=4)   # seq 0 spills
    o_ref0 = ref.decode(gen, seqs=[0])
    o_srv0 = srv.decode(gen, seqs=[0], horizon=4)   # seq 0 pages back
    assert o_ref1 == o_srv1 and o_ref0 == o_srv0
    assert srv.tier_stats()["page_outs"] > 0
    assert srv.tier_stats()["page_ins"] > 0


def test_batcher_chunked_matches_blocking_cold_schedule():
    """ContinuousBatcher(prefill_chunk=C) — admissions advanced one
    chunk per iteration between decode horizons — must finish every
    request with output identical to the blocking per-token cold
    schedule, and reclaim every page."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(4)
    template = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    prompts = [np.concatenate([template, rng.integers(
        0, cfg.vocab_size, 4, dtype=np.int32)]) for _ in range(4)]
    gens = [5, 3, 6, 4]

    def run(prefix_cache, chunk, horizon):
        srv = PagedServer(model, params, page_size=4, hbm_pages=16,
                          dtype=jnp.float32, prefix_cache=prefix_cache)
        b = ContinuousBatcher(srv, max_active=2, horizon=horizon,
                              prefill_chunk=chunk)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            b.submit(Request(rid=i, prompt=p, max_tokens=g))
        stats = b.run_to_completion()
        assert stats["requests"] == 4
        assert srv.table.free_pages == srv.hbm_pages    # all reclaimed
        assert len(srv.table._pinned) == 0
        return {r.rid: r.output for r in b.finished}, srv

    ref, _ = run(False, None, 1)                # cold, blocking
    got, srv = run(True, 4, 4)                  # warm, chunked
    assert got == ref
    assert srv.table.stats.prefix_hits > 0      # sharing was real


# ---------------------------------------------------------------------------
# no-retrace: chunked prefill compiles once per pow2 (chunk, row) bucket
# ---------------------------------------------------------------------------


def test_chunk_no_retrace_across_sizes():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(2)
    srv = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32)
    if not hasattr(srv._chunk_jit, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")

    def admit(seq, n_tokens, chunk):
        srv.add_request(seq, rng.integers(0, cfg.vocab_size, n_tokens,
                                          dtype=np.int32), chunk=chunk)

    admit(0, 13, 4)          # chunks 4,4,4,1 -> buckets C=4, C=1
    sig0 = srv._chunk_jit._cache_size()
    admit(1, 11, 4)          # chunks 4,4,3 -> same buckets, same rows
    assert srv._chunk_jit._cache_size() == sig0
    admit(2, 9, 3)           # chunks 3,3,3 -> C=4 bucket again
    assert srv._chunk_jit._cache_size() == sig0
    admit(3, 16, None)       # one-shot: C=16 -> exactly one new trace
    assert srv._chunk_jit._cache_size() == sig0 + 1
    admit(4, 15, None)       # C bucket 16 again, shorter row
    assert srv._chunk_jit._cache_size() == sig0 + 1
