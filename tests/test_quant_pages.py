"""Quantized KV page format tests (int8/fp8 codes + per-slot f32 scales).

The contract: a quantized PageStore carries scales through the entire
page lifecycle (append, CoW split, host-tier spill, prefix digest);
the fused-dequant decode path matches the pure-jnp oracle to 1e-4 and
agrees with the fp32 server's greedy argmax wherever the fp32 logits
are decisive; fused horizons, chunked prefill and the 1-node pool all
produce outputs identical to the per-token quantized path; and the
quantized in-storage reduce stays bit-identical to the
host-reads-everything baseline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.extent_store import ExtentStore
from repro.core.kv_tier import (PageStore, PageTableManager,
                                dequantize_page_kv, quantize_page_kv)
from repro.kernels import ops
from repro.models.api import get_model
from repro.runtime.pool import PoolServer
from repro.runtime.serve import PagedServer

QDTYPES = ["int8"] + (["fp8"] if hasattr(jnp, "float8_e4m3fn") else [])


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _store(page_dtype, hbm_pages=16, page=4):
    return PageStore(n_layers=2, page_size=page, hbm_pages=hbm_pages,
                     n_kv_heads=2, head_dim=8, dtype=jnp.float32,
                     page_dtype=page_dtype)


# ---------------------------------------------------------------------------
# PageStore: quantized lifecycle at the unit level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("page_dtype", QDTYPES)
def test_quantize_roundtrip_error_bound(page_dtype):
    st = _store(page_dtype)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 2, 8)).astype(np.float32) * 3)
    codes, scale = quantize_page_kv(x, st.qmax, st.code_dtype)
    back = dequantize_page_kv(codes, scale)
    # symmetric per-slot quantization: error bounded by scale/2 per elem
    # (int8) and ~6% relative (fp8 e4m3); both well inside 1.5*scale
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(scale)[..., None] * (0.5 if page_dtype == "int8"
                                            else 32.0)
    assert (err <= bound + 1e-6).all()
    assert codes.dtype == st.code_dtype and scale.dtype == jnp.float32


@pytest.mark.parametrize("page_dtype", QDTYPES)
def test_page_write_copy_spill_carry_scales(page_dtype):
    """write_token quantizes; copy_page and the read/write_page spill
    path carry codes AND scales, so a restored or CoW'd page
    dequantizes identically to its original."""
    st = _store(page_dtype)
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    for li in range(2):
        st.write_token(li, 3, 1, k, v)
    assert float(jnp.abs(st.k_scale[0, 3, 1]).min()) > 0

    def deq(phys):
        return np.asarray(dequantize_page_kv(st.k_pages[:, phys],
                                             st.k_scale[:, phys]))

    orig = deq(3)
    st.copy_page(3, 7)                        # CoW split
    np.testing.assert_array_equal(deq(7), orig)

    spilled = st.read_page(3)                 # HBM -> host tier
    assert len(spilled) == 4                  # codes x2 + scales x2
    st.write_token(0, 3, 1, 2 * k, 2 * v)     # clobber
    st.write_page(3, *spilled)                # host tier -> HBM
    np.testing.assert_array_equal(deq(3), orig)


def test_prefix_digest_keyed_by_page_format():
    """Prefix-cache digests mix in the page format: an fp32 tree and an
    int8 tree of the same tokens can never alias, so a warm admission
    never adopts pages written in another format."""
    toks = np.arange(8, dtype=np.int32)
    t32 = PageTableManager(_store("fp32"))
    t8 = PageTableManager(_store("int8"))
    assert t32.store.format_key != t8.store.format_key
    assert t32._digest(toks) != t8._digest(toks)
    # registration in one format is invisible to the other
    for t in (t32, t8):
        t.add_sequence(0)
        t.ensure_resident(0, n_tokens=8)
        t.set_length(0, 8)
    t32.register_prefix(0, toks)
    t8.add_sequence(1)
    assert t8.match_prefix(1, toks) == 0      # no cross-format hit
    t8.register_prefix(0, toks)
    t8.add_sequence(2)
    assert t8.match_prefix(2, toks) == 7      # same-format hit intact


def test_capacity_doubles_at_equal_byte_budget():
    """The acceptance floor: at an equal HBM byte budget the int8
    window admits >= 2x the pages (hence >= 2x the sequences) of the
    fp32 window."""
    kw = dict(n_layers=2, page_size=4, n_kv_heads=2, head_dim=8,
              dtype=jnp.float32)
    budget = 64 * PageStore.stacked_page_bytes(page_dtype="fp32", **kw)
    pages32 = budget // PageStore.stacked_page_bytes(page_dtype="fp32",
                                                     **kw)
    pages8 = budget // PageStore.stacked_page_bytes(page_dtype="int8",
                                                    **kw)
    assert pages8 >= 2 * pages32

    # and end to end on a real server: same byte budget, >= 2x window
    _, model, params = _tiny_model()
    srv32 = PagedServer(model, params, page_size=4, hbm_pages=16,
                        dtype=jnp.float32)
    budget = 16 * srv32.store.page_bytes()
    srv8 = PagedServer(model, params, page_size=4, hbm_bytes=budget,
                       dtype=jnp.float32, page_dtype="int8")
    assert srv8.table.free_pages >= 2 * srv32.table.free_pages


# ---------------------------------------------------------------------------
# decode parity: fused-dequant kernel vs oracle vs fp32
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("page_dtype", QDTYPES)
def test_decode_step_matches_quantized_reference(page_dtype):
    """The jitted fused-dequant step must reproduce the per-layer
    python loop over the same quantized pages (the jnp q8 oracle) to
    1e-4 — a kernel-vs-specification check, not a quantization-error
    check."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(3)
    B, S = 2, 9
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    server = PagedServer(model, params, page_size=4, hbm_pages=32,
                         dtype=jnp.float32, page_dtype=page_dtype)
    for i in range(B):
        server.add_request(i, prompts[i])
    for _ in range(2):
        toks = {i: server._pending[i] for i in range(B)}
        ref = np.asarray(server.step_reference(toks))
        got = server.step(toks)
        got = np.stack([np.asarray(got[i]) for i in range(B)])
        np.testing.assert_allclose(got, ref, atol=1e-4)
        server._pending = {i: int(np.argmax(got[i])) for i in range(B)}


def _greedy(server, prompts, gen):
    B = prompts.shape[0]
    lasts = [server.add_request(i, prompts[i]) for i in range(B)]
    first = [int(jnp.argmax(l)) for l in lasts]
    out = server.decode(gen - 1)
    return (np.stack(lasts),
            np.stack([[first[i]] + out[i] for i in range(B)]))


def _forced_logits(model, params, prompts, page_dtype, token_stream):
    """Admit, then teacher-force ``token_stream`` ([n_steps][B]) through
    the jitted decode step; returns all logits [1+n_steps, B, vocab]."""
    B = prompts.shape[0]
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32, page_dtype=page_dtype)
    out = [np.stack([np.asarray(srv.add_request(i, prompts[i]))
                     for i in range(B)])]
    for toks in token_stream:
        got = srv.step({i: int(toks[i]) for i in range(B)})
        out.append(np.stack([np.asarray(got[i]) for i in range(B)]))
    return np.concatenate(out, 0)


def test_int8_matches_fp32_on_decisive_logits():
    """Quantized greedy decode agrees with fp32 wherever the fp32
    logits are decisive (top-2 gap > 0.05) — quantization may only flip
    near-ties.  Both servers are teacher-forced with the fp32 greedy
    stream so every compared position saw identical context."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(5)
    B, S, gen = 2, 7, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    _, toks = _greedy(PagedServer(model, params, page_size=4, hbm_pages=32,
                                  dtype=jnp.float32), prompts, gen)
    stream = [toks[:, t] for t in range(gen - 1)]
    lf = _forced_logits(model, params, prompts, "fp32", stream)
    lq = _forced_logits(model, params, prompts, "int8", stream)
    srt = np.sort(lf, -1)
    decisive = srt[:, -1] - srt[:, -2] > 0.05
    assert decisive.any()
    np.testing.assert_array_equal(lf.argmax(-1)[decisive],
                                  lq.argmax(-1)[decisive])


def test_int8_horizon_and_chunked_prefill_match_per_token():
    """Within the int8 format: the fused H=8 horizon and a chunked
    admission produce tokens identical to per-token decode with
    one-shot admission (same pages, same kernel, different schedule)."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(11)
    B, S, gen = 2, 9, 8
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)

    def run(horizon=None, chunk=None):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32, page_dtype="int8")
        lasts = [srv.add_request(i, prompts[i], chunk=chunk)
                 for i in range(B)]
        first = [int(jnp.argmax(l)) for l in lasts]
        out = srv.decode(gen - 1, horizon=horizon)
        return np.stack([[first[i]] + out[i] for i in range(B)])

    base = run()
    np.testing.assert_array_equal(run(horizon=8), base)
    np.testing.assert_array_equal(run(chunk=4), base)


def test_int8_cow_split_then_write_keeps_sharer_output():
    """Two admissions sharing a quantized prefix: the sharer's decode
    CoW-splits the shared tail (codes+scales) and both sequences decode
    exactly as they would without sharing."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(13)
    # S=10 @ page=4: the share covers S-1=9 tokens, so the tail page is
    # shared *partially* and the sharer's first append must CoW-split it
    S, gen = 10, 5
    prompt = rng.integers(0, cfg.vocab_size, S, dtype=np.int32)

    solo = PagedServer(model, params, page_size=4, hbm_pages=32,
                       dtype=jnp.float32, page_dtype="int8")
    first = int(jnp.argmax(solo.add_request(0, prompt)))
    base = [first] + solo.decode(gen - 1, seqs=[0])[0]

    shared = PagedServer(model, params, page_size=4, hbm_pages=32,
                         dtype=jnp.float32, page_dtype="int8")
    f0 = int(jnp.argmax(shared.add_request(0, prompt)))
    f1 = int(jnp.argmax(shared.add_request(1, prompt)))  # prefix share
    assert shared.tier_stats()["prefix_hits"] > 0
    out = shared.decode(gen - 1)
    assert shared.tier_stats()["cow_splits"] > 0
    np.testing.assert_array_equal([f0] + out[0], base)
    np.testing.assert_array_equal([f1] + out[1], base)


def test_pool_one_node_int8_matches_paged_server():
    """The shard_mapped fused-dequant path (LSE partials + scale-aware
    gather) on one node equals the PagedServer int8 path exactly."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(17)
    B, S, gen = 2, 7, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    _, base = _greedy(PagedServer(model, params, page_size=4,
                                  hbm_pages=32, dtype=jnp.float32,
                                  page_dtype="int8"), prompts, gen)
    pool = PoolServer(model, params, n_nodes=1, page_size=4,
                      hbm_pages_per_node=32, dtype=jnp.float32,
                      page_dtype="int8")
    _, got = _greedy(pool, prompts, gen)
    np.testing.assert_array_equal(got, base)


# ---------------------------------------------------------------------------
# quantized analytics extents: dequant-fold bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("page_dtype", QDTYPES)
def test_quantized_scan_bit_identical_to_host_fold(page_dtype):
    """The dequantizing in-storage reduce over quantized extent pages
    is bit-identical to reading the extent back (host-side dequant) and
    folding page-sequentially — same per-page fold order, same
    elementwise f32 dequant."""
    store = ExtentStore(n_pages=8, page_rows=16, n_cols=16,
                        page_dtype=page_dtype)
    rng = np.random.default_rng(19)
    data = rng.normal(size=(70, 12)).astype(np.float32) * 7
    ext = store.put("t", data)
    assert ext.nbytes < data.nbytes           # planner prices smaller reads
    dev = np.asarray(ops.scan_filter_reduce(
        store.pages, store.page_table("t"), ext.n_rows, 0.25,
        scales=store.scales, filter_col=1, filter_op="ge"))
    host = np.asarray(ops.scan_filter_reduce_host(
        jnp.asarray(np.pad(store.get("t"), ((0, 0), (0, 4)))), 0.25,
        page_rows=16, filter_col=1, filter_op="ge"))
    np.testing.assert_array_equal(dev, host)
