"""Unit tests for the DockerSSD core layer."""
import pytest

from repro.core import (DockerSSDNode, EtherONDriver, EthernetFrame,
                        ImageManifest, LambdaFS, LockHeld, MPUViolation,
                        PRIVATE_NS, SHARABLE_NS, StoragePool, TCPConn,
                        UPCALL_SLOTS, VirtualFW, make_blob, register_app)
from repro.core.ether_on import DockerSSDEndpoint, OPC_RECEIVE, OPC_TRANSMIT
from repro.core.virtual_fw import (IO_SYSCALLS, NETWORK_SYSCALLS,
                                   THREAD_SYSCALLS)


# ---------------------------------------------------------------------------
# Ether-oN
# ---------------------------------------------------------------------------


def _pair():
    drv = EtherONDriver("10.0.0.1")
    dev = DockerSSDEndpoint("10.0.0.2")
    drv.attach(dev)
    return drv, dev


def test_etheron_transmit_roundtrip():
    drv, dev = _pair()
    got = []
    dev.set_handler(lambda fr: got.append(fr.payload) or None)
    drv.transmit(EthernetFrame("10.0.0.1", "10.0.0.2", b"hello isp"))
    assert got == [b"hello isp"]
    assert drv.stats.tx_commands == 1
    assert drv.stats.pages_allocated >= 1


def test_etheron_upcall_and_repost():
    drv, dev = _pair()
    assert drv.outstanding_slots("10.0.0.2") == UPCALL_SLOTS
    dev.send_to_host(b"result", "10.0.0.1")
    assert drv.poll().payload == b"result"
    # slot was consumed and immediately re-posted
    assert drv.outstanding_slots("10.0.0.2") == UPCALL_SLOTS


def test_etheron_backpressure_burst():
    """A burst larger than the slot pool must still deliver in order."""
    drv, dev = _pair()
    payload = bytes(range(256)) * 40          # ~10KB -> 7 MTU frames
    dev.send_to_host(payload, "10.0.0.1")
    chunks = []
    while True:
        fr = drv.poll()
        if fr is None:
            break
        chunks.append(fr.payload)
    assert b"".join(chunks) == payload
    assert drv.outstanding_slots("10.0.0.2") == UPCALL_SLOTS


def test_etheron_page_alignment():
    drv, dev = _pair()
    dev.set_handler(lambda fr: None)
    before = drv.stats.pages_allocated
    drv.transmit(EthernetFrame("10.0.0.1", "10.0.0.2", b"x" * 5000))
    # 5018-byte wire frame -> 2 x 4KiB pages
    assert drv.stats.pages_allocated - before == 2


def test_etheron_vendor_opcodes():
    assert OPC_TRANSMIT == 0xE0 and OPC_RECEIVE == 0xE1


# ---------------------------------------------------------------------------
# λFS
# ---------------------------------------------------------------------------


def test_lambdafs_namespace_protection():
    fs = LambdaFS()
    fs.write("/images/blobs/x", b"blob", PRIVATE_NS)
    with pytest.raises(PermissionError):
        fs.read("/images/blobs/x", PRIVATE_NS, actor="host")
    fs.write("/data/in", b"payload", SHARABLE_NS, actor="host")
    assert fs.read("/data/in", SHARABLE_NS, actor="host") == b"payload"


def test_lambdafs_inode_lock_protocol():
    fs = LambdaFS()
    fs.write("/data/f", b"1", SHARABLE_NS)
    fs.host_open("/data/f")
    with pytest.raises(LockHeld):
        fs.container_bind("/data/f", "c1")
    fs.host_close("/data/f")
    fs.container_bind("/data/f", "c1")
    with pytest.raises(LockHeld):
        fs.host_open("/data/f")
    with pytest.raises(LockHeld):
        fs.container_bind("/data/f", "c2")
    fs.container_bind("/data/f", "c1")        # re-entrant for holder
    fs.container_release("/data/f", "c1")
    fs.host_open("/data/f")


def test_lambdafs_locks_not_persistent():
    fs = LambdaFS()
    fs.write("/data/f", b"1", SHARABLE_NS)
    fs.container_bind("/data/f", "c1")
    fs.power_failure()
    fs.host_open("/data/f")                   # lock cleared by crash


def test_lambdafs_path_walk_cache():
    fs = LambdaFS()
    fs.write("/a/b/c/d", b"x", PRIVATE_NS)
    walks_before = fs.stats.path_walks
    fs.read("/a/b/c/d", PRIVATE_NS)
    assert fs.stats.node_cache_hits > 0
    assert fs.stats.path_walks == walks_before


def test_lambdafs_capacity():
    fs = LambdaFS(capacity_bytes=10)
    with pytest.raises(Exception):
        fs.write("/big", b"x" * 100, PRIVATE_NS)


# ---------------------------------------------------------------------------
# Virtual-FW
# ---------------------------------------------------------------------------


def test_virtualfw_syscall_tables():
    assert len(THREAD_SYSCALLS) == 65
    assert len(IO_SYSCALLS) == 43
    assert len(NETWORK_SYSCALLS) == 25


def test_virtualfw_syscall_dispatch():
    fs = LambdaFS()
    fw = VirtualFW(fs)
    fd = fw.syscall("openat", "/tmp/x")
    fw.syscall("write", fd, b"data")
    assert fw.syscall("read", fd) == b"data"
    fw.syscall("close", fd)
    assert fw.syscall_counts["openat"] == 1
    # emulation cost is function-call scale
    assert fw.emulated_us < 1.0


def test_virtualfw_mpu_protection():
    fw = VirtualFW(LambdaFS())
    with pytest.raises(MPUViolation):
        fw.pools.fw_read(0)
    fw.pools.privileged = True
    assert fw.pools.fw_read(0) is not None
    fw.pools.privileged = False
    fw.pools.isp_write(1, b"args")            # ISP pool open in user mode
    assert fw.pools.isp_read(1) == b"args"


def test_tcp_fsm():
    c = TCPConn()
    c.event("passive_open")
    c.event("syn")
    c.event("ack")
    assert c.state == "ESTABLISHED"
    c.event("fin")
    c.event("close")
    c.event("ack")
    assert c.state == "CLOSED"
    with pytest.raises(ValueError):
        c.event("fin")


def test_virtualfw_footprint():
    fp = VirtualFW.binary_footprint()
    assert 80 < fp["reduction"] < 90          # Fig 10: ~83.4x


# ---------------------------------------------------------------------------
# mini-docker
# ---------------------------------------------------------------------------


@register_app("echo")
def _echo(ctx, value=41):
    ctx.log("running")
    ctx.syscall("brk")
    return value + 1


def _node():
    return DockerSSDNode("10.0.0.2")


def test_minidocker_lifecycle():
    node = _node()
    blob = make_blob(ImageManifest("img", "echo", ["base"]),
                     {"base": b"\x00"})
    node.docker.cmd_pull("img", blob)
    assert "img" in node.docker.images()
    cid = node.docker.cmd_create("img")
    out = node.docker.cmd_start(cid, value=1)
    assert out == 2
    assert b"exit code=0" in node.docker.cmd_logs(cid)
    ps = node.docker.cmd_ps()
    assert ps[0]["state"] == "exited"
    out2 = node.docker.cmd_restart(cid, value=10)
    assert out2 == 11
    node.docker.cmd_kill(cid)
    node.docker.cmd_rm(cid)
    assert node.docker.cmd_ps() == []
    node.docker.cmd_rmi("img")
    assert "img" not in node.docker.images()


def test_minidocker_cgroup_budget():
    @register_app("hog")
    def hog(ctx):
        ctx.alloc(2 << 30)

    node = _node()
    blob = make_blob(ImageManifest("hog", "hog", []), {})
    node.docker.cmd_pull("hog", blob)
    cid = node.docker.cmd_create("hog", mem_budget=1 << 20)
    with pytest.raises(MemoryError):
        node.docker.cmd_start(cid)
    assert node.docker.cmd_ps()[0]["state"] == "dead"


def test_minidocker_http_over_etheron():
    pool = StoragePool(1)
    ip = pool.alive_nodes()[0]
    pool.driver.transmit(EthernetFrame("10.0.0.1", ip,
                                       b"GET /containers/json"))
    assert pool.driver.poll().payload == b"[]"


# ---------------------------------------------------------------------------
# storage pool
# ---------------------------------------------------------------------------


def test_pool_failure_reschedule():
    pool = StoragePool(6)
    blob = make_blob(ImageManifest("img", "echo", []), {})
    pool.broadcast_pull("img", blob)
    pl = pool.place_distributed("job", "img", tp=4)
    victim = pl.node_ips[0]
    pool.nodes[victim].fail()
    dead = pool.check_heartbeats(now=1e9)
    assert victim in dead
    assert victim not in pool.placements["job"].node_ips
    assert len(pool.placements["job"].node_ips) == 4
    assert any(e[0] == "reschedule" for e in pool.events)


def test_pool_straggler_detection():
    pool = StoragePool(4)
    slow = pool.alive_nodes()[0]
    pool.nodes[slow].latency_ema_ms = 100.0
    assert pool.stragglers() == [slow]


def test_pool_elastic_scale():
    pool = StoragePool(2, array_size=4)
    pool.scale_to(5)
    assert len(pool.alive_nodes()) == 5
    # newly added nodes must be first-class members: λFS lock syncs ride
    # the pool driver and the array topology respects array_size
    for node in pool.nodes.values():
        assert node.fs._ether is pool.driver
    assert sum(len(a) for a in pool.arrays) == 5
    assert [len(a) for a in pool.arrays] == [4, 1]
    # placement across old + new nodes works (lock syncs don't break)
    pl = pool.place_distributed("job", "img", tp=5)
    assert len(pl.node_ips) == 5


def test_pool_pipeline_stages():
    pool = StoragePool(8)
    pl = pool.place_distributed("j", "img", tp=2, pp=4)
    stages = sorted(set(pl.stage_of.values()))
    assert stages == [0, 1, 2, 3]
