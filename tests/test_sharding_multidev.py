"""Multi-device semantics tests (subprocess with forced host devices):
the sharded train/decode steps must produce the same numbers as the
single-device reference, and the dry-run machinery must work on a small
mesh end-to-end."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# multi-device subprocess tests: minutes of wall clock
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import auto_axis_kwargs
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_arch
        from repro.models.api import get_model
        from repro.optim import adamw
        from repro.runtime import sharding as shd
        from repro.runtime.train import make_train_step
        from repro.data.pipeline import synthetic_stream

        cfg = get_arch("granite_3_2b").reduced()
        model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
        init_fn, upd_fn = adamw(lr=1e-3)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_fn(params)
        batch = {k: jnp.asarray(v) for k, v in synthetic_stream(
            0, 0, 0, batch=8, seq_len=32, vocab=cfg.vocab_size).items()}
        tstep = make_train_step(model, upd_fn)

        # single-device reference
        p_ref, _, m_ref = jax.jit(tstep)(params, opt, batch)
        ref = [np.asarray(x) for x in jax.tree.leaves(p_ref)]

        # sharded on a (2, 4) data x model mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             **auto_axis_kwargs(("data", "model")))
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              shd.param_specs(mesh, params))
        oshard = type(opt)(step=NamedSharding(mesh, P()),
                           m=pshard, v=pshard)
        bshard = shd.to_shardings(mesh, shd.batch_spec(mesh, batch))
        with mesh:
            tstep_sh = jax.jit(tstep, in_shardings=(pshard, oshard, bshard))
            p_sh, _, m_sh = tstep_sh(params, opt, batch)
        for a, b in zip(ref, jax.tree.leaves(p_sh)):
            np.testing.assert_allclose(a, np.asarray(b), atol=2e-5,
                                       rtol=2e-4)
        print("LOSS_MATCH", abs(float(m_ref["loss"]) - float(m_sh["loss"])))
    """)
    assert "LOSS_MATCH" in stdout
    assert float(stdout.strip().split()[-1]) < 1e-4


def test_sharded_decode_matches_single_device():
    """The D-Cache schedule (KV seq-sharded over `model`) must be
    numerically identical to unsharded decode."""
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import auto_axis_kwargs
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_arch
        from repro.models.api import get_model
        from repro.runtime import sharding as shd

        cfg = get_arch("granite_3_2b").reduced()
        model = get_model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 4, 64
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size, jnp.int32)
        _, cache = model.prefill(params, {"tokens": toks[:, :S//2]},
                                 cache_dtype=jnp.float32)
        pad = S - cache["k"].shape[-2]
        widths = [(0,0)]*3 + [(0,pad),(0,0)]
        cache = {**cache,
                 "k": jnp.pad(cache["k"], widths),
                 "v": jnp.pad(cache["v"], widths)}
        lg_ref, _ = jax.jit(model.decode_step)(params, cache, toks[:, S//2])

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             **auto_axis_kwargs(("data", "model")))
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              shd.param_specs(mesh, params))
        cshard = shd.to_shardings(
            mesh, shd.cache_spec_shardings(
                mesh, jax.tree.map(lambda a: jax.ShapeDtypeStruct(
                    a.shape, a.dtype), cache)))
        tshard = NamedSharding(mesh, shd.decode_token_spec(mesh, B))
        with mesh:
            step = jax.jit(model.decode_step,
                           in_shardings=(pshard, cshard, tshard))
            lg_sh, _ = step(params, cache, toks[:, S//2])
        err = float(np.abs(np.asarray(lg_ref) - np.asarray(lg_sh)).max())
        print("DECODE_ERR", err)
    """)
    assert float(stdout.strip().split()[-1]) < 1e-4


def test_small_mesh_dryrun_cell():
    """run_cell machinery on an artificial 8-device production mesh."""
    stdout = _run("""
        import jax, numpy as np, json
        import repro.launch.dryrun as dr
        import repro.launch.mesh as lm
        # shrink the production mesh for the 8-device test env
        lm.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
            (2, 2, 2) if multi_pod else (2, 4),
            ("pod", "data", "model") if multi_pod else ("data", "model"),
            **__import__("repro.launch.mesh", fromlist=["auto_axis_kwargs"]).auto_axis_kwargs(
                ("x",) * (3 if multi_pod else 2)))
        dr.make_production_mesh = lm.make_production_mesh
        import repro.configs.base as cb
        import dataclasses
        # reduced arch, reduced shape
        cfg = cb.get_arch("granite-3-2b").reduced()
        cb._REGISTRY["granite_3_2b"] = cfg
        cb.SHAPES["train_4k"] = cb.ShapeConfig("train_4k", 64, 8, "train")
        cb.SHAPES["decode_32k"] = cb.ShapeConfig("decode_32k", 64, 8, "decode")
        for shape in ("train_4k", "decode_32k"):
            for mesh in ("single", "multi"):
                rec = dr.run_cell("granite-3-2b", shape, mesh)
                assert rec["status"] == "ok", rec.get("error")
                print(shape, mesh, "OK",
                      rec["roofline"]["coll_bytes"] > 0)
    """)
    assert stdout.count("OK") == 4


def test_elastic_mesh_checkpoint_reshard(tmp_path):
    """Save under one mesh, restore under a degraded mesh."""
    stdout = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import auto_axis_kwargs
        from jax.sharding import NamedSharding
        from repro.checkpoint import CheckpointManager
        from repro.configs.base import get_arch
        from repro.launch.mesh import make_elastic_mesh
        from repro.models.api import get_model
        from repro.runtime import sharding as shd

        cfg = get_arch("granite_3_2b").reduced()
        model = get_model(cfg, compute_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        mesh8 = make_elastic_mesh(8, model_parallel=4)
        sh8 = jax.tree.map(lambda s: NamedSharding(mesh8, s),
                           shd.param_specs(mesh8, params))
        params8 = jax.device_put(params, sh8)
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(1, params8)
        # "node failure": restore under a 6-device mesh
        mesh6 = make_elastic_mesh(6, model_parallel=4)   # falls back 6=3x2
        specs6 = shd.param_specs(mesh6, params)
        restored = mgr.restore(params, mesh=mesh6, specs=specs6)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        print("RESHARD_OK", mesh6.shape)
    """)
    assert "RESHARD_OK" in stdout


def test_moe_shardmap_equals_dense_on_mesh():
    """shard_map MoE (EXPERIMENTS.md §Perf iter 3) == dense dispatch."""
    stdout = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import auto_axis_kwargs
        from jax.sharding import NamedSharding
        from repro.configs.base import get_arch
        from repro.models.api import get_model
        from repro.runtime import sharding as shd

        cfg = get_arch("phi3_5_moe_42b_a6_6b").reduced()   # 4 experts
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             **auto_axis_kwargs(("data", "model")))
        m_d = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
        m_s = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True,
                        moe_impl="shardmap")
        p = m_d.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size, jnp.int32)
        ref, _ = m_d.forward(p, {"tokens": toks})
        pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                              shd.param_specs(mesh, p))
        with mesh:
            f = jax.jit(lambda pp, b: m_s.forward(pp, b)[0],
                        in_shardings=(pshard, None))
            got = f(p, {"tokens": toks})
            g = jax.jit(jax.grad(lambda pp: m_s.loss(
                pp, {"tokens": toks, "labels": toks})[0]),
                in_shardings=(pshard,))(p)
        err = float(np.abs(np.asarray(ref) - np.asarray(got)).max())
        gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("MOE_ERR", err)
    """)
    assert float(stdout.strip().split()[-1]) < 2e-4
