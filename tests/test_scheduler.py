"""Continuous batching over the tiered PagedServer: outputs must match
isolated (one-request-at-a-time) serving, pages must be reclaimed via
the public free_sequence API, and admission must respect the HBM
window."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.scheduler import ContinuousBatcher, Request
from repro.runtime.serve import PagedServer


def _tiny():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _isolated_reference(model, params, prompt, gen):
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=64, dtype=jnp.float32)
    last = server.add_request(0, prompt)
    out = [int(jnp.argmax(last))]
    out += server.decode(gen - 1, seqs=[0])[0]
    return out


def test_continuous_batching_matches_isolated():
    cfg, model, params = _tiny()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(4)]
    gens = [3, 5, 2, 4]
    refs = [_isolated_reference(model, params, p, g)
            for p, g in zip(prompts, gens)]

    server = PagedServer(model, params, page_size=4,
                         hbm_pages=10, dtype=jnp.float32)
    sched = ContinuousBatcher(server, max_active=2)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        sched.submit(Request(rid=i, prompt=p, max_tokens=g))
    stats = sched.run_to_completion()
    assert stats["requests"] == 4
    by_id = {r.rid: r.output for r in sched.finished}
    for i, ref in enumerate(refs):
        assert by_id[i][:len(ref)] == ref, (i, by_id[i], ref)


def test_pages_reclaimed_after_completion():
    cfg, model, params = _tiny()
    rng = np.random.default_rng(1)
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=8, dtype=jnp.float32)
    sched = ContinuousBatcher(server, max_active=1)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 5, dtype=np.int32), max_tokens=3))
    stats = sched.run_to_completion()
    assert stats["requests"] == 3
    # all pages are free again, in both tiers
    assert server.table.free_pages == server.hbm_pages
    assert server.table.resident_pages == 0
    assert server.table.host_pages == 0
    assert server.sequence_ids() == []


def test_admission_respects_window():
    cfg, model, params = _tiny()
    rng = np.random.default_rng(2)
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=4, dtype=jnp.float32)
    sched = ContinuousBatcher(server, max_active=4)
    # each request needs 3 pages; window holds one at a time
    for i in range(2):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=4))
    sched.step()
    assert len(sched.active) <= 1          # second request had to wait
    stats = sched.run_to_completion()
    assert stats["requests"] == 2


def test_retired_slot_reused_by_waiting_request():
    """A retired rid frees its pages immediately and the next waiting
    request takes the physical slots within the same scheduler loop."""
    cfg, model, params = _tiny()
    rng = np.random.default_rng(3)
    # window fits exactly one request's working set (3 pages of 4 toks)
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=3, dtype=jnp.float32)
    sched = ContinuousBatcher(server, max_active=2)
    for i in range(2):
        sched.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=4))
    sched.step()
    assert list(sched.active) == [0]       # rid 1 waits on the window
    stats = sched.run_to_completion()
    assert stats["requests"] == 2
    finished_order = [r.rid for r in sched.finished]
    assert finished_order == [0, 1]        # slot handed over after retire
    assert server.table.free_pages == server.hbm_pages
