"""Pool-sharded serving tests.

Fast lane (single device): per-shard page accounting in
PageTableManager, the NodeSpec aliasing fix, the 1-node PoolServer vs
PagedServer equivalence (the shard_map path itself), and the frontend
control-plane wiring.  Slow lane (subprocess with forced host devices):
multi-node decode equivalence to 1e-4, mid-decode failover, and the
aggregate-equals-sum-of-nodes telemetry invariant on a real pool run.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.kv_tier import PageStore, PageTableManager
from repro.core.storage_pool import DockerSSDNode, NodeSpec, StoragePool
from repro.models.api import get_model
from repro.runtime.pool import PoolServer
from repro.runtime.scheduler import PoolRouter, Request
from repro.runtime.serve import PagedServer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# satellites: NodeSpec aliasing, sharded page accounting
# ---------------------------------------------------------------------------


def test_nodespec_default_not_shared():
    """Every node must own its spec: mutating one node's spec (e.g. a
    degraded channel count) cannot leak into the rest of the pool."""
    a = DockerSSDNode("10.0.1.2")
    b = DockerSSDNode("10.0.1.3")
    a.spec.channels = 1
    assert b.spec.channels == NodeSpec().channels
    pool = StoragePool(3)
    ips = list(pool.nodes)
    pool.nodes[ips[0]].spec.channels = 2
    assert pool.nodes[ips[1]].spec.channels == NodeSpec().channels
    # an explicitly passed spec is copied per node, not aliased
    pool2 = StoragePool(2, spec=NodeSpec(channels=7))
    n0, n1 = pool2.nodes.values()
    assert n0.spec is not n1.spec and n0.spec.channels == 7
    pool2.scale_to(3)
    assert list(pool2.nodes.values())[2].spec.channels == NodeSpec().channels


def _store(hbm_pages, n_layers=2, page=4):
    return PageStore(n_layers=n_layers, page_size=page, hbm_pages=hbm_pages,
                     n_kv_heads=2, head_dim=8, dtype=jnp.float32)


def test_sharded_alloc_stays_in_shard():
    """Striped placement: logical page i of a sequence lands in shard
    i % n_shards, and every physical id falls inside its shard's
    contiguous window."""
    t = PageTableManager(_store(16), n_shards=4)
    t.add_sequence(0)
    phys = t.ensure_resident(0, n_tokens=5 * 4)     # 5 logical pages
    for pi, p in enumerate(phys):
        assert t.shard_of_phys(p) == pi % 4
    assert t.free_pages == 11
    assert t.shard_free_pages(0) == 2               # pages 0 and 4 placed
    assert t.free_sequence(0) == 5
    assert t.free_pages == 16


def test_shard_eviction_is_local_and_counted_per_shard():
    """Eviction never crosses a node boundary (each DockerSSD tiers
    against its own flash) and every counter lands on the right shard —
    the pool aggregate is the field-wise sum of the nodes."""
    placement = {}
    t = PageTableManager(_store(8), n_shards=2,
                         shard_of=lambda seq, pi: placement[seq])
    for s in range(4):
        placement[s] = s % 2
        t.add_sequence(s)
    # fill both 4-page windows, then overflow shard 0 only
    for s in (0, 1):
        t.ensure_resident(s, n_tokens=16)           # 4 pages each
    t.ensure_resident(2, n_tokens=8)                # 2 pages in shard 0
    assert t.stats.page_outs == 2
    assert [ss.page_outs for ss in t.shard_stats] == [2, 0]
    # the spilled pages belong to shard 0's host tier
    assert all(placement[k[0]] == 0 for k in t._host)
    # paging seq 0 back in evicts within shard 0; shard 1 untouched
    t.ensure_resident(0, n_tokens=16)
    assert t.shard_stats[1].page_outs == t.shard_stats[1].page_ins == 0
    agg = vars(t.stats)
    per = [vars(ss) for ss in t.shard_stats]
    assert all(agg[k] == sum(p[k] for p in per) for k in agg)


def test_dead_shard_rejects_allocation():
    t = PageTableManager(_store(8), n_shards=2)
    t.add_sequence(0)
    t.ensure_resident(0, n_tokens=8)                # pages on both shards
    assert t.sequences_on_shard(1) == {0}
    t.disable_shard(1)
    t.add_sequence(1)
    with pytest.raises(RuntimeError, match="dead"):
        t.ensure_resident(1, n_tokens=8)            # page 1 -> shard 1


# ---------------------------------------------------------------------------
# PoolServer on one device: the shard_map path itself
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["placed", "striped"])
def test_pool_server_one_node_matches_paged(policy):
    """A 1-node pool must reproduce PagedServer exactly: same prefill
    logits (1e-4), same greedy tokens — the ownership masking and the
    LSE partial merge are the identity on one shard."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    B, S, gen = 3, 9, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    ref = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=32, dtype=jnp.float32,
                     policy=policy)
    for i in range(B):
        la = ref.add_request(i, prompts[i])
        lb = srv.add_request(i, prompts[i])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-4)
    assert ref.decode(gen) == srv.decode(gen)
    agg = srv.tier_stats()
    per = srv.node_tier_stats()
    assert len(per) == 1
    assert all(agg[k] == per[0][k] for k in per[0])


def test_pool_router_frontend_control_plane():
    """End-to-end on one node: requests flow frontend -> Ether-oN frame
    -> placement -> sharded decode; place/free control frames are
    cost-accounted and logged at the node."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(3)]
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=32, dtype=jnp.float32)
    pool = StoragePool(1)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=2)
    for i, p in enumerate(prompts):
        router.submit(Request(rid=i, prompt=p, max_tokens=3))
    stats = router.run_to_completion()
    assert stats["requests"] == 3
    node = pool.nodes[pool.serving_ips()[0]]
    places = [e for e in node.serving_log if e[0] == "place"]
    frees = [e for e in node.serving_log if e[0] == "free"]
    assert len(places) == 3 and len(frees) == 3
    assert pool.driver.stats.control_frames == 6
    assert srv.table.free_pages == srv.hbm_pages     # everything reclaimed


def test_pool_server_eviction_under_pressure():
    """Per-node window smaller than the working set: the pool path must
    stay correct while pages spill to the node's flash tier."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(2)
    B, S, gen = 2, 7, 4
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    ref = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32)
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=4, dtype=jnp.float32)
    for i in range(B):
        la = ref.add_request(i, prompts[i])
        lb = srv.add_request(i, prompts[i])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-4)
    o_ref1 = ref.decode(gen, seqs=[1])
    o_srv1 = srv.decode(gen, seqs=[1])               # seq 0 spills
    o_ref0 = ref.decode(gen, seqs=[0])
    o_srv0 = srv.decode(gen, seqs=[0])               # seq 0 pages back in
    assert o_ref1 == o_srv1 and o_ref0 == o_srv0
    assert srv.tier_stats()["page_outs"] > 0
    assert srv.tier_stats()["page_ins"] > 0


def test_striped_pool_fails_fast_on_node_loss():
    """A striped extent spans every node, so a node failure cannot be
    failed over: the router must raise a clear error instead of
    requeueing work that can never re-admit."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(4)
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=16, dtype=jnp.float32,
                     policy="striped")
    pool = StoragePool(1, heartbeat_timeout=0.0)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=2)
    router.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=4))
    router.step()
    pool.nodes[pool.serving_ips()[0]].fail()
    with pytest.raises(RuntimeError, match="striped pool lost node"):
        router.run_to_completion()


# ---------------------------------------------------------------------------
# multi-node semantics (subprocess with forced host devices)
# ---------------------------------------------------------------------------

def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_SETUP = """
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request
    from repro.runtime.serve import PagedServer

    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(5)]
    gens = [4, 6, 3, 5, 4]

    ref = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32)
    ref_logits = [np.asarray(ref.add_request(i, p))
                  for i, p in enumerate(prompts)]
    ref_out = {i: [int(np.argmax(l))] for i, l in enumerate(ref_logits)}
    for i, toks in ref.decode(max(gens) - 1).items():
        ref_out[i] += toks
    ref_out = {i: o[:g] for (i, o), g in zip(ref_out.items(), gens)}
"""


@pytest.mark.slow
def test_multinode_decode_matches_single_node():
    """4-node pool, both placement policies: prefill logits within 1e-4
    of the 1-node PagedServer and identical greedy decode."""
    stdout = _run(_SETUP + """
    for policy in ("placed", "striped"):
        srv = PoolServer(model, params, n_nodes=4, page_size=4,
                         hbm_pages_per_node=8, dtype=jnp.float32,
                         policy=policy)
        for i, p in enumerate(prompts):
            lb = np.asarray(srv.add_request(i, p))
            assert np.max(np.abs(lb - ref_logits[i])) < 1e-4, policy
        out = srv.decode(max(gens))
        for i, g in enumerate(gens):
            assert out[i][:g - 1] == ref_out[i][1:], (policy, i)
        if policy == "placed":
            assert len({srv.node_of(i) for i in range(5)}) > 1
    print("MULTINODE_OK")
    """)
    assert "MULTINODE_OK" in stdout


@pytest.mark.slow
def test_failover_requeues_and_completes():
    """Kill a node mid-decode: its sequences requeue through the router,
    finish on the survivors, and the final outputs equal the
    uninterrupted single-node run."""
    stdout = _run(_SETUP + """
    srv = PoolServer(model, params, n_nodes=4, page_size=4,
                     hbm_pages_per_node=8, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=0.0)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        router.submit(Request(rid=i, prompt=p, max_tokens=g))
    router.step(); router.step()
    victim = srv.node_of(0)
    pool.nodes[pool.serving_ips()[victim]].fail()
    router.run_to_completion()
    assert router.requeues >= 1
    assert victim not in srv.alive_nodes()
    assert any(e[0] == "serve-requeue" for e in pool.events)
    by_id = {r.rid: r.output for r in router.finished}
    for i, g in enumerate(gens):
        assert by_id[i] == ref_out[i], (i, by_id[i], ref_out[i])
    print("FAILOVER_OK")
    """)
    assert "FAILOVER_OK" in stdout


@pytest.mark.slow
def test_aggregate_tier_stats_is_sum_of_nodes():
    """On a real multi-node run with spill pressure, the pool aggregate
    telemetry equals the field-wise sum of the per-node stats."""
    stdout = _run(_SETUP + """
    srv = PoolServer(model, params, n_nodes=2, page_size=4,
                     hbm_pages_per_node=4, dtype=jnp.float32)
    pool = StoragePool(2)
    pool.attach_server(srv)
    # two sequences per node-sized window: decoding one at a time forces
    # per-node eviction traffic
    for i, p in enumerate(prompts[:4]):
        node = pool.place_sequence(i, 6 + 4)
        srv.add_request(i, p, node=node)
    for i in range(4):
        srv.decode(3, seqs=[i])
    agg = srv.tier_stats()
    per = srv.node_tier_stats()
    assert agg["page_outs"] > 0
    assert all(agg[k] == sum(p[k] for p in per) for k in per[0]), \\
        (agg, per)
    served = pool.serving_tier_stats()
    assert served["pool"] == agg and served["nodes"] == per
    print("STATS_OK")
    """)
    assert "STATS_OK" in stdout