"""Pallas kernel sweeps: assert_allclose against the pure-jnp oracles
(interpret=True on CPU; native compile on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 128),       # MQA
    (2, 4, 4, 384, 64),        # MHA
    (1, 2, 1, 512, 256),       # gemma-style wide heads
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, hkv, s, d, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,h,hkv,d,page,pps,npage", [
    (2, 8, 2, 64, 16, 8, 32),
    (4, 4, 4, 128, 32, 4, 16),
    (2, 8, 1, 64, 16, 6, 12),   # MQA
    (1, 16, 8, 128, 8, 16, 16),
])
def test_paged_attention(b, h, hkv, d, page, pps, npage):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (npage, page, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (npage, page, hkv, d), jnp.float32)
    pt = jax.random.permutation(ks[3], npage)[:b * pps].reshape(
        b, pps).astype(jnp.int32)
    lens = jax.random.randint(ks[4], (b,), 1, pps * page + 1, jnp.int32)
    out = ops.paged_attention(q, kp, vp, pt, lens)
    expect = ref.paged_attention_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_length_masking():
    """Tokens beyond `lengths` must not affect the output."""
    ks = jax.random.split(KEY, 4)
    b, h, hkv, d, page, pps, npage = 2, 4, 2, 64, 16, 4, 8
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (npage, page, hkv, d))
    vp = jax.random.normal(ks[2], (npage, page, hkv, d))
    pt = jnp.arange(b * pps, dtype=jnp.int32).reshape(b, pps)
    lens = jnp.asarray([17, 33], jnp.int32)
    out1 = ops.paged_attention(q, kp, vp, pt, lens)
    kp2 = kp.at[pt[0, 2]].set(999.0)  # beyond length of seq 0
    out2 = ops.paged_attention(q, kp2, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]),
                               atol=1e-6)


@pytest.mark.parametrize("v,d,b,l", [(1000, 128, 4, 16), (512, 256, 2, 8),
                                     (64, 512, 8, 4)])
@pytest.mark.parametrize("weighted", [False, True])
def test_embed_agg(v, d, b, l, weighted):
    ks = jax.random.split(KEY, 3)
    table = jax.random.normal(ks[0], (v, d))
    idx = jax.random.randint(ks[1], (b, l), 0, v, jnp.int32)
    w = jax.random.normal(ks[2], (b, l)) if weighted else None
    out = ops.embed_agg(table, idx, w)
    expect = ref.embed_agg_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (2, 64, 3, 16, 16, 16),
    (1, 128, 2, 32, 32, 32),
    (2, 96, 1, 64, 64, 32),
])
def test_rwkv_scan(b, s, h, dk, dv, chunk):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk))
    s0 = jax.random.normal(ks[5], (b, h, dk, dv))
    o, sT = ops.rwkv_scan(r, k, v, logw, u, s0, chunk=chunk)
    o_r, sT_r = ref.wkv_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_r),
                               atol=2e-4, rtol=2e-3)


def test_rwkv_scan_matches_model_chunked():
    """The Pallas kernel and the model's jnp chunked form agree."""
    from repro.models.rwkv6 import wkv_chunked
    ks = jax.random.split(KEY, 6)
    b, s, h, dk = 2, 64, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dk))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk))
    s0 = jax.random.normal(ks[5], (b, h, dk, dk))
    o1, s1 = ops.rwkv_scan(r, k, v, logw, u, s0, chunk=16)
    o2, s2 = wkv_chunked(r, k, v, logw, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.parametrize("b,h,hkv,d,page,pps,npage", [
    (2, 8, 2, 64, 16, 8, 32),
    (4, 4, 4, 128, 32, 4, 16),
])
def test_paged_attention_q8(b, h, hkv, d, page, pps, npage):
    """int8-KV paged kernel (the §Perf opt-2 realization): matches its
    dequantize-then-attend oracle exactly, and the fp kernel closely."""
    from repro.models.layers import quantize_kv
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp_f = jax.random.normal(ks[1], (npage, page, hkv, d), jnp.float32)
    vp_f = jax.random.normal(ks[2], (npage, page, hkv, d), jnp.float32)
    kq, ksc = quantize_kv(kp_f)
    vq, vsc = quantize_kv(vp_f)
    pt = jax.random.permutation(ks[3], npage)[:b * pps].reshape(
        b, pps).astype(jnp.int32)
    lens = jax.random.randint(ks[4], (b,), 1, pps * page + 1, jnp.int32)
    out = ops.paged_attention_q8(q, kq, vq, ksc, vsc, pt, lens)
    oracle = ref.paged_attention_q8_ref(q, kq, vq, ksc, vsc, pt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=2e-5, rtol=2e-5)
    fp = ref.paged_attention_ref(q, kp_f, vp_f, pt, lens)
    assert float(jnp.abs(out - fp).max()) < 0.05   # quantization noise only
