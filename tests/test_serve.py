"""Serving path tests: PagedServer (tiered KV + Pallas paged_attention)
must produce the same logits as the dense decode path, including under
HBM-window eviction pressure."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.serve import PagedServer, make_serving_fns


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _dense_reference(model, params, prompts, gen):
    """Dense decode path: prefill + argmax generation."""
    B, S = prompts.shape
    total = S + gen
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)},
                                  cache_dtype=jnp.float32)
    pad = total - cache["k"].shape[-2]
    widths = [(0, 0)] * 3 + [(0, pad), (0, 0)]
    cache["k"] = jnp.pad(cache["k"], widths)
    cache["v"] = jnp.pad(cache["v"], widths)
    outs = []
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(gen):
        outs.append(np.asarray(cur))
        logits, cache = model.decode_step(params, cache, cur)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(outs, axis=1)       # [B, gen]


@pytest.mark.parametrize("hbm_pages", [64, 6])   # 6 = exactly the batch
def test_paged_server_matches_dense(hbm_pages):
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    B, S, gen = 2, 7, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)

    ref_tokens = _dense_reference(model, params, prompts, gen)

    server = PagedServer(model, params, page_size=4,
                         hbm_pages_per_layer=hbm_pages, dtype=jnp.float32)
    lasts = [server.add_request(i, prompts[i]) for i in range(B)]
    first = np.asarray([int(jnp.argmax(l)) for l in lasts])
    np.testing.assert_array_equal(first, ref_tokens[:, 0])
    out = server.decode(gen - 1)
    got = np.concatenate([first[:, None],
                          np.asarray([out[i] for i in range(B)])], axis=1)
    np.testing.assert_array_equal(got, ref_tokens)


def test_paged_server_eviction_correct():
    """HBM window smaller than the total working set: idle sequences
    spill to the flash tier and page back in with identical output."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    B, S, gen = 2, 7, 4
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    ref_tokens = _dense_reference(model, params, prompts, gen)

    # 4 pages < 2 seqs x 3 pages: serving B evicts A's pages
    server = PagedServer(model, params, page_size=4,
                         hbm_pages_per_layer=4, dtype=jnp.float32)
    first = []
    for i in range(B):
        first.append(int(jnp.argmax(server.add_request(i, prompts[i]))))
    np.testing.assert_array_equal(np.asarray(first), ref_tokens[:, 0])
    out1 = server.decode(gen - 1, seqs=[1])      # seq 0 spills
    out0 = server.decode(gen - 1, seqs=[0])      # seq 0 pages back in
    got = np.stack([[first[0]] + out0[0], [first[1]] + out1[1]])
    np.testing.assert_array_equal(got, ref_tokens)
    stats = server.tier_stats()
    assert stats["page_outs"] > 0
    assert stats["page_ins"] > 0


def test_make_serving_fns_runs():
    cfg, model, params = _tiny_model()
    prefill, decode = make_serving_fns(model)
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, cache = prefill(params, {"tokens": toks})
    assert logits.shape == (2, cfg.vocab_size)
    lg, cache = decode(params, cache, jnp.argmax(logits, -1).astype(jnp.int32))
    assert lg.shape == (2, cfg.vocab_size)
    assert int(cache["index"]) == 9
