"""Serving path tests: PagedServer (tiered KV + Pallas paged_attention,
one jitted decode step per token) must produce the same logits as the
dense decode path, including under HBM-window eviction pressure."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.serve import PagedServer, make_serving_fns


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _dense_reference(model, params, prompts, gen):
    """Dense decode path: prefill + argmax generation."""
    B, S = prompts.shape
    total = S + gen
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)},
                                  cache_dtype=jnp.float32)
    pad = total - cache["k"].shape[-2]
    widths = [(0, 0)] * 3 + [(0, pad), (0, 0)]
    cache["k"] = jnp.pad(cache["k"], widths)
    cache["v"] = jnp.pad(cache["v"], widths)
    outs = []
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(gen):
        outs.append(np.asarray(cur))
        logits, cache = model.decode_step(params, cache, cur)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(outs, axis=1)       # [B, gen]


@pytest.mark.parametrize("hbm_pages", [64, 6])   # 6 = exactly the batch
def test_paged_server_matches_dense(hbm_pages):
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    B, S, gen = 2, 7, 5
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)

    ref_tokens = _dense_reference(model, params, prompts, gen)

    server = PagedServer(model, params, page_size=4,
                         hbm_pages=hbm_pages, dtype=jnp.float32)
    lasts = [server.add_request(i, prompts[i]) for i in range(B)]
    first = np.asarray([int(jnp.argmax(l)) for l in lasts])
    np.testing.assert_array_equal(first, ref_tokens[:, 0])
    out = server.decode(gen - 1)
    got = np.concatenate([first[:, None],
                          np.asarray([out[i] for i in range(B)])], axis=1)
    np.testing.assert_array_equal(got, ref_tokens)


def test_paged_server_eviction_correct():
    """HBM window smaller than the total working set: idle sequences
    spill to the flash tier and page back in with identical output."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    B, S, gen = 2, 7, 4
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    ref_tokens = _dense_reference(model, params, prompts, gen)

    # 4 pages < 2 seqs x 3 pages: serving B evicts A's pages
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=4, dtype=jnp.float32)
    first = []
    for i in range(B):
        first.append(int(jnp.argmax(server.add_request(i, prompts[i]))))
    np.testing.assert_array_equal(np.asarray(first), ref_tokens[:, 0])
    out1 = server.decode(gen - 1, seqs=[1])      # seq 0 spills
    out0 = server.decode(gen - 1, seqs=[0])      # seq 0 pages back in
    got = np.stack([[first[0]] + out0[0], [first[1]] + out1[1]])
    np.testing.assert_array_equal(got, ref_tokens)
    stats = server.tier_stats()
    assert stats["page_outs"] > 0
    assert stats["page_ins"] > 0


def test_decode_step_matches_reference_loop():
    """The single jitted decode_step must reproduce the per-layer Python
    loop (seed schedule) to within 1e-4 on raw logits."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(3)
    B, S = 3, 9
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
    server = PagedServer(model, params, page_size=4,
                         hbm_pages=32, dtype=jnp.float32)
    for i in range(B):
        server.add_request(i, prompts[i])
    for _ in range(3):                   # several steps, growing context
        toks = {i: server._pending[i] for i in range(B)}
        ref = np.asarray(server.step_reference(toks))   # no commit
        got = server.step(toks)                         # commits
        got = np.stack([np.asarray(got[i]) for i in range(B)])
        np.testing.assert_allclose(got, ref, atol=1e-4)
        server._pending = {i: int(np.argmax(got[i])) for i in range(B)}


def test_prefill_then_decode_equals_prefill_as_decode():
    """One-shot page-writing prefill must be equivalent to teacher-forcing
    the prompt token-by-token through the jitted decode step."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(7)
    S, gen = 9, 4
    prompt = rng.integers(0, cfg.vocab_size, S, dtype=np.int32)

    a = PagedServer(model, params, page_size=4, hbm_pages=16,
                    dtype=jnp.float32)
    last_a = a.add_request(0, prompt)               # one-shot prefill
    out_a = [int(jnp.argmax(last_a))] + a.decode(gen, seqs=[0])[0]

    b = PagedServer(model, params, page_size=4, hbm_pages=16,
                    dtype=jnp.float32)
    last_b = b.add_request(0, prompt[:1])           # 1-token prefill...
    for t in prompt[1:]:                            # ...then teacher-force
        last_b = b.step({0: int(t)})[0]
    np.testing.assert_allclose(np.asarray(last_a), np.asarray(last_b),
                               atol=1e-4)
    b._pending[0] = int(jnp.argmax(last_b))
    out_b = [int(jnp.argmax(last_b))] + b.decode(gen, seqs=[0])[0]
    assert out_a == out_b


def test_batch_shape_bucketing_reuses_compilation():
    """Decode shapes are bucketed to powers of two: batches of 3 and 4
    share one compiled step, so continuous batching does not retrace per
    batch-size fluctuation."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(1)
    server = PagedServer(model, params, page_size=4, hbm_pages=32,
                         dtype=jnp.float32)
    if not hasattr(server._decode_jit, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")
    for i in range(4):
        server.add_request(i, rng.integers(0, cfg.vocab_size, 5,
                                           dtype=np.int32))
    server.decode(1, seqs=[0, 1, 2])
    sig0 = server._decode_jit._cache_size()
    server.decode(1, seqs=[0, 1, 2, 3])    # same pow2 bucket (4)
    assert server._decode_jit._cache_size() == sig0
    server.decode(1, seqs=[0])             # bucket 1 -> one new trace
    assert server._decode_jit._cache_size() == sig0 + 1


def test_free_sequence_reclaims_both_tiers():
    """free_sequence must return every page in HBM *and* the host tier."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(2)
    server = PagedServer(model, params, page_size=4, hbm_pages=4,
                         dtype=jnp.float32)
    p0 = rng.integers(0, cfg.vocab_size, 10, dtype=np.int32)  # 3 pages
    p1 = rng.integers(0, cfg.vocab_size, 10, dtype=np.int32)
    server.add_request(0, p0)
    server.add_request(1, p1)                 # evicts part of seq 0
    assert server.table.host_pages > 0        # seq 0 spilled
    freed = server.free_sequence(0)
    assert freed == 3                         # HBM + host pages combined
    assert all(k[0] != 0 for k in server.table._resident)
    assert all(k[0] != 0 for k in server.table._host)
    # remaining sequence still decodes fine
    server.decode(2, seqs=[1])


def test_failed_donated_step_recovers_store():
    """On accelerators the jitted step donates the store arrays; if the
    call fails mid-execution the old buffers are gone.  The server must
    reopen an empty window (sequences dropped, later requests fine)
    instead of poisoning every later step with deleted arrays."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(5)
    server = PagedServer(model, params, page_size=4, hbm_pages=16,
                         dtype=jnp.float32)
    server.add_request(0, rng.integers(0, cfg.vocab_size, 6, dtype=np.int32))

    def failing_jit(*a, **k):
        # emulate a donated call dying mid-execution: inputs consumed
        server.store.k_pages.delete()
        server.store.v_pages.delete()
        raise RuntimeError("RESOURCE_EXHAUSTED")

    orig = server._decode_jit
    server._decode_jit = failing_jit
    with pytest.raises(RuntimeError):
        server.step({0: 1})
    server._decode_jit = orig
    assert server.sequence_ids() == []            # cache declared lost
    assert server.table.free_pages == server.hbm_pages
    # the server stays serviceable
    server.add_request(1, rng.integers(0, cfg.vocab_size, 6, dtype=np.int32))
    assert len(server.decode(2, seqs=[1])[1]) == 2


def test_make_serving_fns_runs():
    cfg, model, params = _tiny_model()
    prefill, decode = make_serving_fns(model)
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, cache = prefill(params, {"tokens": toks})
    assert logits.shape == (2, cfg.vocab_size)
    lg, cache = decode(params, cache, jnp.argmax(logits, -1).astype(jnp.int32))
    assert lg.shape == (2, cfg.vocab_size)
    assert int(cache["index"]) == 9
