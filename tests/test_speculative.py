"""Speculative draft-verify decoding tests.

The contract: ``decode(horizon=H, speculative=True)`` — n-gram drafts
verified in one chunk-shaped pass, on-device acceptance, partial
``commit_horizon`` — must produce greedy outputs token-for-token
identical to the non-speculative paths at every acceptance rate (the
drafter never changes *what* is emitted, only how many passes it
takes), leave the page table exactly where the plain horizon leaves
it, keep the no-retrace guarantee across accepted-length variance, and
derive bit-identical samples on every pool shard.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.pool import PoolServer
from repro.runtime.scheduler import ContinuousBatcher, Request
from repro.runtime.serve import (GREEDY, PagedServer, SamplingConfig,
                                 draft_ngram, sampling_log_probs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(model, params, prompts, **kw):
    srv = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32, **kw)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    return srv


# repetitive prompts: a constant stream is the drafter's best case
# (the history's suffix recurs everywhere with full runway), so greedy
# decode accepts near-everything — the alpha~1 regime
def _const_prompts(n=3, length=12):
    return [np.full(length + i, c, np.int32)
            for i, c in enumerate((5, 9, 13)[:n])]


# ---------------------------------------------------------------------------
# drafter unit level
# ---------------------------------------------------------------------------


def test_draft_ngram_copies_matched_successors():
    # history 1 2 3 1 2 3 1 2 3 | suffix ..1 2 3 matches at site 5
    # (runway 3) and site 2 (runway 6) — runway-first scoring picks the
    # earlier site and drafts the continuation 1 2 3 1 ...
    hist = jnp.asarray([[1, 2, 3, 1, 2, 3, 1, 2, 3, -1, -1, -1]],
                       jnp.int32)
    d = np.asarray(draft_ngram(hist, jnp.asarray([9], jnp.int32), 4))
    assert d.tolist() == [[1, 2, 3, 1]]


def test_draft_ngram_requires_min_match():
    # final trigram (7 8 9) appears nowhere earlier: no draft, even
    # though the final bigram-of-one (9) recurs
    hist = jnp.asarray([[9, 1, 2, 9, 5, 7, 8, 9]], jnp.int32)
    d = np.asarray(draft_ngram(hist, jnp.asarray([8], jnp.int32), 3))
    assert (d == -1).all()


def test_draft_ngram_short_history_is_silent():
    hist = jnp.asarray([[4, 4, -1, -1]], jnp.int32)
    d = np.asarray(draft_ngram(hist, jnp.asarray([2], jnp.int32), 3))
    assert (d == -1).all()


def test_sampling_log_probs_top_p_masks_tail():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    lp = np.asarray(sampling_log_probs(logits, jnp.float32(1.0),
                                       jnp.float32(0.6)))
    p = np.exp(lp[0])
    # nucleus keeps 0.5 and the 0.3 that crosses the 0.6 mass line;
    # the 0.15/0.05 tail is masked and the survivors renormalize
    assert p[2] < 1e-6 and p[3] < 1e-6
    np.testing.assert_allclose(p[:2], [0.625, 0.375], atol=1e-5)


# ---------------------------------------------------------------------------
# greedy token identity at every acceptance rate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("regime", ["alpha0", "partial", "alpha1"])
def test_spec_greedy_identity(regime):
    """Speculative greedy decode must emit token-for-token what the
    per-token (H=1) and fused (H=8) paths emit, whether drafts never
    land (random text), partially land, or nearly always land
    (constant stream)."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompts = {
        "alpha0": [rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
                   for _ in range(3)],
        "partial": [rng.integers(0, cfg.vocab_size, 9, dtype=np.int32),
                    np.full(12, 5, np.int32),
                    np.full(13, 9, np.int32)],
        "alpha1": _const_prompts(),
    }[regime]
    gen = 24

    def run(**kw):
        return _serve(model, params, prompts).decode(gen, **kw)

    ref = run(horizon=1)
    assert run(horizon=8) == ref
    srv = _serve(model, params, prompts)
    assert srv.decode(gen, horizon=8, speculative=True) == ref
    st = srv.speculation_stats()
    if regime == "alpha1":
        assert st["alpha"] > 0.7 and st["accepted"] > gen


def test_spec_eos_and_budgets_match_non_spec():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    probe = _serve(model, params, prompts)
    eos = int(probe.decode(8)[0][3])
    budgets = {0: 3, 1: 8, 2: 6}

    def run(spec):
        srv = _serve(model, params, prompts)
        out = srv.decode(8, horizon=8, eos_id=eos, budgets=budgets,
                         speculative=spec)
        return out, {s: srv.table.length(s) for s in (0, 1, 2)}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# partial commit: rejected drafts leave no trace in the page table
# ---------------------------------------------------------------------------


def test_spec_rollback_leaves_table_identical():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()

    def run(spec):
        srv = _serve(model, params, prompts)
        srv.decode(16, horizon=8, speculative=spec)
        return srv

    a, b = run(False), run(True)
    assert {s: a.table.length(s) for s in a.sequence_ids()} == \
           {s: b.table.length(s) for s in b.sequence_ids()}
    assert a.table.resident_pages == b.table.resident_pages
    assert len(b.table._pinned) == 0
    # the speculative run really did roll rejected pages back
    assert b.tier_stats()["horizon_pages_rolled_back"] > 0 or \
        b.speculation_stats()["alpha"] == 1.0


# ---------------------------------------------------------------------------
# no-retrace: accepted-length variance shares one compiled program
# ---------------------------------------------------------------------------


def test_spec_no_retrace_across_accepted_lengths():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    srv = _serve(model, params, prompts)
    if not hasattr(srv._spec_jit, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")
    # the first run compiles every (b2, pps, h) bucket its passes hit;
    # within it the accepted lengths vary from warm-up 1s to full
    # horizons, all through those same programs
    srv.decode(16, horizon=8, speculative=True)
    sig = srv._spec_jit._cache_size()
    assert sig > 0                        # speculative passes really ran
    for i in range(len(prompts)):
        srv.free_sequence(i)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    srv.decode(16, horizon=8, speculative=True)
    assert srv._spec_jit._cache_size() == sig


# ---------------------------------------------------------------------------
# sampling: deterministic, seed-sensitive, spec == non-spec stream
# ---------------------------------------------------------------------------


def test_sampling_deterministic_and_seeded():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    sc = SamplingConfig(temperature=0.8, top_p=0.9, seed=42)

    def run(s):
        return _serve(model, params, prompts).decode(
            12, horizon=4, sampling=s)

    assert run(sc) == run(sc)
    assert run(sc) != run(dataclasses.replace(sc, seed=7))


def test_spec_sampling_runs_and_is_deterministic():
    """Speculative sampling (rejection-accept on device) must be
    reproducible under a fixed seed, and must actually exercise the
    draft path — a greedy priming phase seeds the history with repeats
    so the sampled phase has something to draft."""
    cfg, model, params = _tiny_model()
    sc = SamplingConfig(temperature=0.05, top_p=0.95, seed=3)

    def run():
        srv = _serve(model, params, _const_prompts(2))
        srv.decode(12, horizon=8)                  # greedy priming
        out = srv.decode(16, horizon=8, speculative=True, sampling=sc)
        return out, srv.speculation_stats()

    o1, st1 = run()
    o2, st2 = run()
    assert o1 == o2
    assert st1["passes"] > 0 and st1["drafted"] > 0


# ---------------------------------------------------------------------------
# pool: every shard derives identical tokens (greedy and sampled)
# ---------------------------------------------------------------------------


def test_pool_spec_one_node_matches_paged_greedy():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    ref = _serve(model, params, prompts)
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=64, dtype=jnp.float32)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    assert srv.decode(16, horizon=8, speculative=True) == \
        ref.decode(16, horizon=8)
    assert srv.speculation_stats()["passes"] > 0


def test_pool_spec_one_node_matches_paged_sampled():
    """temperature>0: the pool path must draw the identical Gumbel /
    uniform streams from the replicated pass key — bit-exact tokens vs
    the single-node server."""
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    sc = SamplingConfig(temperature=0.7, top_p=0.95, seed=11)

    def run(cls, **kw):
        srv = cls(model, params, **kw)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        srv.decode(8, horizon=8)                   # greedy priming
        return srv.decode(16, horizon=8, speculative=True, sampling=sc)

    assert run(PoolServer, n_nodes=1, page_size=4,
               hbm_pages_per_node=64, dtype=jnp.float32) == \
        run(PagedServer, page_size=4, hbm_pages=64, dtype=jnp.float32)


def _run(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_pool_spec_multi_node_matches_paged():
    """2 simulated nodes: shard-mapped draft-verify (replicated
    history/key, sharded pages) must emit exactly the single-node
    stream, greedy and sampled."""
    stdout = _run("""
    import dataclasses, numpy as np
    import jax, jax.numpy as jnp
    from repro.configs.base import get_arch
    from repro.models.api import get_model
    from repro.runtime.pool import PoolServer
    from repro.runtime.serve import PagedServer, SamplingConfig

    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [np.full(12 + i, c, np.int32)
               for i, c in enumerate((5, 9, 13))]
    sc = SamplingConfig(temperature=0.5, top_p=0.9, seed=2)

    def run(cls, **kw):
        srv = cls(model, params, **kw)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        g = srv.decode(12, horizon=8, speculative=True)
        s = srv.decode(8, horizon=8, speculative=True, sampling=sc)
        return g, s

    ref = run(PagedServer, page_size=4, hbm_pages=64,
              dtype=jnp.float32)
    got = run(PoolServer, n_nodes=2, page_size=4,
              hbm_pages_per_node=32, dtype=jnp.float32)
    assert got == ref, (got, ref)
    print("POOL_SPEC_OK")
    """)
    assert "POOL_SPEC_OK" in stdout


# ---------------------------------------------------------------------------
# scheduler: speculative batcher matches the per-token schedule
# ---------------------------------------------------------------------------


def test_batcher_speculative_matches_per_token_schedule():
    """ContinuousBatcher(speculative=True) — mixed join/evict at
    horizon boundaries, 1-token tails running plain — must finish every
    request with output identical to the per-token schedule."""
    cfg, model, params = _tiny_model()
    prompts = _const_prompts() + [
        np.random.default_rng(1).integers(0, cfg.vocab_size, 7,
                                          dtype=np.int32)]
    gens = [5, 9, 3, 7]

    def run(h, spec):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32)
        b = ContinuousBatcher(srv, max_active=2, horizon=h,
                              speculative=spec)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            b.submit(Request(rid=i, prompt=p, max_tokens=g))
        stats = b.run_to_completion()
        assert stats["requests"] == len(prompts)
        assert srv.table.free_pages == srv.hbm_pages
        return {r.rid: r.output for r in b.finished}

    assert run(8, True) == run(1, False)


def test_batcher_speculative_requires_horizon():
    cfg, model, params = _tiny_model()
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    with pytest.raises(ValueError, match="speculative"):
        ContinuousBatcher(srv, max_active=2, horizon=1, speculative=True)


# ---------------------------------------------------------------------------
# sampling= config threading and the greedy= shim
# ---------------------------------------------------------------------------


def test_greedy_shim_deprecated_but_equivalent():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    ref = _serve(model, params, prompts).decode(8)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = _serve(model, params, prompts).decode(8, greedy=True)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert out == ref
    with pytest.raises(ValueError, match="greedy=False"):
        _serve(model, params, prompts).decode(8, greedy=False)


def test_greedy_sampling_config_is_argmax():
    cfg, model, params = _tiny_model()
    prompts = _const_prompts()
    ref = _serve(model, params, prompts).decode(8, horizon=4)
    out = _serve(model, params, prompts).decode(
        8, horizon=4, sampling=GREEDY)
    assert out == ref


def test_decode_speculative_requires_fusable_horizon():
    cfg, model, params = _tiny_model()
    srv = _serve(model, params, _const_prompts())
    with pytest.raises(ValueError, match="speculative"):
        srv.decode(4, horizon=1, speculative=True)


# ---------------------------------------------------------------------------
# analytical: speculation model + overhead fit
# ---------------------------------------------------------------------------


def test_speculative_terms_expected_tokens():
    from repro.core.analytical import speculative_terms
    t = speculative_terms(n_tokens=256, horizon=8, alpha=1.0,
                          host_overhead_s=1e-3, verify_pos_s=1e-4)
    assert t["expected_tokens_per_pass"] == pytest.approx(8.0)
    t0 = speculative_terms(n_tokens=256, horizon=8, alpha=0.0,
                           host_overhead_s=1e-3, verify_pos_s=1e-4)
    assert t0["expected_tokens_per_pass"] == pytest.approx(1.0)
    # alpha=1 emits H tokens for one pass's host cost: strictly faster
    assert t["modeled_tokens_per_s"] > t0["modeled_tokens_per_s"]


def test_fit_speculation_overheads_recovers_terms():
    from repro.core.analytical import (fit_speculation_overheads,
                                       speculative_terms)
    host, pos = 2e-3, 3e-4
    a = speculative_terms(512, 4, 0.9, host, pos)
    b = speculative_terms(512, 16, 0.9, host, pos)
    fh, fp = fit_speculation_overheads(
        4, a["expected_tokens_per_pass"], a["modeled_tokens_per_s"],
        16, b["expected_tokens_per_pass"], b["modeled_tokens_per_s"])
    # speculative_terms rounds passes up to a whole pass (149 vs the
    # exact 148.88 at H=4), so the modeled tok/s it hands back carries
    # ~1/passes of quantization — the fit recovers to that resolution
    assert fh == pytest.approx(host, rel=2e-2)
    assert fp == pytest.approx(pos, rel=2e-2)
