"""Fused decode-horizon tests.

The contract: ``decode(horizon=H)`` — H tokens per host interaction,
on-device argmax/EOS/budget masking against horizon-reserved pages —
must produce greedy outputs token-for-token identical to the per-token
path, for any H, under eviction pressure, mid-horizon EOS, scheduler
joins/evicts at horizon boundaries, and pool failover.  Plus the
no-retrace guarantee: horizons over different active-sequence counts in
one pow2 bucket share a compiled program.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core.kv_tier import PageStore, PageTableManager
from repro.models.api import get_model
from repro.runtime.pool import PoolServer
from repro.runtime.scheduler import ContinuousBatcher, Request
from repro.runtime.serve import PagedServer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_model():
    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# reserve_horizon / commit_horizon (table-manager unit level)
# ---------------------------------------------------------------------------


def _store(hbm_pages, page=4):
    return PageStore(n_layers=2, page_size=page, hbm_pages=hbm_pages,
                     n_kv_heads=2, head_dim=8, dtype=jnp.float32)


def test_reserve_horizon_pins_and_rollback_frees():
    t = PageTableManager(_store(16))
    t.add_sequence(0)
    t.set_length(0, 6)                      # 2 pages committed
    t.ensure_resident(0)
    phys = t.reserve_horizon(0, 9)          # covers 6+9=15 tokens -> 4 pages
    assert len(phys) == 4
    assert t.resident_pages == 4
    assert len(t._pinned) == 4              # whole reservation pinned
    # commit 3 of the horizon's 9: length 9 -> 3 pages; 1 page rolls back
    assert t.commit_horizon(0, 3) == 1
    assert t.length(0) == 9
    assert t.resident_pages == 3
    assert t.free_pages == 13
    t.unpin_all()
    # a full free still reclaims everything
    assert t.free_sequence(0) == 3
    assert t.free_pages == 16


def test_reserve_horizon_rejects_bad_horizon():
    t = PageTableManager(_store(8))
    t.add_sequence(0)
    with pytest.raises(ValueError, match="horizon"):
        t.reserve_horizon(0, 0)


def test_reserve_horizon_respects_pinned_working_set():
    """A reservation larger than the window must raise the same
    pinned-working-set error the per-token path raises (admission
    control's contract), not corrupt the table."""
    t = PageTableManager(_store(4))
    t.add_sequence(0)
    t.set_length(0, 4)
    with pytest.raises(RuntimeError, match="pinned working set"):
        t.reserve_horizon(0, 64)            # 17 pages > 4-page window
    t.unpin_all()


def test_failed_batch_reservation_rolls_back_earlier_seqs():
    """When one sequence of a horizon batch cannot reserve (window
    overflow), the sequences reserved before it must not keep phantom
    data-less pages resident — the plan rolls every reservation back."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(7)
    srv = PagedServer(model, params, page_size=4, hbm_pages=8,
                      dtype=jnp.float32)
    for i in range(2):
        srv.add_request(i, rng.integers(0, cfg.vocab_size, 5,
                                        dtype=np.int32))   # 2 pages each
    with pytest.raises(RuntimeError, match="pinned working set"):
        # 5+12 tokens -> 5 pages per seq; seq 1's reservation overflows
        srv._plan_horizon([0, 1], {0: 12, 1: 12})
    # residency back to the committed working set, nothing pinned
    assert srv.table.resident_pages == 4
    assert len(srv.table._pinned) == 0
    assert srv.table.host_pages == 0
    # the server stays serviceable: a fitting horizon decodes fine
    out = srv.decode(4, horizon=4)
    assert srv.table.length(0) == 5 + 4 and len(out[0]) == 4


# ---------------------------------------------------------------------------
# horizon equivalence: decode(horizon=H) == per-token path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("horizon", [1, 4, 17])
def test_decode_horizon_matches_per_token(horizon):
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
               for _ in range(3)]
    gen = 12

    def run(h):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        out = srv.decode(gen, horizon=h)
        return out, srv

    ref, _ = run(None)
    got, srv = run(horizon)
    assert got == ref
    # the horizon reservation must be fully rolled back to the
    # committed lengths: same residency as the per-token run
    need = sum(srv.table.pages_needed(srv.table.length(s))
               for s in srv.sequence_ids())
    assert srv.table.resident_pages == need
    assert len(srv.table._pinned) == 0


def test_decode_horizon_under_eviction_pressure():
    """Window smaller than the total working set: horizon decode of one
    sequence spills the other to the flash tier and back, outputs
    unchanged."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(2)
    B, S, gen = 2, 7, 4
    prompts = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)

    ref = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32)
    srv = PagedServer(model, params, page_size=4, hbm_pages=4,
                      dtype=jnp.float32)
    for i in range(B):
        la = ref.add_request(i, prompts[i])
        lb = srv.add_request(i, prompts[i])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-4)
    o_ref1 = ref.decode(gen, seqs=[1])
    o_srv1 = srv.decode(gen, seqs=[1], horizon=4)    # seq 0 spills
    o_ref0 = ref.decode(gen, seqs=[0])
    o_srv0 = srv.decode(gen, seqs=[0], horizon=4)    # seq 0 pages back
    assert o_ref1 == o_srv1 and o_ref0 == o_srv0
    assert srv.tier_stats()["page_outs"] > 0
    assert srv.tier_stats()["page_ins"] > 0


def test_mid_horizon_eos_stops_on_device():
    """A sequence that emits EOS mid-horizon must stop appending/emitting
    on device; its tokens (including the EOS) match the per-token run,
    and the un-consumed reservation rolls back."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(2)]

    probe = PagedServer(model, params, page_size=4, hbm_pages=32,
                        dtype=jnp.float32)
    for i, p in enumerate(prompts):
        probe.add_request(i, p)
    free_run = probe.decode(8)
    eos = free_run[0][2]                    # seq 0's third decode token

    def run(h):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        out = srv.decode(8, horizon=h, eos_id=int(eos))
        return out, srv

    # per-token semantics of eos_id via the horizon path with H=1
    ref, _ = run(1)
    got, srv = run(8)                       # EOS lands mid-horizon
    assert got == ref
    for s, toks in got.items():
        cut = free_run[s]
        if int(eos) in cut:
            k = cut.index(int(eos))
            assert toks == cut[:k + 1]      # stops right after EOS
        else:
            assert toks == cut
    # committed lengths reflect only the consumed part of the horizon
    assert srv.table.length(0) == 6 + len(got[0])
    assert len(srv.table._pinned) == 0


def test_horizon_budgets_stop_per_sequence():
    """Per-sequence budgets (the scheduler's max_tokens enforcement)
    mask on device: each sequence stops at its own budget inside one
    fused horizon."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 5, dtype=np.int32)
               for _ in range(3)]
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    ref = srv.decode(8, horizon=None)       # consumes pending; re-serve
    srv2 = PagedServer(model, params, page_size=4, hbm_pages=32,
                       dtype=jnp.float32)
    for i, p in enumerate(prompts):
        srv2.add_request(i, p)
    budgets = {0: 2, 1: 8, 2: 5}
    got = srv2.decode(8, horizon=8, budgets=budgets)
    for s in range(3):
        assert got[s] == ref[s][:budgets[s]], s
        assert srv2.table.length(s) == 5 + budgets[s]


def test_per_token_path_honors_eos_and_budgets():
    """eos_id/budgets must stop sequences on the per-token path exactly
    like the fused path (host-side between steps vs on device)."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(2)]

    probe = PagedServer(model, params, page_size=4, hbm_pages=32,
                        dtype=jnp.float32)
    for i, p in enumerate(prompts):
        probe.add_request(i, p)
    eos = int(probe.decode(6)[0][2])

    def run(h):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        out = srv.decode(6, horizon=h, eos_id=eos, budgets={0: 6, 1: 3})
        return out, {s: srv.table.length(s) for s in (0, 1)}

    out_pt, len_pt = run(None)
    out_h, len_h = run(4)
    assert out_pt == out_h
    assert len_pt == len_h                  # identical commit trajectory
    assert len(out_pt[1]) == 3              # budget respected


# ---------------------------------------------------------------------------
# no-retrace: one compiled program per (pow2 batch, pow2 pps, pow2 H)
# ---------------------------------------------------------------------------


def test_horizon_no_retrace_across_active_counts():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(3)
    srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    if not hasattr(srv._horizon_jit, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")
    for i in range(4):
        srv.add_request(i, rng.integers(0, cfg.vocab_size, 5,
                                        dtype=np.int32))
    srv.decode(4, seqs=[0, 1, 2], horizon=4)
    sig0 = srv._horizon_jit._cache_size()
    srv.decode(4, seqs=[0, 1, 2, 3], horizon=4)   # same pow2 bucket (4)
    assert srv._horizon_jit._cache_size() == sig0
    # a horizon tail in the same pow2 bucket keeps the program too:
    # decode(6, horizon=4) runs fused chunks H=4 then H=2
    srv.decode(6, seqs=[0, 1], horizon=4)
    sig1 = srv._horizon_jit._cache_size()
    srv.decode(6, seqs=[0, 1], horizon=4)
    assert srv._horizon_jit._cache_size() == sig1


# ---------------------------------------------------------------------------
# scheduler on horizon boundaries
# ---------------------------------------------------------------------------


def test_batcher_horizon_matches_per_token_schedule():
    """ContinuousBatcher(horizon=H) — joins/evicts at horizon
    boundaries, device-side EOS + budgets — must finish every request
    with output identical to the per-token schedule."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(4)]
    gens = [3, 7, 2, 5]

    probe = PagedServer(model, params, page_size=4, hbm_pages=64,
                        dtype=jnp.float32)
    probe.add_request(0, prompts[0])
    eos = int(probe.decode(4)[0][1])        # a token that really occurs

    def run(h):
        srv = PagedServer(model, params, page_size=4, hbm_pages=16,
                          dtype=jnp.float32)
        b = ContinuousBatcher(srv, max_active=2, horizon=h)
        for i, (p, g) in enumerate(zip(prompts, gens)):
            b.submit(Request(rid=i, prompt=p, max_tokens=g, eos_id=eos))
        stats = b.run_to_completion()
        assert stats["requests"] == 4
        assert srv.table.free_pages == srv.hbm_pages   # all reclaimed
        return {r.rid: r.output for r in b.finished}

    ref = run(1)
    for h in (3, 4, 8):
        assert run(h) == ref, h


def test_batcher_horizon_mixed_eos_truncates_host_side():
    """Active requests with different eos ids cannot share one device
    eos mask; the batcher truncates host-side and outputs still match
    the per-token schedule."""
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(2)]

    probe = PagedServer(model, params, page_size=4, hbm_pages=64,
                        dtype=jnp.float32)
    for i, p in enumerate(prompts):
        probe.add_request(i, p)
    free_run = probe.decode(6)
    eos_ids = [int(free_run[0][1]), int(free_run[1][2])]

    def run(h):
        srv = PagedServer(model, params, page_size=4, hbm_pages=32,
                          dtype=jnp.float32)
        b = ContinuousBatcher(srv, max_active=2, horizon=h)
        for i, p in enumerate(prompts):
            b.submit(Request(rid=i, prompt=p, max_tokens=6,
                             eos_id=eos_ids[i]))
        b.run_to_completion()
        return {r.rid: r.output for r in b.finished}

    assert run(4) == run(1)


# ---------------------------------------------------------------------------
# pool: sharded horizon + failover at a horizon boundary (slow lane)
# ---------------------------------------------------------------------------


def test_pool_horizon_one_node_matches_paged():
    cfg, model, params = _tiny_model()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)
               for _ in range(3)]
    ref = PagedServer(model, params, page_size=4, hbm_pages=32,
                      dtype=jnp.float32)
    srv = PoolServer(model, params, n_nodes=1, page_size=4,
                     hbm_pages_per_node=32, dtype=jnp.float32)
    for i, p in enumerate(prompts):
        ref.add_request(i, p)
        srv.add_request(i, p)
    assert srv.decode(8, horizon=4) == ref.decode(8)


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_pool_failover_mid_horizon_decode():
    """Kill a node while a horizon-scheduled router is mid-flight: the
    victims requeue at the next horizon boundary, re-prefill
    prompt+history on survivors, and finish with outputs identical to
    the uninterrupted per-token run."""
    stdout = _run("""
    import dataclasses, numpy as np
    import jax, jax.numpy as jnp
    from repro.configs.base import get_arch
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request
    from repro.runtime.serve import PagedServer

    cfg = dataclasses.replace(get_arch("granite_3_2b").reduced(),
                              n_layers=2, vocab_size=64)
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(5)]
    gens = [9, 11, 8, 10, 9]

    ref = PagedServer(model, params, page_size=4, hbm_pages=64,
                      dtype=jnp.float32)
    ref_out = {}
    for i, p in enumerate(prompts):
        ref_out[i] = [int(np.argmax(np.asarray(ref.add_request(i, p))))]
    for i, toks in ref.decode(max(gens) - 1).items():
        ref_out[i] += toks
    ref_out = {i: o[:g] for (i, o), g in zip(ref_out.items(), gens)}

    srv = PoolServer(model, params, n_nodes=4, page_size=4,
                     hbm_pages_per_node=8, dtype=jnp.float32)
    pool = StoragePool(4, heartbeat_timeout=0.0)
    pool.attach_server(srv)
    router = PoolRouter(srv, pool, max_active=5, horizon=4)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        router.submit(Request(rid=i, prompt=p, max_tokens=g))
    router.step()                        # one fused horizon everywhere
    victim = srv.node_of(0)
    assert any(len(r.output) > 1 for r in router.active.values())
    pool.nodes[pool.serving_ips()[victim]].fail()     # dies mid-decode
    router.run_to_completion()
    assert router.requeues >= 1
    assert victim not in srv.alive_nodes()
    by_id = {r.rid: r.output for r in router.finished}
    for i, g in enumerate(gens):
        assert by_id[i] == ref_out[i], (i, by_id[i], ref_out[i])
    print("HORIZON_FAILOVER_OK")
    """)
    assert "HORIZON_FAILOVER_OK" in stdout
