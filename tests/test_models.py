"""Per-architecture smoke tests (reduced configs, CPU) + serving
consistency: every assigned arch instantiates, runs one forward/train
step with correct output shapes and no NaNs; prefill+decode matches the
full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, ShapeConfig, cells, get_arch
from repro.models.api import get_model

# heaviest suite in the repo: every arch x (train step, prefill/decode)
pytestmark = pytest.mark.slow

SMOKE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.synth_batch(SMOKE)
    loss, parts = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), (arch_id, loss)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (SMOKE.global_batch, SMOKE.seq_len,
                            cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # one SGD-flavored step reduces nothing catastrophically
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a).has_decode])
def test_prefill_decode_matches_forward(arch_id):
    cfg = get_arch(arch_id).reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    logits_full, _ = model.forward(params, {"tokens": toks})
    lp, cache = model.prefill(params, {"tokens": toks[:, :S - 4]},
                              cache_dtype=jnp.float32)
    errs = [float(np.abs(np.asarray(lp) -
                         np.asarray(logits_full[:, S - 5])).max())]
    if "k" in cache and cache["k"].shape[-2] < S:
        pad = S - cache["k"].shape[-2]
        widths = [(0, 0)] * (cache["k"].ndim - 2) + [(0, pad), (0, 0)]
        cache["k"] = jnp.pad(cache["k"], widths)
        cache["v"] = jnp.pad(cache["v"], widths)
    for t in range(S - 4, S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        errs.append(float(np.abs(np.asarray(lg) -
                                 np.asarray(logits_full[:, t])).max()))
    assert max(errs) < 5e-4, (arch_id, errs)


def test_encoder_only_has_no_decode_cells():
    names = [(a.name, s.name) for a, s, _ in cells(runnable_only=True)]
    assert ("hubert-xlarge", "decode_32k") not in names
    assert ("hubert-xlarge", "prefill_32k") in names
    # long_500k only for sub-quadratic archs
    longs = [a for a, s in names if s == "long_500k"]
    assert sorted(longs) == ["rwkv6-3b", "zamba2-1.2b"]
    assert len(names) == 31


def test_frontend_archs_take_embeds():
    cfg = get_arch("paligemma_3b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    assert model.uses_embeds()
    batch = model.synth_batch(SMOKE)
    assert "embeds" in batch
    loss, _ = model.loss(model.init(jax.random.PRNGKey(0)), batch)
    assert np.isfinite(float(loss))


def test_hubert_bidirectional():
    """Encoder-only: flipping future tokens must change past logits."""
    cfg = get_arch("hubert_xlarge").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models.frontends import synth_embeddings
    e1 = synth_embeddings(cfg, 1, 16, jax.random.PRNGKey(1))
    e2 = e1.at[:, -1].set(0.0)
    l1, _ = model.forward(params, {"embeds": e1})
    l2, _ = model.forward(params, {"embeds": e2})
    assert float(np.abs(np.asarray(l1[:, 0]) -
                        np.asarray(l2[:, 0])).max()) > 1e-6


def test_causal_decoder_is_causal():
    cfg = get_arch("granite_3_2b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                            cfg.vocab_size, jnp.int32)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
    l1, _ = model.forward(params, {"tokens": t1})
    l2, _ = model.forward(params, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), atol=1e-5)


def test_rwkv_decode_state_is_constant_size():
    cfg = get_arch("rwkv6_3b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    spec_small = model.cache_spec(2, 128)
    spec_large = model.cache_spec(2, 524_288)
    assert jax.tree.map(lambda s: s.shape, spec_small) == \
        jax.tree.map(lambda s: s.shape, spec_large)


def test_moe_routes_to_multiple_experts():
    cfg = get_arch("phi3_5_moe_42b_a6_6b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.synth_batch(SMOKE)
    _, parts = model.loss(params, batch)
    assert float(parts["aux"]) > 0  # balance loss active
