"""Validation of the analytical LLM-pool model (Fig 12/13) and the ISP
cost model (Fig 3/11) against the paper's claims."""
import numpy as np
import pytest

from repro.core import analytical as A
from repro.core import isp_perf as I


@pytest.fixture(scope="module")
def pool_results():
    return A.evaluate_pool()


def test_fig12b_headline_ratios(pool_results):
    r = A.headline_ratios(pool_results)
    assert 6.0 <= r["d_cache_vs_h_cache"] <= 10.0          # paper: 7.9x
    assert 300 <= r["h_cache_vs_h_nocache"] <= 560         # paper: 421x
    assert 3400 <= r["d_cache_vs_d_nocache"] <= 6200       # paper: 4.6Kx
    assert 2300 <= r["d_cache_vs_h_nocache"] <= 4300       # paper: 3.2Kx
    assert 1.4 <= r["d_nocache_slowdown_vs_h"] <= 2.0      # paper: 1.7x


def test_fig12a_parallelism_patterns(pool_results):
    """Cache -> tensor parallel; NoCache on hosts -> pipeline-heavy."""
    for name, row in pool_results.items():
        dp, tp, pp = row["configs"]["H-Cache"]["parallelism"]
        assert tp >= pp and tp >= dp, (name, "H-Cache", (dp, tp, pp))
        dp, tp, pp = row["configs"]["D-Cache"]["parallelism"]
        assert tp >= pp and tp >= dp, (name, "D-Cache", (dp, tp, pp))
    big = ["gopher-280B", "turing-530B", "palm-540B", "megatron-1T"]
    for name in big:
        dp, tp, pp = pool_results[name]["configs"]["H-NoCache"]["parallelism"]
        assert pp > 1, (name, (dp, tp, pp))


def test_fig13a_crossovers():
    rl = A.seq_sensitivity("lamda-137B")
    rm = A.seq_sensitivity("megatron-1T")
    assert A.crossover_point(rl) == 256                     # paper: 256
    assert 256 <= A.crossover_point(rm) <= 2048             # paper: 1024
    # converged speedup ~9.5x
    assert 8.0 <= rl[-1]["speedup"] <= 12.5
    # below crossover the host wins (DockerSSD ~60% of host perf)
    assert rl[0]["speedup"] < 1.0


def test_fig13_smaller_models_benefit_more():
    """Same (moderate) seq length -> the smaller model is already past its
    crossover and shows greater speedup (paper: larger models spend more
    time in MLPs, delaying the KV-cache benefit)."""
    rl = {r["seq_len"]: r["speedup"] for r in A.seq_sensitivity("lamda-137B")}
    rm = {r["seq_len"]: r["speedup"] for r in A.seq_sensitivity("megatron-1T")}
    for s in (256, 512):
        assert rl[s] > rm[s], (s, rl[s], rm[s])


def test_fig13cd_batch_sensitivity():
    rows = A.batch_sensitivity("lamda-137B", seq_len=1024)
    sp = [r["speedup"] for r in rows]
    assert max(sp) <= 1.6                                   # paper: <=~1.3x
    assert sp == sorted(sp)                                 # grows w/ batch


def test_generation_time_monotonic_in_seq():
    m = A.POOL_LLMS[0]
    ts = [A.generation_time(m, seq_len=s, batch=16, dp=1, tp=16, pp=1,
                            cache=True, device="ssd")["total"]
          for s in (1024, 4096, 16384)]
    assert ts[0] < ts[1] < ts[2]


# ---------------------------------------------------------------------------
# ISP model (Fig 3 / Fig 11)
# ---------------------------------------------------------------------------


def test_fig11_headline_ratios():
    r = I.headline_ratios()
    assert 1.4 <= r["dvirtfw_vs_pisp"] <= 1.8               # paper: 1.6x
    assert 1.5 <= r["dvirtfw_vs_dnaive"] <= 2.1             # paper: 1.8x
    assert 1.4 <= r["dvirtfw_vs_dfullos"] <= 1.8            # paper: 1.6x
    assert 1.1 <= r["dvirtfw_vs_host"] <= 1.5               # paper: 1.3x
    assert 0.10 <= r["pispv_vs_pispr"] <= 0.17              # paper: 13.7%
    assert 0.04 <= r["dfullos_over_pispv"] <= 0.15          # paper: 9.3%
    assert 0.08 <= r["dnaive_over_dfullos"] <= 0.18         # paper: 12.8%


def test_fig3_breakdown():
    r = I.headline_ratios()
    assert 0.30 <= r["host_storage_share"] <= 0.46          # paper: 38%
    assert 0.35 <= r["pisp_comm_share"] <= 0.50             # paper: 43%
    assert 0.40 <= r["pisp_storage_reduction"] <= 0.60      # paper: 50%
    assert r["pisp_vs_host"] > 1.0                          # P.ISP slower e2e


def test_table2_constants():
    assert len(I.WORKLOADS) == 13
    by = {f"{w.program}-{w.name}": w for w in I.WORKLOADS}
    assert by["embed-rm1"].io_size_gb == 1.3
    assert by["mariadb-tpch4"].syscalls == 1.1e6
    assert by["vsftpd-fileup"].tcp_packets == 1.2e6


def test_all_six_models_complete():
    out = I.evaluate_all()
    assert len(out) == 13
    for wl, models in out.items():
        assert set(models) == set(I.MODELS)
        for m, compos in models.items():
            assert set(compos) == set(I.COMPONENTS)
            assert all(v >= 0 for v in compos.values())


def test_dvirtfw_component_story():
    """D-VirtFW: no LBA-set, no Kernel-ctx, tiny System."""
    w = I.WORKLOADS[0]
    d = I.components(w, "D-VirtFW")
    p = I.components(w, "P.ISP-V")
    f = I.components(w, "D-FullOS")
    assert d["LBA-set"] == 0 and d["Kernel-ctx"] == 0
    assert p["LBA-set"] > 0 and p["Kernel-ctx"] > 0
    assert d["System"] < f["System"] / 10
