"""Quickstart: train a ~100M-param dense LM for a few hundred steps.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]

Uses the full production stack — ArchConfig, AdamW + cosine schedule,
grad accumulation, deterministic sharded data pipeline (learnable
synthetic stream so the loss visibly falls), async atomic checkpoints.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_arch
from repro.data import ShardedLoader
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.runtime.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    # a ~100M-param granite-family config (trainable on this CPU box at
    # reduced width; bump d_model/n_layers on real hardware)
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
        vocab_size=8192)
    model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
    n_params = None

    sched = warmup_cosine(1e-3, 20, args.steps)
    init_fn, upd_fn = adamw(lr=sched)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: granite-family {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")
    opt = init_fn(params)
    tstep = jax.jit(make_train_step(model, upd_fn, grad_accum=2),
                    donate_argnums=(0, 1))

    loader = ShardedLoader(global_batch=16, seq_len=128,
                           vocab=cfg.vocab_size, n_shards=1, shard=0,
                           kind="learnable")
    mgr = CheckpointManager(args.ckpt, keep=2)
    t0 = time.time()
    first = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        params, opt, metrics = tstep(params, opt, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt},
                     blocking=False)
    mgr.save(args.steps, {"params": params, "opt": opt})
    mgr.wait()
    loader.close()
    print(f"\nloss {first:.3f} -> {loss:.3f}; checkpoints at {args.ckpt} "
          f"(steps {mgr.steps()})")
    assert loss < first, "training did not learn"


if __name__ == "__main__":
    main()
