"""RAG on the computing-enabled storage pool: in-storage top-k
retrieval feeding prefix-cached serving, end to end.

The corpus's embedding matrix lives as an ExtentStore extent on a
DockerSSD.  Each query becomes an ``AnalyticsJob(reduce="topk")`` —
the scored scan runs *inside* the storage node (double-buffered Pallas
kernel over the extent pages) and only k (id, score) pairs ride the
RESULTS frame back, instead of the whole embedding matrix crossing the
tunnel.  Top-k ids map to context token blocks through one batched
``embed_gather``, the assembled prompt (template ++ retrieved chunks ++
question) goes to the paged server, and the shared-prefix cache absorbs
the repeated template + chunks across requests — the second wave of
admissions computes only each question's tail.

The demo asserts the two load-bearing invariants:
  * device retrieval is bit-identical to the host fold, so decode
    outputs are token-identical to a host-side retrieval baseline;
  * warm (prefix-cached) admission beats the cache-ablated cold path.

  PYTHONPATH=src python examples/serve_rag.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import StoragePool, analytics_blob
from repro.models.api import get_model
from repro.runtime.retrieval import RetrievalFrontend
from repro.runtime.serve import PagedServer


def main():
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # corpus: 16 documents, each a 16-token context chunk with a
    # 32-dim embedding row; one shared instruction template
    n_docs, d_emb, chunk_tok, k = 16, 32, 16, 3
    n_req, tail, gen = 4, 8, 8
    template = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
    corpus = rng.integers(0, cfg.vocab_size, (n_docs, chunk_tok),
                          dtype=np.int32)
    emb = rng.normal(size=(n_docs, d_emb)).astype(np.float32)

    pool = StoragePool(1, extent_cfg={"n_pages": n_docs // 4 + 2,
                                      "page_rows": 4, "n_cols": d_emb})
    pool.broadcast_pull("isp-analytics", analytics_blob())

    warm = PagedServer(model, params, page_size=8, hbm_pages=64,
                       dtype=jnp.float32)
    cold = PagedServer(model, params, page_size=8, hbm_pages=64,
                       dtype=jnp.float32, prefix_cache=False)
    fe = RetrievalFrontend(pool, warm, corpus_tokens=corpus,
                           template=template, k=k)
    fe_cold = RetrievalFrontend(pool, cold, corpus_tokens=corpus,
                                template=template, k=k)
    ip = fe.ingest(emb)[0]
    print(f"corpus extent: {n_docs}x{d_emb} embeddings on node {ip}")

    # every request asks about one topic (same query vector), with its
    # own question tail — the RAG shape the prefix cache pays off on
    query = rng.normal(size=(d_emb,)).astype(np.float32)

    def qtails():
        return [rng.integers(0, cfg.vocab_size, tail, dtype=np.int32)
                for _ in range(n_req)]

    def wave(fe_, tails, force):
        t0 = time.perf_counter()
        for i, qt in enumerate(tails):
            fe_.submit(i, query, qt, force=force)
        dt = time.perf_counter() - t0
        out = fe_.server.decode(gen)
        got = {i: out[i] for i in range(n_req)}
        for i in range(n_req):
            fe_.server.free_sequence(i)
        return dt, got

    # host-retrieval baseline on the cache-ablated server = the oracle
    tails = qtails()
    _, base = wave(fe_cold, tails, "host")
    # device retrieval on the warm server: first wave seeds the cache
    _, first = wave(fe, tails, "device")
    assert first == base, "device retrieval diverged from host baseline"
    hit = fe.retrieve([query], force="device")[0]
    print(f"top-{k} in storage: ids {hit['ids']} (scores "
          f"{[round(s, 3) for s in hit['scores']]})")

    # second wave: fresh questions, same topic — template + retrieved
    # chunks ride the prefix cache
    wave(fe, qtails(), "device")                 # bucket warm-up
    t_warm, second = wave(fe, qtails(), "device")
    t_cold, _ = wave(fe_cold, qtails(), "device")
    print(f"admission wave: cold {t_cold*1e3:.1f} ms | warm "
          f"{t_warm*1e3:.1f} ms ({t_cold / t_warm:.1f}x)")
    print(f"retrieval placement: {fe.stats}")
    assert t_warm < t_cold, "prefix-cached admission should be faster"
    print("outputs token-identical to host-side retrieval baseline; "
          "warm admissions rode the shared prefix")


if __name__ == "__main__":
    main()
