"""ISP-container analytics on a disaggregated storage pool — the paper's
Figure 5 flow, end to end, with the container workload as a *jitted
analytics program* (scan -> filter -> reduce over flash-resident
extents):

  1. build the generic ``isp-analytics`` Docker blob and `docker pull`
     it onto every DockerSSD over Ether-oN,
  2. host drops raw table data into the *sharable* namespace; each node
     ingests it into its ExtentStore pages through λFS,
  3. an AnalyticsJob submitted through the docker-cli front door
     executes a jitted Pallas scan/filter/reduce near the flash and
     returns only the aggregate,
  4. the OffloadPlanner decides Host vs D-VirtFW per job from the
     calibrated isp_perf costs, batches device jobs per node, and the
     results match the host-reads-everything path bit for bit,
  5. the classic DLRM 'embed' container (Pallas embed_agg) still runs
     on the same pool, and logs stream back over the NVMe upcall path.

  PYTHONPATH=src python examples/isp_containers.py
"""
import json
import sys
import urllib.parse

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import (AnalyticsJob, EthernetFrame, SHARABLE_NS,
                        StoragePool, analytics_blob, from_jsonable,
                        make_blob, ImageManifest, register_app)
from repro.core.analytical import data_plane_terms
from repro.kernels import ops
from repro.runtime.offload import OffloadPlanner


@register_app("dlrm-embed")
def dlrm_embed(ctx, table_path="/data/table.npy", idx_path="/data/idx.npy"):
    """The paper's 'embed' workload: sparse-feature lookup + sum-pool,
    executed near the data (kernel: repro.kernels.embed_agg)."""
    ctx.log("binding inputs from the sharable namespace")
    ctx.bind(table_path)
    ctx.bind(idx_path)
    table = np.frombuffer(ctx.fs.read(table_path, SHARABLE_NS),
                          np.float32).reshape(-1, 64)
    idx = np.frombuffer(ctx.fs.read(idx_path, SHARABLE_NS),
                        np.int32).reshape(-1, 16)
    ctx.syscall("openat", table_path, "sharable")
    ctx.alloc(table.nbytes + idx.nbytes)
    pooled = ops.embed_agg(jnp.asarray(table), jnp.asarray(idx))
    ctx.release(table_path)
    ctx.release(idx_path)
    ctx.log(f"pooled {idx.shape[0]} bags of {idx.shape[1]} lookups")
    return np.asarray(pooled)


def main():
    pool = StoragePool(4, extent_cfg={"n_pages": 16, "page_rows": 64,
                                      "n_cols": 32})
    print(f"pool: {len(pool.nodes)} DockerSSDs; IPs "
          f"{pool.alive_nodes()[:3]}...")

    # 1. the generic analytics image, pulled everywhere over Ether-oN
    pool.broadcast_pull("isp-analytics", analytics_blob())
    print("pulled 'isp-analytics' onto all nodes")

    # 2. host places raw tables in the sharable NS; nodes ingest them
    #    into flash extent pages through λFS (counted syscalls)
    rng = np.random.default_rng(0)
    tables = {}
    for i, ip in enumerate(pool.alive_nodes()[:2]):
        node = pool.nodes[ip]
        data = rng.normal(size=(500, 32)).astype(np.float32)
        node.fs.write("/data/tbl.bin", data.tobytes(), SHARABLE_NS,
                      actor="host")
        shape = node.ingest_extent(f"tbl{i}", "/data/tbl.bin", 32)
        tables[f"tbl{i}"] = (ip, data)
        print(f"  {ip}: ingested extent tbl{i} {shape}")

    # 3. analytics through the docker-cli front door, over Ether-oN
    ip, data = tables["tbl0"]
    job = AnalyticsJob(extent="tbl0", filter_col=3, filter_op="ge",
                      threshold=0.0, reduce="count")
    node = pool.nodes[ip]
    cid = json.loads(node.docker.handle_http(
        "POST /containers/create?image=isp-analytics"))["Id"]
    q = urllib.parse.quote(json.dumps([job.to_dict()]))
    resp = from_jsonable(json.loads(node.docker.handle_http(
        f"POST /containers/{cid}/start?job={q}")))
    block = resp["result"][0]
    ref = np.asarray(ops.scan_filter_reduce_host(
        jnp.asarray(data), 0.0, page_rows=64, filter_col=3,
        filter_op="ge"))
    assert np.array_equal(block, ref), "front door != host reference"
    print(f"front door: count(col3 >= 0) = {block[0, 0]:.0f} of "
          f"{data.shape[0]} rows (bit-identical to the host fold)")

    # 4. planner: decide, batch per node, execute across the pool
    planner = OffloadPlanner(pool)
    jobs = [AnalyticsJob(extent=name, filter_col=1, filter_op="lt",
                         threshold=0.5, reduce="sum", reduce_col=2,
                         job_id=i)
            for i, name in enumerate(tables)]
    recs = planner.execute(jobs)
    for rec in recs:
        est = rec["est"]
        print(f"  job {rec['job'].job_id} on {est.node_ip}: -> "
              f"{rec['where']} (modeled host {est.host_s*1e3:.2f} ms vs "
              f"d-virtfw {est.dvirtfw_s*1e3:.2f} ms), "
              f"sum[col2|col1<0.5] = {rec['result']:.3f}")
        _, d = tables[rec["job"].extent]
        ref = np.asarray(ops.scan_filter_reduce_host(
            jnp.asarray(d), 0.5, page_rows=64, filter_col=1,
            filter_op="lt"))
        assert np.array_equal(rec["block"], ref)
    terms = data_plane_terms(pool.driver.stats,
                             bytes_scanned=sum(d.nbytes for _, d in
                                               tables.values()),
                             n_jobs=len(jobs))
    print(f"data plane: {terms['job_frames']:.0f} job frames, "
          f"{terms['wire_bytes']:.0f} wire bytes "
          f"({terms['us_per_job']:.1f} us/job accounted)")

    # 5. the classic DLRM embed container still runs on the same pool
    blob = make_blob(ImageManifest("dlrm-embed", "dlrm-embed",
                                   ["rootfs-layer0"]),
                     {"rootfs-layer0": b"binaries+runtime"})
    pool.broadcast_pull("dlrm-embed", blob)
    ip = pool.alive_nodes()[2]
    node = pool.nodes[ip]
    table = rng.normal(size=(512, 64)).astype(np.float32)
    idx = rng.integers(0, 512, (32, 16), dtype=np.int32)
    node.fs.write("/data/table.npy", table.tobytes(), SHARABLE_NS,
                  actor="host")
    node.fs.write("/data/idx.npy", idx.tobytes(), SHARABLE_NS,
                  actor="host")
    cid, pooled = node.docker.cmd_run("dlrm-embed")
    print(f"dlrm-embed on {ip}: pooled shape {pooled.shape}")

    # logs via docker-cli over Ether-oN, then a node failure reschedule
    pool.driver.transmit(EthernetFrame("10.0.0.1", ip,
                                       f"GET /containers/{cid}/logs".encode()))
    frame = pool.driver.poll()
    print(f"logs over Ether-oN from {ip}:")
    for line in frame.payload.decode().strip().splitlines():
        print("   |", line)
    pool.place_independent("embed-job", "dlrm-embed", n=2)
    victim = pool.placements["embed-job"].node_ips[0]
    pool.nodes[victim].fail()
    pool.check_heartbeats(now=1e9)
    print(f"killed {victim}; pool events: {pool.events[-1]}")
    print(f"Ether-oN stats: {pool.driver.stats.tx_commands} tx cmds, "
          f"{pool.driver.stats.rx_completions} upcalls, "
          f"{pool.driver.stats.job_frames} job frames, "
          f"{pool.driver.stats.lock_syncs} inode-lock syncs")


if __name__ == "__main__":
    main()
