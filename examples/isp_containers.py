"""ISP-container lifecycle on a disaggregated storage pool — the paper's
Figure 5 flow, end to end:

  1. build a Docker-style blob (manifest + layers) for the DLRM 'embed'
     workload (the paper's rm1/rm2 ISP kernel),
  2. `docker pull` it onto every DockerSSD over Ether-oN,
  3. host drops input data into the *sharable* namespace,
  4. `docker run` executes the ISP-container near the flash (embedding
     lookups via the Pallas embed_agg kernel), with inode locks
     protecting host/container concurrency,
  5. logs stream back over the NVMe upcall path; a node failure gets
     rescheduled by the pool.

  PYTHONPATH=src python examples/isp_containers.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import (EthernetFrame, SHARABLE_NS, StoragePool,
                        make_blob, ImageManifest, register_app)
from repro.kernels import ops


@register_app("dlrm-embed")
def dlrm_embed(ctx, table_path="/data/table.npy", idx_path="/data/idx.npy"):
    """The paper's 'embed' workload: sparse-feature lookup + sum-pool,
    executed near the data (kernel: repro.kernels.embed_agg)."""
    ctx.log("binding inputs from the sharable namespace")
    ctx.bind(table_path)
    ctx.bind(idx_path)
    table = np.frombuffer(ctx.fs.read(table_path, SHARABLE_NS),
                          np.float32).reshape(-1, 64)
    idx = np.frombuffer(ctx.fs.read(idx_path, SHARABLE_NS),
                        np.int32).reshape(-1, 16)
    ctx.syscall("openat", table_path, "sharable")
    ctx.alloc(table.nbytes + idx.nbytes)
    pooled = ops.embed_agg(jnp.asarray(table), jnp.asarray(idx))
    ctx.release(table_path)
    ctx.release(idx_path)
    ctx.log(f"pooled {idx.shape[0]} bags of {idx.shape[1]} lookups")
    return np.asarray(pooled)


def main():
    pool = StoragePool(n_nodes=8)
    print(f"pool: {len(pool.nodes)} DockerSSDs in "
          f"{len(pool.arrays)} arrays; IPs "
          f"{pool.alive_nodes()[:3]}...")

    # 1-2. blob build + docker pull everywhere
    blob = make_blob(ImageManifest("dlrm-embed", "dlrm-embed",
                                   ["rootfs-layer0"]),
                     {"rootfs-layer0": b"binaries+runtime"})
    pool.broadcast_pull("dlrm-embed", blob)
    print(f"pulled 'dlrm-embed' ({len(blob)}B blob) onto all nodes")

    # 3. host places input data in the sharable namespace of 4 nodes
    rng = np.random.default_rng(0)
    job_nodes = pool.alive_nodes()[:4]
    for ip in job_nodes:
        node = pool.nodes[ip]
        table = rng.normal(size=(512, 64)).astype(np.float32)
        idx = rng.integers(0, 512, (32, 16), dtype=np.int32)
        node.fs.write("/data/table.npy", table.tobytes(), SHARABLE_NS,
                      actor="host")
        node.fs.write("/data/idx.npy", idx.tobytes(), SHARABLE_NS,
                      actor="host")

    # 4. distributed placement + run (mode 2 of the paper: one job
    #    spanning the pool)
    pool.place_distributed("embed-job", "dlrm-embed", dp=4)
    results = pool.run_on(
        "embed-job",
        lambda node, rank: node.docker.cmd_run("dlrm-embed")[1])
    print(f"ran on {len(results)} nodes; pooled shapes "
          f"{[r.shape for r in results]}")

    # 5. logs via docker-cli over Ether-oN
    ip = job_nodes[0]
    pool.driver.transmit(EthernetFrame("10.0.0.1", ip,
                                       b"GET /containers/1/logs"))
    frame = pool.driver.poll()
    print(f"logs over Ether-oN from {ip}:")
    for line in frame.payload.decode().strip().splitlines():
        print("   |", line)

    # failure: kill a node mid-fleet, watch the pool reschedule
    victim = pool.placements["embed-job"].node_ips[0]
    pool.nodes[victim].fail()
    pool.check_heartbeats(now=1e9)
    print(f"killed {victim}; pool events: {pool.events[-1]}")
    print(f"Ether-oN stats: {pool.driver.stats.tx_commands} tx cmds, "
          f"{pool.driver.stats.rx_completions} upcalls, "
          f"{pool.driver.stats.lock_syncs} inode-lock syncs")


if __name__ == "__main__":
    main()
