"""Elastic training: node failure -> degraded mesh -> exact resume.

The 1000+-node story in one script: train on an 8-device mesh with
sharded params/optimizer, checkpoint asynchronously, "lose" two devices,
rebuild a 6-device mesh (`make_elastic_mesh` keeps the model axis
intact), restore the checkpoint **resharded** onto the degraded mesh,
re-partition the deterministic data pipeline, and verify training
continues from the exact same state (loss trajectory matches a
never-interrupted run on the new mesh).

  PYTHONPATH=src python examples/elastic_training.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_arch
from repro.data.pipeline import synthetic_stream
from repro.launch.mesh import make_elastic_mesh
from repro.models.api import get_model
from repro.optim import adamw
from repro.runtime import sharding as shd
from repro.runtime.train import make_train_step


def shardings_for(mesh, params, opt):
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.param_specs(mesh, params))
    oshard = type(opt)(step=NamedSharding(mesh, P()), m=pshard, v=pshard)
    return pshard, oshard


def batch_for(step, cfg, n_shards):
    """Deterministic global batch assembled from per-shard streams."""
    shards = [synthetic_stream(0, step, s, batch=2, seq_len=32,
                               vocab=cfg.vocab_size, kind="learnable")
              for s in range(n_shards)]
    return {k: jnp.asarray(np.concatenate([s[k] for s in shards]))
            for k in shards[0]}


def main():
    cfg = get_arch("granite-3-2b").reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, remat="none")
    init_fn, upd_fn = adamw(lr=3e-3)
    tstep = make_train_step(model, upd_fn)

    params = model.init(jax.random.PRNGKey(0))
    opt = init_fn(params)
    mgr = CheckpointManager("/tmp/repro_elastic", keep=2)

    # ---- phase 1: healthy fleet (8 devices, 2x4 mesh) ----
    mesh8 = make_elastic_mesh(8, model_parallel=4)
    pshard8, oshard8 = shardings_for(mesh8, params, opt)
    params = jax.device_put(params, pshard8)
    opt = jax.device_put(opt, oshard8)
    with mesh8:
        step8 = jax.jit(tstep, in_shardings=(pshard8, oshard8, None),
                        out_shardings=(pshard8, oshard8, None))
        for step in range(6):
            params, opt, m = step8(params, opt, batch_for(step, cfg, 8))
            print(f"[8-dev {mesh8.shape}] step {step} "
                  f"loss {float(m['loss']):.4f}")
    mgr.save(6, {"params": params, "opt": opt}, blocking=False)
    mgr.wait()
    print("checkpoint committed at step 6 (async, atomic)")

    # ---- phase 2: two devices "fail" -> degraded 6-device mesh ----
    mesh6 = make_elastic_mesh(6, model_parallel=4)  # falls back to (3, 2)
    print(f"rebuilt degraded mesh: {dict(mesh6.shape)}")
    template = {"params": jax.tree.map(np.asarray, params),
                "opt": jax.tree.map(np.asarray, opt)}
    specs6 = {"params": shd.param_specs(mesh6, params),
              "opt": type(opt)(step=P(), m=shd.param_specs(mesh6, params),
                               v=shd.param_specs(mesh6, params))}
    state = mgr.restore(template, mesh=mesh6, specs=specs6)
    params6, opt6 = state["params"], state["opt"]
    pshard6, oshard6 = shardings_for(mesh6, params6, opt6)

    with mesh6:
        step6 = jax.jit(tstep, in_shardings=(pshard6, oshard6, None),
                        out_shardings=(pshard6, oshard6, None))
        losses_resumed = []
        for step in range(6, 10):
            params6, opt6, m = step6(params6, opt6, batch_for(step, cfg, 8))
            losses_resumed.append(float(m["loss"]))
            print(f"[6-dev {mesh6.shape}] step {step} "
                  f"loss {losses_resumed[-1]:.4f}")

    # ---- verify: identical to a never-interrupted continuation ----
    with mesh8:
        p_ref = jax.device_put(jax.tree.map(np.asarray, state["params"]),
                               pshard8)
        o_ref = jax.device_put(jax.tree.map(np.asarray, state["opt"]),
                               oshard8)
        losses_ref = []
        for step in range(6, 10):
            p_ref, o_ref, m = step8(p_ref, o_ref, batch_for(step, cfg, 8))
            losses_ref.append(float(m["loss"]))
    err = max(abs(a - b) for a, b in zip(losses_resumed, losses_ref))
    print(f"\nresumed-vs-reference loss trajectory max |Δ| = {err:.2e}")
    assert err < 1e-5
    print("elastic resume is exact: the degraded fleet continues the run")


if __name__ == "__main__":
    main()
