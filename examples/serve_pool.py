"""Distributed LLM inference on the computing-enabled storage pool —
the paper's case study (Fig 8b) at demo scale, on the *pool* path.

One request flows through the whole stack: the ``StoragePool`` frontend
admits it (an Ether-oN control frame carries the placement to the
chosen DockerSSD), the ``PoolRouter`` does least-loaded placement and
per-node admission control, and every generated token is ONE jitted
``shard_map``-ped decode step spanning all nodes — each ``model``-axis
shard of the PageStore is one node's HBM window, per-node paged
attention partials are merged with log-sum-exp collectives.  Mid-run a
node is killed: the heartbeat machinery drops its sequences and the
router re-prefills them on the survivors, reproducing the exact greedy
outputs of an uninterrupted run.

``--fault-plan`` additionally puts a seeded fault injector on the
fabric boundary (drops, CRC-caught corruption, duplicates, reordering
delays) — the reliable-delivery layer absorbs all of it and the outputs
still match token for token.

  PYTHONPATH=src python examples/serve_pool.py [--fault-plan lossy]
"""
import argparse
import dataclasses
import os
import re
import sys
import time

N_NODES = 4
# pool nodes are simulated as host devices; the count must be fixed
# before jax is imported
flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
               os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = \
    f"{flags} --xla_force_host_platform_device_count={N_NODES}".strip()

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import analytical as A
from repro.core.storage_pool import StoragePool
from repro.models.api import get_model
from repro.runtime.pool import PoolServer
from repro.runtime.scheduler import PoolRouter, Request
from repro.runtime.serve import PagedServer


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fault-plan", default="none",
                    help="seeded fabric fault plan for scenario 1 — a "
                         "preset name (none/lossy/storm), inline JSON, "
                         "or a path to a plan file "
                         "(repro.core.faults.load_plan)")
    args = ap.parse_args()
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_req, prompt_len, gen = 6, 24, 12
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
               for _ in range(n_req)]

    # single-node reference (the PR-1 path) for the equivalence check
    ref = PagedServer(model, params, page_size=8, hbm_pages=64,
                      dtype=jnp.float32)
    ref_out = {}
    for i, p in enumerate(prompts):
        ref_out[i] = [int(jnp.argmax(ref.add_request(i, p)))]
    for i, toks in ref.decode(gen - 1).items():
        ref_out[i] += toks

    # the pool: frontend -> Ether-oN control plane -> placement ->
    # mesh-sharded decode
    server = PoolServer(model, params, n_nodes=N_NODES, page_size=8,
                        hbm_pages_per_node=16, dtype=jnp.float32)
    pool = StoragePool(N_NODES, heartbeat_timeout=0.0)
    pool.attach_server(server)
    if args.fault_plan != "none":
        from repro.core.faults import load_plan
        pool.attach_faults(load_plan(args.fault_plan))
        print(f"fault injector armed: plan '{args.fault_plan}'")
    # horizon=4: four tokens per host interaction — the router admits,
    # evicts and polls heartbeats at horizon boundaries while the fused
    # on-device token loop runs uninterrupted in between
    router = PoolRouter(server, pool, max_active=n_req, horizon=4)
    t0 = time.monotonic()
    for i, p in enumerate(prompts):
        router.submit(Request(rid=i, prompt=p, max_tokens=gen))
    # a few horizons in, one DockerSSD dies mid-decode
    router.step()
    router.step()
    victim = server.node_of(0)
    dead_ip = pool.serving_ips()[victim]
    pool.nodes[dead_ip].fail()
    print(f"killed node {victim} ({dead_ip}) mid-decode")
    stats = router.run_to_completion()
    dt = time.monotonic() - t0

    by_id = {r.rid: r.output for r in router.finished}
    assert all(by_id[i][:len(ref_out[i])] == ref_out[i]
               for i in range(n_req)), "pool outputs diverged from 1-node"
    toks = sum(len(o) for o in by_id.values())
    print(f"served {n_req} requests x ({prompt_len} prompt + {gen} gen) "
          f"over {N_NODES} nodes in {dt:.1f}s — outputs identical to the "
          f"single-node path, {router.requeues} requeued after the failure")

    agg = server.tier_stats()
    print(f"aggregate tiered-KV telemetry: page_ins={agg['page_ins']} "
          f"page_outs={agg['page_outs']} hits={agg['hits']} "
          f"residency={agg['residency']:.2f}")
    for s, ns in enumerate(server.node_tier_stats()):
        mark = " (died)" if s not in server.alive_nodes() else ""
        print(f"  node {s}{mark}: hits={ns['hits']} "
              f"page_ins={ns['page_ins']} page_outs={ns['page_outs']}")
    ct = A.control_plane_terms(pool.driver.stats, toks)
    print(f"Ether-oN control plane: {ct['control_frames']:.0f} frames "
          f"({ct['frames_per_1k_tokens']:.1f}/1K tokens), "
          f"{ct['us_per_token']:.2f} us/token — off the decode hot path")
    if pool.fault_injector is not None:
        fs = pool.fault_injector.stats
        ds = pool.driver.stats
        print(f"chaos absorbed: {fs.dropped} dropped / {fs.corrupted} "
              f"corrupted / {fs.duplicated} duplicated / {fs.delayed} "
              f"delayed -> {ds.retransmits} retransmits, {ds.nacks} "
              f"NACKs, {ds.dup_frames} dups discarded — outputs still "
              f"identical")

    # --- scenario 2: one system prompt shared across the pool ----------
    # N requests carry the same 18-token template + distinct tails.  The
    # prefix-aware placement routes every sharer to the DockerSSD whose
    # index already holds the template pages (refcount shares, zero
    # prefill compute there), admissions run chunked, and the greedy
    # outputs must match a compute-everything cold run exactly.
    template = rng.integers(0, cfg.vocab_size, 18, dtype=np.int32)
    sp_prompts = [np.concatenate([template, rng.integers(
        0, cfg.vocab_size, 6, dtype=np.int32)]) for _ in range(n_req)]

    cold = PagedServer(model, params, page_size=8, hbm_pages=64,
                       dtype=jnp.float32, prefix_cache=False)
    cold_out = {}
    for i, p in enumerate(sp_prompts):
        cold_out[i] = [int(jnp.argmax(cold.add_request(i, p)))]
    for i, toks in cold.decode(gen).items():
        cold_out[i] += toks

    # per-node window sized for the whole shared-template cohort: the
    # prefix-aware placement sends every sharer to the owning node, so
    # that one window must hold template + n_req private extents
    warm_srv = PoolServer(model, params, n_nodes=N_NODES, page_size=8,
                          hbm_pages_per_node=32, dtype=jnp.float32)
    warm_pool = StoragePool(N_NODES)
    warm_pool.attach_server(warm_srv)
    warm_out = {}
    for i, p in enumerate(sp_prompts):
        node = warm_pool.place_sequence(i, len(p) + gen, prompt=p)
        warm_out[i] = [int(jnp.argmax(
            warm_srv.add_request(i, p, node=node, chunk=8)))]
    for i, toks in warm_srv.decode(gen).items():
        warm_out[i] += toks

    assert warm_out == cold_out, \
        "shared-prefix pool outputs diverged from the cold run"
    owner = warm_srv.node_of(0)
    assert all(warm_srv.node_of(i) == owner for i in range(n_req)), \
        "prefix-aware placement scattered the template's sharers"
    hits = [ns["prefix_hits"] for ns in warm_srv.node_tier_stats()]
    assert hits[owner] > 0 and sum(hits) == hits[owner], \
        f"prefix hits off the owning node: {hits}"
    print(f"\nshared system prompt: {n_req} requests, one template — all "
          f"routed to owning node {owner} ({hits[owner]} page hits, "
          f"hit rate {warm_srv.prefix_hit_rate():.2f}), outputs "
          f"identical to the cold run")

    # --- scenario 3: speculative decoding on a repetitive stream -------
    # Prompts whose tail already carries the continuation (constant
    # runs the demo model self-sustains): the prompt-lookup drafter
    # copies candidates out of the prompt, ONE chunk-shaped pass
    # verifies them, and the pool commits whole accepted prefixes per
    # host interaction.  Outputs must be token-identical to the plain
    # fused horizon.
    spec_prompts = [np.asarray([c] * 24 + [t] * 16, np.int32)
                    for c, t in ((41, 49), (500, 259))]
    spec_srv = PoolServer(model, params, n_nodes=N_NODES, page_size=8,
                          hbm_pages_per_node=32, dtype=jnp.float32)
    spec_pool = StoragePool(N_NODES)
    spec_pool.attach_server(spec_srv)
    spec_gen = 24

    def spec_run(speculative):
        for s in list(spec_srv.sequence_ids()):
            spec_srv.free_sequence(s)
        out = {}
        for i, p in enumerate(spec_prompts):
            node = spec_pool.place_sequence(i, len(p) + spec_gen, prompt=p)
            out[i] = [int(jnp.argmax(
                spec_srv.add_request(i, p, node=node)))]
        for i, toks in spec_srv.decode(spec_gen, horizon=8,
                                       speculative=speculative).items():
            out[i] += toks
        return out

    plain_out = spec_run(False)
    spec_srv.reset_speculation_stats()
    spec_out = spec_run(True)
    assert spec_out == plain_out, \
        "speculative pool outputs diverged from the plain horizon"
    st = spec_srv.speculation_stats()
    assert st["passes"] > 0 and st["drafted"] > 0, \
        "repetitive prompts produced no speculative passes"
    print(f"\nspeculative decode: alpha={st['alpha']:.2f} over "
          f"{st['passes']} draft-verify passes "
          f"(accepted-length hist {st['accepted_len_hist']}) — outputs "
          f"identical to the plain fused horizon")

    # --- scenario 4: elastic pool — scale up under load, drain back ----
    # The same PoolServer capacity bucket serves with 2 of 4 nodes;
    # load arrives, the pool grows to 4 (parked shards re-join — the
    # compiled mesh programs never retrace), then drains back to 2 with
    # sequences still decoding: resident pages migrate device-to-device
    # over MIGRATE frames and outputs stay token-identical to a pool
    # that ran at 4 nodes the whole time, with zero requests shed.
    from repro.runtime.serve import SamplingConfig
    el_prompts = [rng.integers(0, cfg.vocab_size, prompt_len,
                               dtype=np.int32) for _ in range(6)]
    el_gens = [10, 12, 9, 11, 10, 12]
    samp = SamplingConfig(temperature=0.8, top_p=0.9, seed=11)

    def elastic_run(elastic):
        srv = PoolServer(model, params, n_nodes=N_NODES,
                         active=(2 if elastic else None), page_size=8,
                         hbm_pages_per_node=32, dtype=jnp.float32)
        epool = StoragePool(2 if elastic else N_NODES,
                            heartbeat_timeout=1e9)
        epool.attach_server(srv)
        erouter = PoolRouter(srv, epool, max_active=6, horizon=4,
                             prefill_chunk=8, sampling=samp)
        phase_of = {}
        for i, (p, g) in enumerate(zip(el_prompts[:3], el_gens[:3])):
            erouter.submit(Request(rid=i, prompt=p, max_tokens=g))
            phase_of[i] = "2-node"
        if elastic:
            erouter.step(); erouter.step()
            epool.scale_to(4)            # wire + activate parked shards
        for i, (p, g) in enumerate(zip(el_prompts[3:], el_gens[3:]),
                                   start=3):
            erouter.submit(Request(rid=i, prompt=p, max_tokens=g))
            phase_of[i] = "4-node"
        if elastic:
            # decode until the new nodes actually host live sequences,
            # so the drain-back exercises live page migration
            guard = 0
            while guard < 60 and not (
                    srv.table.sequences_on_shard(2)
                    and srv.table.sequences_on_shard(3)):
                erouter.step()
                guard += 1
            for node in (3, 2):
                epool.drain_serving_node(node)
            for i in list(erouter.active) + list(erouter.prefilling):
                phase_of[i] = "drain-back"
        erouter.run_to_completion()
        return ({r.rid: list(r.output) for r in erouter.finished},
                erouter, epool, srv, phase_of)

    fix_out, fix_r, _, _, _ = elastic_run(False)
    el_out, el_r, el_pool, el_srv, phase_of = elastic_run(True)
    assert el_out == fix_out, \
        "elastic outputs diverged from the fixed-4-node run"
    assert not el_r.rejected and not fix_r.rejected, \
        "elastic scaling shed requests"
    est = el_pool.driver.stats
    assert est.migrate_frames > 0, "drain-back migrated no pages"
    print(f"\nelastic pool: 2 -> 4 -> 2 nodes under load "
          f"(temperature={samp.temperature}) — outputs identical to a "
          f"fixed 4-node run, 0 shed, {est.migrate_frames} pages "
          f"migrated warm ({est.migrate_bytes} bytes over MIGRATE "
          f"frames), alive={el_srv.alive_nodes()}")
    for ph in ("2-node", "4-node", "drain-back"):
        reqs = [r for r in el_r.finished if phase_of.get(r.rid) == ph]
        if not reqs:
            continue
        ttft = [r.t_first - r.t_arrive for r in reqs]
        tpot = [(r.t_done - r.t_first) / max(len(r.output) - 1, 1)
                for r in reqs]
        print(f"  {ph:>10}: {len(reqs)} req | TTFT p50 "
              f"{np.percentile(ttft, 50)*1e3:.0f} / p99 "
              f"{np.percentile(ttft, 99)*1e3:.0f} ms | TPOT p50 "
              f"{np.percentile(tpot, 50)*1e3:.1f} / p99 "
              f"{np.percentile(tpot, 99)*1e3:.1f} ms")

    # what this buys at full scale (paper Fig 12b, our analytical model):
    res = A.evaluate_pool()
    r = A.headline_ratios(res)
    print(f"\nfull-scale verdict (analytical, 8 LLMs, seq 32K): "
          f"D-Cache beats H-Cache {r['d_cache_vs_h_cache']:.1f}x "
          f"(paper: 7.9x), H-NoCache {r['d_cache_vs_h_nocache']:.0f}x "
          f"(paper: 3.2Kx)")


if __name__ == "__main__":
    main()
