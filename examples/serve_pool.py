"""Distributed LLM inference on the computing-enabled storage pool —
the paper's case study (Fig 8b) at demo scale.

Serves a small GQA decoder with batched requests through the **tiered
paged KV cache** (host-side PageTableManager + device PageStore with
stacked per-layer pages) and the Pallas ``paged_attention`` kernel —
each generated token is ONE jitted decode step for the whole batch and
every layer.  Reports the D-Cache-style telemetry (page-ins/outs,
prefetch hits) plus the analytical pool model's verdict for the
full-size systems.

  PYTHONPATH=src python examples/serve_pool.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import analytical as A
from repro.models.api import get_model
from repro.runtime.serve import PagedServer


def main():
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    # deliberately small HBM window -> the flash tier gets exercised
    server = PagedServer(model, params, page_size=8,
                         hbm_pages=12, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    n_req, prompt_len, gen = 3, 24, 16
    t0 = time.time()
    for i in range(n_req):
        prompt = rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
        server.add_request(i, prompt)
    # the HBM window holds two active requests; the third spills to the
    # flash tier and pages back in when its turn comes (D-Cache tiering)
    out = server.decode(gen, seqs=[0, 1])
    out.update(server.decode(gen, seqs=[2]))
    dt = time.time() - t0
    toks = n_req * (prompt_len + gen)
    print(f"served {n_req} requests x ({prompt_len} prompt + {gen} gen) "
          f"= {toks} tokens in {dt:.1f}s")
    stats = server.tier_stats()
    print(f"tiered-KV telemetry: page_ins={stats['page_ins']} "
          f"page_outs={stats['page_outs']} hits={stats['hits']} "
          f"prefetch_hits={stats['prefetch_hits']} "
          f"residency={stats['residency']:.2f}")
    print("sample generations:", {k: v[:6] for k, v in out.items()})

    # what this buys at full scale (paper Fig 12b, our analytical model):
    res = A.evaluate_pool()
    r = A.headline_ratios(res)
    print(f"\nfull-scale verdict (analytical, 8 LLMs, seq 32K): "
          f"D-Cache beats H-Cache {r['d_cache_vs_h_cache']:.1f}x "
          f"(paper: 7.9x), H-NoCache {r['d_cache_vs_h_nocache']:.0f}x "
          f"(paper: 3.2Kx)")


if __name__ == "__main__":
    main()
