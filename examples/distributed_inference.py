"""Distributed inference across pool nodes with pipeline parallelism —
the paper's Fig 8b flow run concretely: a small decoder's layers are
partitioned over DockerSSD nodes (PP stages), each stage executes its
layer slice as a containerized task, activations hop stage-to-stage over
Ether-oN, and the pool survives a mid-run node failure.

  PYTHONPATH=src python examples/distributed_inference.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core import (SHARABLE_NS, StoragePool, make_blob, ImageManifest,
                        register_app)
from repro.models import layers as L
from repro.models.api import get_model

CFG = dataclasses.replace(
    get_arch("granite-3-2b"),
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
    vocab_size=512)
MODEL = get_model(CFG, compute_dtype=jnp.float32)
PARAMS = MODEL.init(jax.random.PRNGKey(0))


@register_app("llm-stage")
def llm_stage(ctx, stage: int = 0, n_stages: int = 2):
    """One pipeline stage: run my slice of layers on the activation
    fetched from my sharable namespace."""
    ctx.bind("/act/in.npy")
    h = np.frombuffer(ctx.fs.read("/act/in.npy", SHARABLE_NS),
                      np.float32).reshape(1, -1, CFG.d_model)
    ctx.release("/act/in.npy")
    h = jnp.asarray(h)
    per = CFG.n_layers // n_stages
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)[None, :]
    for li in range(stage * per, (stage + 1) * per):
        lp = jax.tree.map(lambda a: a[li], PARAMS["layers"])
        a = L.apply_norm(lp["attn_norm"], h, CFG.norm)
        h = h + L.attention_block(lp["attn"], a, CFG, positions=positions)
        m = L.apply_norm(lp["mlp_norm"], h, CFG.norm)
        h = h + L.apply_mlp(lp["mlp"], m, CFG.act)
    ctx.log(f"stage {stage}: ran layers {stage*per}..{(stage+1)*per-1}")
    return np.asarray(h)


def run_pipeline(pool, placement, tokens):
    """Drive microbatches through the stages over the pool."""
    h = np.asarray(L.embed_tokens(PARAMS["embed"], jnp.asarray(tokens),
                                  jnp.float32), np.float32)
    stages = sorted(set(placement.stage_of.values()))
    for stage in stages:
        ip = [i for i in placement.node_ips
              if placement.stage_of[i] == stage][0]
        node = pool.nodes[ip]
        node.fs.write("/act/in.npy", h.tobytes(), SHARABLE_NS, actor="host")
        cid, h = node.docker.cmd_run("llm-stage", stage=stage,
                                     n_stages=len(stages))
    h = np.asarray(L.apply_norm(PARAMS["final_norm"], jnp.asarray(h),
                                CFG.norm))
    logits = np.asarray(L.unembed(PARAMS["embed"], PARAMS.get("lm_head"),
                                  jnp.asarray(h), CFG.tie_embeddings))
    return logits


def main():
    pool = StoragePool(n_nodes=4)
    blob = make_blob(ImageManifest("llm-stage", "llm-stage", ["weights"]),
                     {"weights": b"stage-shard"})
    pool.broadcast_pull("llm-stage", blob)
    pl = pool.place_distributed("llm", "llm-stage", pp=2)
    print(f"pipeline placement: {pl.stage_of}")

    tokens = np.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], np.int32)
    logits = run_pipeline(pool, pl, tokens)

    # verify against the monolithic model
    ref, _ = MODEL.forward(PARAMS, {"tokens": jnp.asarray(tokens)})
    err = float(np.abs(logits - np.asarray(ref)).max())
    print(f"pipelined-vs-monolithic max err: {err:.2e}")
    assert err < 1e-3

    # node failure mid-service: reschedule, run again, same answer
    victim = pl.node_ips[0]
    pool.nodes[victim].fail()
    pool.check_heartbeats(now=1e9)
    print(f"failed {victim} -> {pool.events[-1]}")
    logits2 = run_pipeline(pool, pool.placements["llm"], tokens)
    err2 = float(np.abs(logits2 - np.asarray(ref)).max())
    print(f"after reschedule, max err: {err2:.2e}")
    assert err2 < 1e-3
    print("pipelined inference survived the failure with identical output")


if __name__ == "__main__":
    main()
