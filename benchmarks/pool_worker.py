"""Subprocess worker for ``benchmarks/run.py pool``.

One pool size per process: the simulated node count is an XLA device
count, which must be fixed before jax is imported, so the parent
benchmark launches one worker per cell.  Prints a JSON record on
stdout: tokens/s of the batched decode, the greedy outputs and the
prefill logits (the parent checks every pool size against the 1-node
``PagedServer`` reference to 1e-4), tier telemetry and the Ether-oN
control-plane terms.

``--mode degraded`` runs the failure cell instead: the same workload
through the PoolRouter with one node killed mid-run (plus optional
``--fault-plan`` fabric chaos) — outputs must match the uninterrupted
reference, and the record carries recovery latency and the goodput dip.

  python benchmarks/pool_worker.py --nodes 4 [--mode pool|single|degraded] \
      [--requests 6 --prompt-len 24 --gen 16] [--fault-plan lossy]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, required=True)
    ap.add_argument("--mode", choices=["pool", "single", "degraded"],
                    default="pool")
    ap.add_argument("--fault-plan", default="none",
                    help="degraded mode: seeded fabric fault plan "
                         "layered on the mid-run kill — a preset name "
                         "(none/lossy/storm), inline JSON, or a path "
                         "(repro.core.faults.load_plan)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused decode-horizon length for the horizon "
                         "cell (0 disables)")
    ap.add_argument("--page-dtype", choices=["fp32", "int8", "fp8"],
                    default="fp32",
                    help="KV page storage format (quantized pages "
                         "decode through the fused-dequant kernel)")
    args = ap.parse_args()

    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_"
                               f"device_count={args.nodes}").strip()
    sys.path.insert(0, os.path.join(REPO, "src"))

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_arch
    from repro.core import analytical as A
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model

    # the demo config of examples/serve_pool.py / BENCH_serve.json
    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, args.prompt_len,
                            dtype=np.int32) for _ in range(args.requests)]

    rec = {"nodes": args.nodes, "mode": args.mode,
           "page_dtype": args.page_dtype}

    if args.mode == "degraded":
        # -- degraded-mode cell: the main workload through the
        # PoolRouter with one DockerSSD killed mid-run (optionally
        # under --fault-plan fabric chaos).  An uninterrupted run on an
        # identically warmed stack is the reference: the chaos run must
        # finish every request with token-identical output, and the
        # record carries the recovery latency (kill -> every victim
        # sequence re-placed and decoding on a survivor) and the
        # goodput dip the failure cost.
        from repro.core.faults import load_plan
        from repro.runtime.pool import PoolServer
        from repro.runtime.scheduler import PoolRouter, Request

        def fresh():
            server = PoolServer(
                model, params, n_nodes=args.nodes,
                page_size=args.page_size,
                hbm_pages_per_node=-(-8 * args.requests // args.nodes),
                dtype=jnp.float32, page_dtype=args.page_dtype)
            pool = StoragePool(args.nodes, heartbeat_timeout=0.0)
            pool.attach_server(server)
            if args.fault_plan != "none":
                pool.attach_faults(load_plan(args.fault_plan))
            return server, pool

        def drive(server, pool, kill):
            """One full workload pass through a fresh router.  ``kill``
            fails the node owning the first active sequence once decode
            is under way (iteration 2 — mid-run, after the first
            horizon)."""
            router = PoolRouter(server, pool, max_active=args.requests,
                                horizon=max(args.horizon, 1),
                                prefill_chunk=2 * args.page_size)
            for i, p in enumerate(prompts):
                router.submit(Request(rid=i, prompt=p,
                                      max_tokens=args.gen))
            timeline = []             # (step wall s, tokens emitted)
            victims, killed, t_kill, recovery_s = [], None, None, None
            while router.waiting or router.prefilling or router.active:
                if kill and t_kill is None and router.active:
                    rid = next(iter(router.active))
                    killed = server.node_of(rid)
                    victims = [r for r in list(router.active)
                               if server.node_of(r) == killed]
                    pool.nodes[pool.serving_ips()[killed]].fail()
                    t_kill = time.perf_counter()
                t0 = time.perf_counter()
                n = router.step()
                timeline.append((time.perf_counter() - t0, n))
                if t_kill is not None and recovery_s is None:
                    done = {f.rid for f in router.finished}
                    if all(r in router.active or r in done
                           for r in victims):
                        recovery_s = time.perf_counter() - t_kill
            out = {r.rid: list(r.output) for r in router.finished}
            return out, timeline, router, killed, recovery_s

        # reference: one untimed pass warms the jit buckets (admission
        # chunks, horizon steps), then the timed uninterrupted run
        server, pool = fresh()
        drive(server, pool, kill=False)
        ref_out, ref_tl, _, _, _ = drive(server, pool, kill=False)

        # chaos: same warm-up discipline on a fresh stack (a killed
        # node cannot be revived), then the timed run with the kill
        server, pool = fresh()
        drive(server, pool, kill=False)
        out, tl, router, killed, recovery_s = drive(server, pool,
                                                    kill=True)

        assert out == ref_out, \
            "degraded run diverged from the uninterrupted reference"
        assert recovery_s is not None, "victim sequences never recovered"
        toks = args.requests * args.gen
        ref_s = sum(dt for dt, _ in ref_tl)
        deg_s = sum(dt for dt, _ in tl)
        st = pool.driver.stats
        rec["degraded"] = {
            "killed_node": killed,
            "fault_plan": args.fault_plan,
            "outputs_identical_after_kill": out == ref_out,
            "recovery_s": recovery_s,
            "requeues": router.requeues,
            "rejected": len(router.rejected),
            "ref_tokens_per_s": toks / ref_s,
            "degraded_tokens_per_s": toks / deg_s,
            "goodput_vs_uninterrupted": ref_s / deg_s,
            "steps_ref": len(ref_tl),
            "steps_degraded": len(tl),
            "retransmits": st.retransmits,
            "nacks": st.nacks,
            "dup_frames": st.dup_frames,
        }
        if pool.fault_injector is not None:
            rec["degraded"]["faults"] = \
                pool.fault_injector.stats.as_dict()
        print(json.dumps(rec))
        return

    if args.mode == "single":
        from repro.runtime.serve import PagedServer
        server = PagedServer(model, params, page_size=args.page_size,
                             hbm_pages=8 * args.requests,
                             dtype=jnp.float32,
                             page_dtype=args.page_dtype)
        pool = None
    else:
        from repro.runtime.pool import PoolServer
        server = PoolServer(
            model, params, n_nodes=args.nodes, page_size=args.page_size,
            hbm_pages_per_node=-(-8 * args.requests // args.nodes),
            dtype=jnp.float32, page_dtype=args.page_dtype)
        pool = StoragePool(args.nodes)
        pool.attach_server(server)

    # admission through the frontend (pool mode: placement rides an
    # Ether-oN control frame to the chosen node before the shard admits)
    logits = []
    for i, p in enumerate(prompts):
        if pool is not None:
            node = pool.place_sequence(i, args.prompt_len + args.gen)
            last = server.add_request(i, p, node=node)
        else:
            last = server.add_request(i, p)
        logits.append(np.asarray(last, np.float64).tolist())

    def readmit():
        for s in list(server.sequence_ids()):
            server.free_sequence(s)
        for i, p in enumerate(prompts):
            if pool is not None:
                node = pool.place_sequence(i, args.prompt_len + args.gen)
                server.add_request(i, p, node=node)
            else:
                server.add_request(i, p)

    reps = 3                          # best-of-N per cell (noise guard)

    def timed(horizon):
        """Best-of-``reps`` timed decodes from identical re-admitted
        states; the caller warms the shape buckets first, so jit
        tracing never contaminates a cell."""
        best = None
        for _ in range(reps):
            readmit()
            t0 = time.perf_counter()
            server.decode(args.gen, horizon=horizon)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best = dt
        return best

    out = server.decode(args.gen)          # warm per-token + canonical out
    dt = timed(None)

    toks = args.requests * args.gen
    rec["tokens_per_s"] = toks / dt
    rec["decode_s"] = dt
    rec["outputs"] = {int(k): [int(t) for t in v] for k, v in out.items()}

    if args.horizon > 0:
        readmit()
        out_h = server.decode(args.gen, horizon=args.horizon)   # warm
        assert out_h == out, "horizon decode diverged from per-token"
        dt_h = timed(args.horizon)
        rec["horizon"] = args.horizon
        rec["tokens_per_s_horizon"] = toks / dt_h
        rec["decode_s_horizon"] = dt_h
        rec["horizon_outputs_match"] = True
    rec["prefill_logits"] = logits
    rec["tier"] = {k: v for k, v in server.tier_stats().items()}
    if pool is not None:
        rec["node_tier"] = server.node_tier_stats()
        # control-plane terms over ONE placement round (a place frame
        # per request), not the cumulative warm-up admissions:
        # delta-account the driver stats around a single readmit, the
        # same discipline the isp bench applies to its data plane
        import copy
        import types
        s0 = copy.copy(vars(pool.driver.stats))
        readmit()
        delta = types.SimpleNamespace(**{
            k: v - s0[k] for k, v in vars(pool.driver.stats).items()})
        rec["control_plane"] = A.control_plane_terms(delta, toks)

    # -- shared-prefix cell: one system template across every request --
    # cold = the prefix cache ablated (every prompt token computed,
    # chunked); warm = the same prompts re-admitted with the cache
    # seeded by an untimed round.  Outputs must be token-identical cold
    # vs warm; in pool mode the prefix-aware placement routes every
    # sharer to the node whose index holds the template.
    chunk = 2 * args.page_size
    shared = 3 * args.prompt_len // 4
    sp_template = rng.integers(0, cfg.vocab_size, shared, dtype=np.int32)
    sp_prompts = [np.concatenate([sp_template, rng.integers(
        0, cfg.vocab_size, args.prompt_len - shared, dtype=np.int32)])
        for _ in range(args.requests)]

    def sp_free():
        for s in list(server.sequence_ids()):
            server.free_sequence(s)

    def sp_admit(ps):
        for i, p in enumerate(ps):
            if pool is not None:
                node = pool.place_sequence(
                    i, args.prompt_len + args.gen, prompt=p)
                server.add_request(i, p, node=node, chunk=chunk)
            else:
                server.add_request(i, p, chunk=chunk)

    def sp_decode():
        # one sequence at a time: the prefix-aware placement
        # concentrates the cohort on the owning node, whose window only
        # has to hold the ACTIVE working set — idle sharers' unshared
        # pages spill to that node's flash tier and page back, the
        # shared template pages never move
        pend = server.pending_tokens()
        out = {}
        for i in range(args.requests):
            out[i] = [pend[i]] + server.decode(args.gen, seqs=[i])[i]
        return out

    sp_free()
    server.prefix_cache = False
    sp_admit(sp_prompts)             # untimed cold-shape bucket warm-up
    sp_free()
    t0 = time.perf_counter()
    sp_admit(sp_prompts)
    t_cold = time.perf_counter() - t0
    out_cold = sp_decode()
    sp_free()

    server.prefix_cache = True
    sp_admit(sp_prompts)             # untimed: seeds the prefix cache
    sp_free()
    sp_admit(sp_prompts)             # untimed warm-shape bucket warm-up
    sp_free()
    s_tok0 = server.table.stats.prefix_tokens
    c_tok0 = server.prefill_tokens_computed
    t0 = time.perf_counter()
    sp_admit(sp_prompts)
    t_warm = time.perf_counter() - t0
    owner = server.node_of(0) if pool is not None else None
    saved = server.table.stats.prefix_tokens - s_tok0
    computed = server.prefill_tokens_computed - c_tok0
    out_warm = sp_decode()
    assert out_warm == out_cold, \
        "warm (shared-prefix) outputs diverged from the cold run"
    rec["shared_prefix"] = {
        "shared_fraction": shared / args.prompt_len,
        "prefill_chunk": chunk,
        "cold_admission_s": t_cold,
        "warm_admission_s": t_warm,
        "warm_speedup": t_cold / t_warm,
        "prefix_hit_rate": saved / max(saved + computed, 1),
        "prefill_tokens_per_s": {
            "cold": args.requests * args.prompt_len / t_cold,
            "warm_admitted": args.requests * args.prompt_len / t_warm,
        },
        "outputs_identical_warm_vs_cold": True,
    }
    if pool is not None:
        rec["shared_prefix"]["owner_node"] = owner
        rec["shared_prefix"]["node_prefix_hits"] = [
            ns["prefix_hits"] for ns in server.node_tier_stats()]
    sp_free()

    # -- speculative cell: prompt-lookup draft-verify vs the plain
    # horizon, the serve_decode cell's workload on this pool size.
    # Repetitive prompts carry their own continuation in the tail
    # (constant runs the demo model self-sustains), so the drafter
    # copies successors out of the prompt from the first pass; outputs
    # must stay token-identical to the plain fused horizon.
    spec_gen, spec_h = 48, 16
    spec_prompts = [np.asarray([c] * (24 + i % 2) + [t] * 16, np.int32)
                    for i, (c, t) in
                    enumerate([(41, 49), (500, 259)] * 2)]
    # the cell inherits the main workload's window, and a spec
    # sequence's whole reservation (prompt + gen pages) is resident and
    # pinned by the last pass.  Size the reservation against the
    # per-shard window: when the default gen doesn't fit one node's
    # share, fall back to the largest gen (and a matching horizon) that
    # does, instead of skipping the cell (capacity-guarded placement
    # disperses sharers once the prefix node's window fills, so a shard
    # holds at most its even cohort share).
    max_plen = max(len(p) for p in spec_prompts)
    if pool is None:
        window = 8 * args.requests
        seqs_here = len(spec_prompts)
    else:
        window = -(-8 * args.requests // args.nodes)       # one shard
        seqs_here = -(-len(spec_prompts) // args.nodes)
    gen_fit = (window // seqs_here) * args.page_size - max_plen
    spec_window_limited = gen_fit < spec_gen
    if spec_window_limited:
        spec_gen = gen_fit
        spec_h = max(1, min(spec_h, spec_gen))
    if args.horizon > 0 and spec_gen < 2:
        rec["speculative"] = {"skipped":
                              "per-node window below one sequence's "
                              "prompt pages — no gen fits"}
    if args.horizon > 0 and spec_gen >= 2:

        def spec_admit():
            sp_free()
            for i, p in enumerate(spec_prompts):
                if pool is not None:
                    node = pool.place_sequence(
                        i, len(p) + spec_gen, prompt=p)
                    server.add_request(i, p, node=node)
                else:
                    server.add_request(i, p)

        def spec_timed(horizon, speculative):
            spec_admit()
            server.decode(spec_gen, horizon=horizon,
                          speculative=speculative)     # bucket warm-up
            best, out, stats = None, None, None
            for _ in range(reps):
                spec_admit()
                server.reset_speculation_stats()
                t0 = time.perf_counter()
                o = server.decode(spec_gen, horizon=horizon,
                                  speculative=speculative)
                dt = time.perf_counter() - t0
                if best is None or dt < best:
                    best, out = dt, o
                    stats = server.speculation_stats()
            toks = sum(len(v) for v in out.values())
            return toks / best, out, stats

        base_tps, base_out, _ = spec_timed(args.horizon, False)
        spec_tps, spec_out, st = spec_timed(spec_h, True)
        assert spec_out == base_out, \
            "speculative decode diverged from the plain horizon"
        rec["speculative"] = {
            "gen": spec_gen, "spec_horizon": spec_h,
            "window_limited": spec_window_limited,
            "base_tokens_per_s": base_tps,
            "spec_tokens_per_s": spec_tps,
            "speedup_vs_horizon": spec_tps / base_tps,
            "alpha": st["alpha"],
            "passes": st["passes"],
            "fallback_passes": st["fallback_passes"],
            "accepted_len_hist": {str(k): v for k, v
                                  in st["accepted_len_hist"].items()},
            "outputs_identical": True,
        }
        sp_free()

    # -- latency percentiles: the main workload through the continuous
    # batcher (iteration-level admission) — per-request p50/p99 TTFT
    # and TPOT, the traffic-facing face of the aggregate tok/s above
    from repro.runtime.scheduler import (ContinuousBatcher, PoolRouter,
                                         Request)

    def lat_run():
        sp_free()
        kw = dict(max_active=args.requests,
                  horizon=max(args.horizon, 1),
                  prefill_chunk=2 * args.page_size)
        sched = (PoolRouter(server, pool, **kw) if pool is not None
                 else ContinuousBatcher(server, **kw))
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_tokens=args.gen))
        return sched.run_to_completion()

    # two untimed warm-ups: the first traces cache-cold buckets and
    # seeds the prefix cache, the second traces the warm-hit buckets
    # the steady-state (timed) run actually uses
    lat_run()
    lat_run()
    lat = lat_run()
    rec["latency"] = {k: lat[k] for k in
                      ("requests", "mean_ttft_s", "p50_ttft_s",
                       "p99_ttft_s", "mean_tpot_s", "p50_tpot_s",
                       "p99_tpot_s", "mean_latency_s", "p99_latency_s")}
    sp_free()
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
