"""Subprocess worker for the ``benchmarks/run.py pool`` autoscale cell.

Open-loop Poisson traffic against an elastic pool: a seeded arrival
process (exponential inter-arrivals on the scheduler-iteration clock,
so the trace replays bit-for-bit) walks through three phases —

  * **steady**: a rate the initial serving set handles inside SLO
    (this phase also calibrates the declared TTFT target),
  * **burst**: several times the steady rate — the backlog breaches the
    SLO and the :class:`~repro.runtime.autoscaler.Autoscaler` grows the
    serving set one node per cooldown,
  * **cooldown**: a trickle — sustained headroom drains the pool back
    down with live sequences still decoding (the zero-drop invariant).

Open-loop means arrivals NEVER wait for completions: the generator
submits on schedule whether or not the pool is keeping up, which is
what makes queue depth an honest SLO signal.

The record carries per-phase p50/p99 TTFT/TPOT (requests bucketed by
arrival phase), every scale decision, the SLO-recovery latencies, the
MIGRATE counters and the analytical migration terms.  Hard floors are
asserted in-process — zero shed requests, at least one scale-up and one
drain, a recorded breach->healthy recovery, and exactly zero MIGRATE
frames before the first drain — so the CI quick lane fails loudly, not
quietly.

  python benchmarks/autoscale_worker.py --nodes 4 --initial 2 [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pct(xs, q):
    import numpy as np
    return float(np.percentile(xs, q)) if xs else 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4,
                    help="pow2 capacity bucket (XLA device count)")
    ap.add_argument("--initial", type=int, default=2,
                    help="serving nodes at t=0 (also the drain floor)")
    ap.add_argument("--quick", action="store_true",
                    help="shorter phases for the CI smoke lane")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=4)
    args = ap.parse_args()

    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_"
                               f"device_count={args.nodes}").strip()
    sys.path.insert(0, os.path.join(REPO, "src"))

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_arch
    from repro.core import analytical as A
    from repro.core.storage_pool import StoragePool
    from repro.models.api import get_model
    from repro.runtime.autoscaler import Autoscaler, ServingSLO
    from repro.runtime.pool import PoolServer
    from repro.runtime.scheduler import PoolRouter, Request

    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    # -- the seeded open-loop arrival trace ----------------------------------
    # (phase name, length in scheduler iterations, arrivals per iteration)
    if args.quick:
        phases = [("steady", 8, 0.4), ("burst", 8, 3.5),
                  ("cooldown", 30, 0.08)]
    else:
        phases = [("steady", 16, 0.4), ("burst", 12, 4.0),
                  ("cooldown", 50, 0.08)]
    rng = np.random.default_rng(args.seed)
    arrivals = []                       # (iteration, phase, rid, gen)
    base, rid = 0, 0
    for name, iters, rate in phases:
        t = float(base)
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= base + iters:
                break
            arrivals.append((t, name, rid, int(4 + rng.integers(0, 5))))
            rid += 1
        base += iters
    horizon_iters = base
    # the burst must exist even under an unlucky seed — the cell is
    # about the response to overload, not about sampling overload
    assert sum(1 for a in arrivals if a[1] == "burst") >= 3, \
        "seed produced no burst; pick another --seed"
    # one long-running straggler at the start of cooldown keeps live
    # pages on the pool while the drains fire — the warm path (device
    # page migration) is exercised, not just empty-node parks
    long_start = sum(i for _, i, _ in phases[:2])
    arrivals.append((float(long_start), "cooldown", rid, 40))
    arrivals.sort()
    prompts = {r: rng.integers(0, cfg.vocab_size, args.prompt_len,
                               dtype=np.int32)
               for _, _, r, _ in arrivals}

    # batch slots scale with the serving set: 3 per node, retuned live
    # after every membership change (the whole point of scaling up is
    # more concurrent decode, not just more KV room)
    SLOTS_PER_NODE = 3

    def fresh_router(server, pool):
        return PoolRouter(server, pool,
                          max_active=SLOTS_PER_NODE * args.initial,
                          horizon=4, prefill_chunk=2 * args.page_size)

    server = PoolServer(model, params, n_nodes=args.nodes,
                        active=args.initial, page_size=args.page_size,
                        hbm_pages_per_node=32, dtype=jnp.float32)
    pool = StoragePool(args.initial, heartbeat_timeout=1e9)
    pool.attach_server(server)

    # a maintenance drain mid-cooldown targets the node HOSTING the
    # long-running sequence — the autoscaler's own scale-downs pick the
    # emptiest node (warm path trivially), so the live-page migration
    # cost is exercised the way it occurs in production: an operator
    # retiring a loaded node while it is still decoding
    maint_iter = long_start + 6
    maint = {}

    def drive(router, asc=None, trace=None, maintenance=False):
        """Run an arrival trace open-loop; returns finished requests
        tagged with their arrival phase."""
        phase_of = {}
        queue = list(arrivals if trace is None else trace)
        it = 0
        # after the last request drains, keep the controller ticking
        # through a quiet grace period: burst-era samples age out of
        # the freshness window, the breach closes (the recovery stamp),
        # and sustained headroom walks the pool back down
        grace = (asc.window + asc.sustain + 3 * asc.cooldown + 8
                 if asc is not None else 0)
        while (queue or router.waiting or router.prefilling
               or router.active or grace > 0):
            busy = bool(queue or router.waiting or router.prefilling
                        or router.active)
            if not busy:
                grace -= 1
            while queue and queue[0][0] <= it:
                _, ph, r, gen = queue.pop(0)
                phase_of[r] = ph
                router.submit(Request(rid=r, prompt=prompts[r],
                                      max_tokens=gen))
            if asc is not None:
                asc.tick()
                router.max_active = \
                    SLOTS_PER_NODE * len(server.alive_nodes())
                if os.environ.get("ASC_DEBUG"):
                    m = asc.metrics()
                    print(f"it={it} alive={len(server.alive_nodes())} "
                          f"q={m['queue_depth']} p99={m['p99_ttft_s']:.3f} "
                          f"idle={asc._idle_ticks} "
                          f"breach={asc._breach_since is not None} "
                          f"act={len(router.active)} "
                          f"pre={len(router.prefilling)}",
                          file=sys.stderr)
            if maintenance and it >= maint_iter and not maint:
                # retire the most-loaded node while it still holds live
                # pages (retried each iteration until one qualifies)
                alive = server.alive_nodes()
                occ = {s: server.pages_per_node -
                       server.table.shard_free_pages(s) for s in alive}
                node = max(alive, key=lambda s: occ[s])
                if os.environ.get("ASC_DEBUG"):
                    print(f"maint-check it={it} occ={occ}",
                          file=sys.stderr)
                if occ[node] > 0 and len(alive) > args.initial:
                    mig_pre = pool.driver.stats.migrate_frames
                    rep = pool.drain_serving_node(node)
                    maint.update(
                        iteration=it, node=node,
                        victims=len(rep["victims"]),
                        migrated_pages=rep["migrated_pages"],
                        cold=len(rep["cold"]),
                        migrate_frames_before=mig_pre)
            _t0 = time.perf_counter()
            router.step()
            if os.environ.get("ASC_DEBUG"):
                print(f"it={it} step_dt="
                      f"{time.perf_counter() - _t0:.3f}", file=sys.stderr)
            it += 1
            if it > 40 * horizon_iters:
                raise RuntimeError("traffic never drained")
        return phase_of, it

    # -- calibration pass: fixed pool, full trace ----------------------------
    # Warms every jit bucket the elastic run will hit (admission chunks,
    # horizon steps, batch sizes) AND measures the steady-phase tail the
    # SLO is declared against — a target the initial serving set can
    # meet, which the burst will then breach.
    # trace every jit bucket the elastic run will hit — including the
    # peak-concurrency batch shapes the scaled-up pool admits
    cal0 = fresh_router(server, pool)
    cal0.max_active = SLOTS_PER_NODE * args.nodes
    drive(cal0)
    for s in list(server.sequence_ids()):
        server.free_sequence(s)
    # warm steady-only pass: the tail the SLO is declared against must
    # not be polluted by compile time
    cal_router = fresh_router(server, pool)
    drive(cal_router, trace=[a for a in arrivals if a[1] == "steady"])
    cal_ttft = [r.t_first - r.t_arrive for r in cal_router.finished]
    slo = ServingSLO(ttft_p99_s=max(4.0 * _pct(cal_ttft, 99), 0.05),
                     queue_depth=3)

    # -- rehearsal: the full elastic scenario, untimed -----------------------
    # The elastic run crosses memberships and kernels the fixed-pool
    # calibration never visits (intermediate serving sets, the
    # device-to-device migrate copy): one rehearsal traces them all so
    # compile time never lands in a measured percentile.
    for s in list(server.sequence_ids()):
        server.free_sequence(s)
    reh_router = fresh_router(server, pool)
    reh_asc = Autoscaler(reh_router, pool, slo=slo,
                         min_nodes=args.initial, max_nodes=args.nodes,
                         window=16, cooldown=2, headroom_frac=0.5,
                         sustain=3)
    drive(reh_router, reh_asc, maintenance=True)
    maint.clear()

    # -- the measured elastic run --------------------------------------------
    for s in list(server.sequence_ids()):
        server.free_sequence(s)
    pool.grow_serving(args.initial)
    while len(server.alive_nodes()) > args.initial:
        pool.drain_serving_node(server.alive_nodes()[-1])
    assert len(server.alive_nodes()) == args.initial
    router = fresh_router(server, pool)
    asc = Autoscaler(router, pool, slo=slo, min_nodes=args.initial,
                     max_nodes=args.nodes, window=16, cooldown=2,
                     headroom_frac=0.5, sustain=3)
    st = pool.driver.stats
    mig0, mbytes0 = st.migrate_frames, st.migrate_bytes
    t0 = time.perf_counter()
    phase_of, iters = drive(router, asc, maintenance=True)
    wall_s = time.perf_counter() - t0
    # drain back to the floor if the trace ended mid-episode (the
    # controller only ticks while traffic exists)
    while len(server.alive_nodes()) > args.initial:
        asc._idle_ticks, asc._last_action_tick = asc.sustain, -10 ** 9
        if asc.tick() is None:
            break

    # -- floors (CI quick lane gates on this process exiting 0) --------------
    ups = [d for d in asc.decisions if d.kind == "up"]
    downs = [d for d in asc.decisions if d.kind == "down"]
    assert ups, "burst never triggered a scale-up"
    assert downs, "sustained headroom never triggered a drain"
    assert not router.rejected, \
        f"shed {len(router.rejected)} requests — drains must be zero-drop"
    assert asc.recoveries, \
        "post-scale-up tail never recovered below the SLO"
    assert downs[0].tick > ups[0].tick, "drained before the burst grew"
    # MIGRATE frames appear exactly when a drain moves live pages: zero
    # while the pool was static, positive once the loaded node retired
    first_down = downs[0]
    assert maint, "maintenance drain never found a node to retire"
    assert maint["migrate_frames_before"] == mig0, \
        "MIGRATE frames on a static pool"
    assert maint["migrated_pages"] + maint["cold"] > 0, \
        f"maintenance drain moved nothing: {maint}"
    assert st.migrate_frames - mig0 == maint["migrated_pages"], \
        "MIGRATE counter out of step with the drain report"
    done = {r.rid for r in router.finished}
    assert done == set(prompts), f"lost requests: {set(prompts) - done}"

    per_phase = {}
    for name, _, rate in phases:
        reqs = [r for r in router.finished if phase_of[r.rid] == name]
        ttft = [r.t_first - r.t_arrive for r in reqs]
        tpot = [(r.t_done - r.t_first) / max(len(r.output) - 1, 1)
                for r in reqs]
        per_phase[name] = {
            "arrival_rate_per_iter": rate, "requests": len(reqs),
            "p50_ttft_s": _pct(ttft, 50), "p99_ttft_s": _pct(ttft, 99),
            "p50_tpot_s": _pct(tpot, 50), "p99_tpot_s": _pct(tpot, 99),
        }

    toks = sum(len(r.output) for r in router.finished)
    rec = {
        "nodes": args.nodes, "initial": args.initial,
        "quick": bool(args.quick), "seed": args.seed,
        "slo": {"ttft_p99_s": slo.ttft_p99_s,
                "queue_depth": slo.queue_depth},
        "requests": len(router.finished),
        "iterations": iters,
        "tokens_per_s": toks / wall_s,
        "phases": per_phase,
        "scale_events": [dataclasses.asdict(d) for d in asc.decisions],
        "recoveries": asc.recoveries,
        "slo_recovery_s": min(r["recovery_s"] for r in asc.recoveries),
        "peak_nodes": max(d.nodes for d in asc.decisions),
        "final_nodes": len(server.alive_nodes()),
        "rejected": len(router.rejected),
        "requeues": router.requeues,
        "migrate_frames": st.migrate_frames - mig0,
        "migrate_bytes": st.migrate_bytes - mbytes0,
        "migrated_pages_in": server.table.stats.migrated_in,
        "maintenance_drain": maint,
        "first_drain_tick": first_down.tick,
        "migration": A.migration_terms(
            type("S", (), {"migrate_frames": st.migrate_frames - mig0,
                           "migrate_bytes": st.migrate_bytes - mbytes0}),
            max(toks, 1)),
    }
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
