"""Benchmark driver — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig12b     # one

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the
figure-level tables the paper reports.  Roofline terms come from the
dry-run artifacts (results/*.jsonl) — see §Roofline in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np


def _cell(fn, *args, n=3, **kw):
    """Time a callable with one untimed warm-up call first — every
    timed region in this driver excludes jit tracing/compilation (the
    discipline all serving/pool/isp cells follow too)."""
    fn(*args, **kw)                      # warmup / compile (untimed)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / n * 1e6
    return us, out


def _csv(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# Fig 3 — ISP performance-impact breakdown
# ---------------------------------------------------------------------------


def fig3_breakdown():
    from repro.core import isp_perf as I
    us, rows = _cell(I.fig3_breakdown)
    _csv("fig3_breakdown", us)
    host, pisp = rows["Host"], rows["P.ISP-V"]
    print(f"  Host:   Compute={host['Compute']:.1f}s "
          f"Storage={host['Storage']:.1f}s ({host['Storage']/host['total']:.0%}) "
          f"Communicate={host['Communicate']:.1f}s")
    print(f"  P.ISP:  Compute={pisp['Compute']:.1f}s "
          f"Storage={pisp['Storage']:.1f}s "
          f"(-{1-pisp['Storage']/host['Storage']:.0%} vs Host) "
          f"Communicate={pisp['Communicate']:.1f}s "
          f"({pisp['Communicate']/pisp['total']:.0%} of total)")
    print(f"  P.ISP e2e vs Host: {pisp['total']/host['total']:.2f}x "
          f"(paper: ~1.4x)")


# ---------------------------------------------------------------------------
# Fig 10 — Virtual-FW binary footprint
# ---------------------------------------------------------------------------


def fig10_footprint():
    from repro.core.virtual_fw import VirtualFW
    us, fp = _cell(VirtualFW.binary_footprint)
    _csv("fig10_footprint", us, f"reduction={fp['reduction']:.1f}x")
    print(f"  Linux stack {fp['linux_bytes']/1e6:.0f} MB -> Virtual-FW "
          f"{fp['virtual_fw_bytes']/1e6:.1f} MB "
          f"({fp['reduction']:.1f}x; paper: 83.4x)")


# ---------------------------------------------------------------------------
# Fig 11 — overall latency, 6 models x 13 workloads
# ---------------------------------------------------------------------------


def fig11_overall():
    from repro.core import isp_perf as I
    us, table = _cell(I.evaluate_all)
    _csv("fig11_overall", us)
    print(f"  {'workload':18s}" + "".join(f"{m:>10s}" for m in I.MODELS) +
          "   (normalized to D-VirtFW)")
    for wl, models in table.items():
        base = sum(models["D-VirtFW"].values())
        row = "".join(f"{sum(c.values())/base:10.2f}"
                      for c in models.values())
        print(f"  {wl:18s}{row}")
    r = I.headline_ratios()
    print(f"  D-VirtFW speedups: vs P.ISP {r['dvirtfw_vs_pisp']:.2f}x "
          f"(1.6) | vs D-Naive {r['dvirtfw_vs_dnaive']:.2f}x (1.8) | "
          f"vs D-FullOS {r['dvirtfw_vs_dfullos']:.2f}x (1.6) | "
          f"vs Host {r['dvirtfw_vs_host']:.2f}x (1.3)")


# ---------------------------------------------------------------------------
# Fig 12a/12b — distributed LLM inference on the storage pool
# ---------------------------------------------------------------------------


def fig12a_parallelism():
    from repro.core import analytical as A
    us, res = _cell(A.evaluate_pool)
    _csv("fig12a_parallelism", us)
    print(f"  {'model':16s}{'nodes':>6s}" +
          "".join(f"{c:>22s}" for c in A.CONFIGS))
    for name, row in res.items():
        cells = "".join(
            f"{str(row['configs'][c]['parallelism']):>22s}"
            for c in A.CONFIGS)
        print(f"  {name:16s}{row['nodes']:6d}{cells}")
    print("  (dp, tp, pp) — Cache -> TP-dominant; H-NoCache -> PP "
          "(paper Fig 12a)")


def fig12b_llm_pool():
    from repro.core import analytical as A
    us, res = _cell(A.evaluate_pool)
    _csv("fig12b_llm_pool", us)
    print(f"  {'model':16s}" + "".join(f"{c:>14s}" for c in A.CONFIGS) +
          "   total seconds (seq 32K, batch 1/node)")
    for name, row in res.items():
        cells = "".join(f"{row['configs'][c]['time']['total']:14.3g}"
                        for c in A.CONFIGS)
        print(f"  {name:16s}{cells}")
    r = A.headline_ratios(res)
    print(f"  D-Cache vs H-Cache {r['d_cache_vs_h_cache']:.1f}x (paper 7.9) | "
          f"H-Cache vs H-NoCache {r['h_cache_vs_h_nocache']:.0f}x (421) | "
          f"D-Cache vs D-NoCache {r['d_cache_vs_d_nocache']:.0f}x (4.6K) | "
          f"D-Cache vs H-NoCache {r['d_cache_vs_h_nocache']:.0f}x (3.2K)")


# ---------------------------------------------------------------------------
# Fig 13 — sensitivity
# ---------------------------------------------------------------------------


def fig13_sensitivity():
    from repro.core import analytical as A
    for name in ("lamda-137B", "megatron-1T"):
        us, rows = _cell(A.seq_sensitivity, name)
        _csv(f"fig13_seq_{name}", us,
             f"crossover={A.crossover_point(rows)}")
        print(f"  {name}: crossover at seq {A.crossover_point(rows)} "
              f"(paper: {'256' if 'lamda' in name else '1024'}), "
              f"converged speedup {rows[-1]['speedup']:.1f}x (paper ~9.5x)")
        line = " ".join(f"{r['seq_len']}:{r['speedup']:.2f}"
                        for r in rows[::2])
        print(f"    speedup by seq: {line}")
    for name in ("lamda-137B", "megatron-1T"):
        us, rows = _cell(A.batch_sensitivity, name, seq_len=1024)
        mx = max(r["speedup"] for r in rows)
        _csv(f"fig13_batch_{name}", us, f"max_speedup={mx:.2f}")
        print(f"  {name}: batch 1..512 speedups "
              f"{[round(r['speedup'],2) for r in rows]} (paper max ~1.3x)")


# ---------------------------------------------------------------------------
# Table 2 — workload characteristics
# ---------------------------------------------------------------------------


def table2_workloads():
    from repro.core import isp_perf as I
    _csv("table2_workloads", 0.0, f"n={len(I.WORKLOADS)}")
    print(f"  {'workload':18s}{'GB':>7s}{'IOs':>9s}{'syscalls':>10s}"
          f"{'walks':>8s}{'files':>8s}{'tcp':>9s}{'host_s':>7s}")
    for w in I.WORKLOADS:
        print(f"  {w.program + '-' + w.name:18s}{w.io_size_gb:7.1f}"
              f"{w.io_count:9.0f}{w.syscalls:10.0f}{w.path_walks:8.0f}"
              f"{w.files_opened:8.0f}{w.tcp_packets:9.0f}"
              f"{w.exec_time_s:7.0f}")


# ---------------------------------------------------------------------------
# kernels — microbenchmarks vs jnp references (CPU interpret mode:
# numbers are correctness-path timings, not TPU perf)
# ---------------------------------------------------------------------------


def kernel_micro():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)

    q = jax.random.normal(ks[0], (2, 4, 256, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    us, _ = _cell(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v)))
    us_r, _ = _cell(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v)))
    _csv("kernel_flash_attention", us, f"ref_us={us_r:.0f}")

    qd = jax.random.normal(ks[0], (4, 8, 64))
    kp = jax.random.normal(ks[1], (32, 16, 2, 64))
    vp = jax.random.normal(ks[2], (32, 16, 2, 64))
    pt = jnp.arange(32, dtype=jnp.int32).reshape(4, 8)
    lens = jnp.full((4,), 100, jnp.int32)
    us, _ = _cell(lambda: jax.block_until_ready(
        ops.paged_attention(qd, kp, vp, pt, lens)))
    _csv("kernel_paged_attention", us)

    table = jax.random.normal(ks[3], (4096, 128))
    idx = jax.random.randint(ks[4], (8, 32), 0, 4096, jnp.int32)
    us, _ = _cell(lambda: jax.block_until_ready(ops.embed_agg(table, idx)))
    _csv("kernel_embed_agg", us)

    r = jax.random.normal(ks[0], (1, 64, 2, 32))
    kk = jax.random.normal(ks[1], (1, 64, 2, 32))
    vv = jax.random.normal(ks[2], (1, 64, 2, 32))
    logw = -jnp.exp(jax.random.normal(ks[3], (1, 64, 2, 32)))
    u = jax.random.normal(ks[4], (2, 32))
    s0 = jnp.zeros((1, 2, 32, 32))
    us, _ = _cell(lambda: jax.block_until_ready(
        ops.rwkv_scan(r, kk, vv, logw, u, s0)[0]))
    _csv("kernel_rwkv_scan", us)


# ---------------------------------------------------------------------------
# serving — batched jitted decode throughput on the tiered KV path
# ---------------------------------------------------------------------------


def _shared_prefix_cell(model, params, cfg, rng, quick=False):
    """Warm-vs-cold admission on a shared-prefix workload (75% of every
    prompt is one system template — a >=50% shared-prefix workload).

    Cold = the prefix cache ablated (``prefix_cache=False``): every
    admission computes every prompt token through chunked prefill.
    Warm = the cache holds the template (seeded by an untimed round):
    admissions compute only the per-request tail.  Both run the same
    chunked admission path on the same shape buckets (untimed warm-up
    first, best-of-3), and the warm outputs must be token-identical to
    the cold server's on the same prompts.  Returns the cell dict for
    BENCH_serve.json."""
    import jax.numpy as jnp
    from repro.core import analytical as A
    from repro.runtime.serve import PagedServer

    n_req, shared, total, chunk = 4, 48, 64, 16
    gen = 4 if quick else 8
    reps = 3
    template = rng.integers(0, cfg.vocab_size, shared, dtype=np.int32)

    def mk_prompts():
        return [np.concatenate([template, rng.integers(
            0, cfg.vocab_size, total - shared, dtype=np.int32)])
            for _ in range(n_req)]

    def admit_all(srv, prompts):
        for i, p in enumerate(prompts):
            srv.add_request(i, p, chunk=chunk)

    def free_all(srv):
        for s in list(srv.sequence_ids()):
            srv.free_sequence(s)

    def outputs(srv, prompts):
        admit_all(srv, prompts)
        pend = srv.pending_tokens()
        out = srv.decode(gen)
        got = {i: [pend[i]] + out[i] for i in range(n_req)}
        free_all(srv)
        return got

    cold_srv = PagedServer(model, params, page_size=8, hbm_pages=64,
                           dtype=jnp.float32, prefix_cache=False)
    warm_srv = PagedServer(model, params, page_size=8, hbm_pages=64,
                           dtype=jnp.float32)

    # untimed round: warms every shape bucket on both servers, seeds the
    # warm server's cache with the template, and checks token identity
    # (the warm server's admissions ride shared prefix pages; its greedy
    # outputs must match the compute-everything server exactly)
    prompts0 = mk_prompts()
    out_cold = outputs(cold_srv, prompts0)
    out_warm = outputs(warm_srv, prompts0)
    identical = out_warm == out_cold
    assert identical, "shared-prefix outputs diverged from the cold run"

    def timed_round(srv):
        best = None
        for _ in range(reps):
            prompts = mk_prompts()       # fresh tails: only the
            t0 = time.perf_counter()     # template can hit the cache
            admit_all(srv, prompts)
            dt = time.perf_counter() - t0
            free_all(srv)
            best = dt if best is None else min(best, dt)
        return best

    s0 = warm_srv.table.stats.prefix_tokens
    c0 = warm_srv.prefill_tokens_computed
    t_warm = timed_round(warm_srv)
    saved = warm_srv.table.stats.prefix_tokens - s0
    computed = warm_srv.prefill_tokens_computed - c0
    hit_rate = saved / max(saved + computed, 1)
    t_cold = timed_round(cold_srv)
    speedup = t_cold / t_warm

    # admission-stall cells: one blocking one-shot admission vs one
    # chunk-bounded warm admission (what a decode horizon actually
    # waits for under the interleaving scheduler)
    def single(srv, ch):
        p = mk_prompts()[0]
        srv.add_request(0, p, chunk=ch)     # bucket warm-up (untimed)
        srv.free_sequence(0)
        best = None
        for _ in range(reps):
            p = mk_prompts()[0]
            t0 = time.perf_counter()
            srv.add_request(0, p, chunk=ch)
            dt = time.perf_counter() - t0
            srv.free_sequence(0)
            best = dt if best is None else min(best, dt)
        return best

    t_one_shot = single(cold_srv, None)       # whole prompt, one call
    t_warm_admission = single(warm_srv, chunk)  # tail only, one chunk
    # modeled terms: fit (host, per-token) from the two cold admission
    # shapes, then the prefix/chunk amortization model
    t_cold_chunked = single(cold_srv, chunk)    # 4 chunks, 32 tokens
    host_s, tok_s = A.fit_prefill_overheads(
        total, 1, t_one_shot, total, -(-total // chunk), t_cold_chunked)
    modeled = A.prefix_chunk_terms(total, shared, chunk, host_s, tok_s)

    cell = {
        "workload": {"n_req": n_req, "prompt_len": total,
                     "shared_prefix_len": shared,
                     "shared_fraction": shared / total,
                     "prefill_chunk": chunk, "gen": gen},
        "cold_admission_s": t_cold,
        "warm_admission_s": t_warm,
        "warm_speedup": speedup,
        "prefix_hit_rate": hit_rate,
        "prefill_tokens_per_s": {
            "cold": n_req * total / t_cold,
            "warm_admitted": n_req * total / t_warm,
        },
        "outputs_identical_warm_vs_cold": identical,
        "stall": {
            "one_shot_admission_s": t_one_shot,
            "chunked_warm_admission_s": t_warm_admission,
            "cold_chunked_admission_s": t_cold_chunked,
        },
        "modeled": {"host_overhead_s": host_s,
                    "token_prefill_s": tok_s, **modeled},
    }
    print(f"  shared-prefix ({shared}/{total} tokens shared): cold "
          f"{t_cold*1e3:.1f} ms | warm {t_warm*1e3:.1f} ms | "
          f"{speedup:.1f}x warm speedup | hit rate {hit_rate:.2f}")
    print(f"  admission stall: one-shot {t_one_shot*1e3:.1f} ms -> one "
          f"warm chunk {t_warm_admission*1e3:.1f} ms (modeled warm "
          f"speedup {modeled['modeled_warm_speedup']:.1f}x, stall "
          f"reduction {modeled['stall_reduction']:.1f}x)")
    # conservative floors (CI bench-smoke): prefix-cache perf
    # regressions fail the build
    assert speedup >= 2.0, \
        f"warm admission {speedup:.2f}x < 2x floor on shared-prefix " \
        f"workload"
    assert t_warm_admission < t_one_shot, \
        "a chunk-bounded warm admission must stall decode less than a " \
        "blocking one-shot admission"
    return cell


def _capacity_cell(model, params, cfg, rng):
    """Equal-HBM capacity cell (quantized KV page format): the window
    is sized from one byte budget for both formats, so the cell
    measures how many concurrent sequences fit *resident* (decode with
    zero spill) in fp32 vs int8 pages — the acceptance floor is int8
    >= 2x fp32.  Also records the per-spilled-page cold-tier bytes of
    each format (a page_outs-forcing run) and decisive-logit argmax
    agreement between the formats at admission."""
    import jax.numpy as jnp
    from repro.runtime.serve import PagedServer

    prompt_len, gen = 16, 4
    total = prompt_len + gen
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
               for _ in range(64)]

    probe = PagedServer(model, params, page_size=8, hbm_pages=8,
                        dtype=jnp.float32)
    budget = 6 * probe.pages_needed(total) * probe.store.page_bytes()

    cells = {}
    for pd in ("fp32", "int8"):
        srv = PagedServer(model, params, page_size=8, hbm_bytes=budget,
                          dtype=jnp.float32, page_dtype=pd)
        per_seq = srv.pages_needed(total)
        cap = srv.table.free_pages // per_seq
        logits = [np.asarray(srv.add_request(i, prompts[i]))
                  for i in range(cap)]
        srv.decode(gen - 1)
        st = srv.tier_stats()
        assert st["page_outs"] == 0, \
            f"{pd}: capacity run spilled — window math is wrong"

        # cold-tier sub-cell: force spills through a tiny window and
        # read the per-page bytes the host tier actually received
        tiny = PagedServer(model, params, page_size=8, hbm_pages=4,
                           dtype=jnp.float32, page_dtype=pd)
        for i in range(3):
            tiny.add_request(i, prompts[i])
        tst = tiny.tier_stats()
        assert tst["page_outs"] > 0
        cells[pd] = {
            "max_resident_seqs": cap,
            "window_pages": srv.table.free_pages + cap * per_seq,
            "page_bytes": st["page_bytes"],
            "spill_bytes_per_page": tst["bytes_out"] / tst["page_outs"],
            "admission_argmax": [int(np.argmax(l)) for l in logits],
            "admission_logits": logits,
        }

    # decisive-logit parity across formats on the shared admissions
    n = min(cells["fp32"]["max_resident_seqs"],
            cells["int8"]["max_resident_seqs"])
    lf = np.stack(cells["fp32"].pop("admission_logits")[:n])
    lq = np.stack(cells["int8"].pop("admission_logits")[:n])
    srt = np.sort(lf, -1)
    decisive = srt[:, -1] - srt[:, -2] > 0.05
    agree = bool((lf.argmax(-1)[decisive] == lq.argmax(-1)[decisive]).all())

    cap_ratio = (cells["int8"]["max_resident_seqs"] /
                 cells["fp32"]["max_resident_seqs"])
    byte_ratio = (cells["fp32"]["spill_bytes_per_page"] /
                  cells["int8"]["spill_bytes_per_page"])
    cell = {"hbm_byte_budget": budget, "prompt_len": prompt_len,
            "gen": gen, "fp32": cells["fp32"], "int8": cells["int8"],
            "capacity_ratio": cap_ratio,
            "cold_tier_bytes_ratio": byte_ratio,
            "decisive_positions": int(decisive.sum()),
            "decisive_argmax_agree": agree}
    print(f"  capacity @ equal HBM ({budget} B): fp32 "
          f"{cells['fp32']['max_resident_seqs']} seqs | int8 "
          f"{cells['int8']['max_resident_seqs']} seqs "
          f"({cap_ratio:.1f}x) | cold-tier bytes/page "
          f"{byte_ratio:.1f}x smaller | decisive argmax agree {agree}")
    assert cap_ratio >= 2.0, \
        f"int8 capacity {cap_ratio:.2f}x < 2x floor at equal HBM bytes"
    assert byte_ratio >= 2.0, \
        f"int8 cold-tier bytes only {byte_ratio:.2f}x smaller"
    assert agree, "int8 flipped a decisive fp32 argmax at admission"
    return cell


def _speculative_cell(model, params, cfg, quick=False):
    """Speculative draft-verify cell: decode throughput of
    ``speculative=True`` (lookup drafter + one chunk-shaped verify
    pass per draft) against the plain H=8 fused horizon, on two
    workloads.  The repetitive workload is constant-token prompts —
    the demo model's greedy continuation of a constant stream is
    itself constant, the regime the lookup drafter is built for
    (alpha -> 1, every pass commits a full draft).  The adversarial
    workload is i.i.d. random prompts: drafts can't land, the alpha
    EMA closes the gate, and throughput must hold near the plain
    horizon.  Outputs must be token-identical to the non-speculative
    path on both.  Two spec horizons on the repetitive workload feed
    ``fit_speculation_overheads`` (per-pass host cost + per-position
    verify cost), mirrored against ``speculative_terms``."""
    import jax.numpy as jnp
    from repro.core import analytical as A
    from repro.runtime.serve import PagedServer

    n_req, plen, gen = 4, 40, 48
    base_h, spec_h = 8, 16
    rng = np.random.default_rng(7)
    # prompt-lookup's paying regime: the prompt tail already carries
    # the stream the model will emit (here: constant runs the demo
    # model self-sustains for >= gen tokens), so the drafter copies
    # successors out of the prompt from the very first pass
    rep_prompts = [np.asarray([c] * (24 + i % 2) + [t] * 16, np.int32)
                   for i, (c, t) in enumerate([(41, 49), (500, 259)] * 2)]
    adv_prompts = [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
                   for _ in range(n_req)]
    srv = PagedServer(model, params, page_size=16, hbm_pages=64,
                      dtype=jnp.float32)

    def timed(prompts, horizon, speculative):
        """Untimed same-shape warm-up on the warm server, then
        best-of-3 timed decodes from identical re-admitted states
        (the serve_decode discipline — jit caches are per-instance)."""
        def readmit():
            for s in list(srv.sequence_ids()):
                srv.free_sequence(s)
            for i, p in enumerate(prompts):
                srv.add_request(i, p)
        readmit()
        srv.decode(gen, horizon=horizon, speculative=speculative)
        best, out, stats = None, None, None
        for _ in range(3):
            readmit()
            srv.reset_speculation_stats()
            t0 = time.perf_counter()
            o = srv.decode(gen, horizon=horizon, speculative=speculative)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best, out, stats = dt, o, srv.speculation_stats()
        toks = sum(len(v) for v in out.values())
        return toks / best, out, stats

    cell = {"config": {"n_req": n_req, "prompt_len": plen, "gen": gen,
                       "base_horizon": base_h, "spec_horizon": spec_h}}
    fit_in = {}
    for name, prompts in (("repetitive", rep_prompts),
                          ("adversarial", adv_prompts)):
        base_tps, base_out, _ = timed(prompts, base_h, False)
        spec_tps, spec_out, st = timed(prompts, spec_h, True)
        assert spec_out == base_out, \
            f"speculative {name} decode diverged from the greedy path"
        ratio = spec_tps / base_tps
        cell[name] = {
            "base_tokens_per_s": base_tps,
            "spec_tokens_per_s": spec_tps,
            "speedup_vs_h8": ratio,
            "alpha": st["alpha"],
            "passes": st["passes"],
            "fallback_passes": st["fallback_passes"],
            "accepted_len_hist": {str(k): v for k, v
                                  in st["accepted_len_hist"].items()},
        }
        if name == "repetitive" and st["passes"]:
            fit_in[spec_h] = (st["emitted"] / st["passes"], spec_tps)
    # second spec horizon on the repetitive workload -> overhead fit
    tps8, _, st8 = timed(rep_prompts, base_h, True)
    if st8["passes"] and fit_in:
        fit_in[base_h] = (st8["emitted"] / st8["passes"], tps8)
        (ha, (tpa, sa)), (hb, (tpb, sb)) = sorted(fit_in.items())
        host_s, pos_s = A.fit_speculation_overheads(ha, tpa, sa,
                                                    hb, tpb, sb)
        modeled = A.speculative_terms(
            n_req * gen, spec_h, cell["repetitive"]["alpha"],
            host_s, pos_s)
        cell["fitted"] = {"host_overhead_s": host_s,
                          "verify_pos_s": pos_s}
        cell["modeled"] = modeled
    rep, adv = cell["repetitive"], cell["adversarial"]
    print(f"  speculative (vs H={base_h} greedy): repetitive "
          f"{rep['speedup_vs_h8']:.2f}x (alpha={rep['alpha']:.2f}) | "
          f"adversarial {adv['speedup_vs_h8']:.2f}x "
          f"(alpha={adv['alpha']:.2f}, "
          f"fallback {adv['fallback_passes']} passes)")
    # conservative floors: the repetitive regime must pay for the
    # draft-verify machinery outright; the adversarial regime must
    # stay within noise of the plain horizon (the gate's whole job)
    assert rep["speedup_vs_h8"] >= 2.0, \
        f"speculative repetitive {rep['speedup_vs_h8']:.2f}x < 2x floor"
    assert adv["speedup_vs_h8"] >= 0.9, \
        f"speculative adversarial {adv['speedup_vs_h8']:.2f}x < 0.9x"
    return cell


def _latency_cell(model, params, cfg, rng, quick=False):
    """Per-request latency percentiles through the continuous batcher:
    more requests than ``max_active``, so admissions queue behind the
    running batch and TTFT spreads — p50/p99 TTFT and TPOT are the
    traffic-facing slice the aggregate tok/s cells hide."""
    import jax.numpy as jnp
    from repro.runtime.scheduler import ContinuousBatcher, Request
    from repro.runtime.serve import PagedServer

    n_req, plen, gen = 8, 24, (8 if quick else 16)
    srv = PagedServer(model, params, page_size=8, hbm_pages=48,
                      dtype=jnp.float32)
    prompts = [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
               for _ in range(n_req)]

    def run():
        for s in list(srv.sequence_ids()):
            srv.free_sequence(s)
        b = ContinuousBatcher(srv, max_active=4, horizon=4,
                              prefill_chunk=16)
        for i, p in enumerate(prompts):
            b.submit(Request(rid=i, prompt=p, max_tokens=gen))
        return b.run_to_completion()

    # two untimed warm-ups: the first traces the cache-cold buckets and
    # seeds the prefix cache; the second traces the warm-hit buckets the
    # steady-state (timed) run actually uses
    run()
    run()
    st = run()
    assert st["requests"] == n_req, "latency cell lost requests"
    cell = {"workload": {"n_req": n_req, "prompt_len": plen, "gen": gen,
                         "max_active": 4, "horizon": 4,
                         "prefill_chunk": 16},
            **{k: st[k] for k in
               ("mean_ttft_s", "p50_ttft_s", "p99_ttft_s", "mean_tpot_s",
                "p50_tpot_s", "p99_tpot_s", "mean_latency_s",
                "p99_latency_s")}}
    print(f"  latency ({n_req} req, {4} active): TTFT p50 "
          f"{st['p50_ttft_s']*1e3:.1f} ms / p99 "
          f"{st['p99_ttft_s']*1e3:.1f} ms | TPOT p50 "
          f"{st['p50_tpot_s']*1e3:.1f} ms / p99 "
          f"{st['p99_tpot_s']*1e3:.1f} ms")
    assert st["p99_ttft_s"] >= st["p50_ttft_s"] > 0
    return cell


def _rag_cell(model, params, cfg, rng, quick=False):
    """End-to-end RAG cell: in-storage top-k retrieval feeding
    prefix-cached admission.

    Every request asks about the same topic (one query vector, fresh
    per-request question tails), so each assembled prompt shares
    template + retrieved chunks — the prefix a warm cache absorbs.
    Cold = prefix cache ablated (every prompt token computed); warm =
    cache seeded by an untimed round.  Retrieval runs *in storage*
    (``force="device"``: only k (id, score) pairs cross the wire) and
    the whole pipeline's outputs must be token-identical to a host-side
    retrieval baseline (``force="host"``: host fetches the extent and
    folds it — the bit-identity contract end to end)."""
    import jax.numpy as jnp
    from repro.core import StoragePool, analytics_blob
    from repro.runtime.retrieval import RetrievalFrontend
    from repro.runtime.serve import PagedServer

    n_docs, d_emb, chunk_tok, k = 12, 32, 16, 3
    n_req, tail, gen, reps = 4, 8, (4 if quick else 8), 3
    template = rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
    corpus = rng.integers(0, cfg.vocab_size, (n_docs, chunk_tok),
                          dtype=np.int32)
    emb = rng.normal(size=(n_docs, d_emb)).astype(np.float32)

    pool = StoragePool(1, extent_cfg={"n_pages": n_docs // 4 + 2,
                                      "page_rows": 4, "n_cols": d_emb})
    pool.broadcast_pull("isp-analytics", analytics_blob())
    query = rng.normal(size=(d_emb,)).astype(np.float32)

    cold_srv = PagedServer(model, params, page_size=8, hbm_pages=64,
                           dtype=jnp.float32, prefix_cache=False)
    warm_srv = PagedServer(model, params, page_size=8, hbm_pages=64,
                           dtype=jnp.float32)
    fe_cold = RetrievalFrontend(pool, cold_srv, corpus_tokens=corpus,
                                template=template, k=k)
    fe_warm = RetrievalFrontend(pool, warm_srv, corpus_tokens=corpus,
                                template=template, k=k)
    fe_cold.ingest(emb)

    def qtails():
        return [rng.integers(0, cfg.vocab_size, tail, dtype=np.int32)
                for _ in range(n_req)]

    def free_all(srv):
        for s in list(srv.sequence_ids()):
            srv.free_sequence(s)

    def admit(fe, tails, force):
        """One request wave: per-request TTFT = retrieve + assemble +
        prefill (the whole RAG admission)."""
        ts = []
        for i, qt in enumerate(tails):
            t0 = time.perf_counter()
            fe.submit(i, query, qt, force=force)
            ts.append(time.perf_counter() - t0)
        return ts

    def outputs(fe, tails, force):
        admit(fe, tails, force)
        pend = fe.server.pending_tokens()
        out = fe.server.decode(gen)
        got = {i: [pend[i]] + out[i] for i in range(n_req)}
        free_all(fe.server)
        return got

    # untimed round: warms every shape bucket, seeds the warm cache,
    # and pins the end-to-end contract — device-retrieval outputs must
    # be token-identical to the host-side retrieval baseline
    tails0 = qtails()
    out_host = outputs(fe_cold, tails0, "host")
    out_dev = outputs(fe_warm, tails0, "device")
    identical = out_dev == out_host
    assert identical, "device-retrieval RAG outputs diverged from the " \
                      "host-side retrieval baseline"
    admit(fe_warm, qtails(), "device")     # untimed warm-bucket warm-up
    free_all(warm_srv)

    def timed(fe, force):
        best = None
        for _ in range(reps):
            ts = admit(fe, qtails(), force)
            free_all(fe.server)
            if best is None or sum(ts) < sum(best):
                best = ts
        return best

    warm_ts = timed(fe_warm, "device")
    cold_ts = timed(fe_cold, "device")
    speedup = float(np.mean(cold_ts) / np.mean(warm_ts))

    def pcts(ts):
        return {"mean": float(np.mean(ts)),
                "p50": float(np.percentile(ts, 50)),
                "p99": float(np.percentile(ts, 99)),
                "per_request": list(ts)}

    prompt_len = len(template) + k * chunk_tok + tail
    cell = {
        "workload": {"n_req": n_req, "n_docs": n_docs, "d_emb": d_emb,
                     "chunk_tokens": chunk_tok, "k": k,
                     "template_tokens": len(template),
                     "prompt_len": prompt_len,
                     "shared_fraction": (prompt_len - tail) / prompt_len,
                     "gen": gen},
        "cold_ttft_s": pcts(cold_ts),
        "warm_ttft_s": pcts(warm_ts),
        "warm_ttft_speedup": speedup,
        "retrieval_placement": dict(fe_warm.stats),
        "outputs_identical_device_vs_host_retrieval": identical,
    }
    print(f"  rag ({n_req} req, k={k}, {prompt_len} tok prompts): cold "
          f"TTFT {np.mean(cold_ts)*1e3:.1f} ms | warm "
          f"{np.mean(warm_ts)*1e3:.1f} ms | {speedup:.1f}x | outputs == "
          f"host-retrieval baseline: {identical}")
    assert fe_warm.stats["device"] > 0, \
        "RAG cell never scored in storage"
    assert speedup >= 2.0, \
        f"warm RAG TTFT only {speedup:.2f}x better than cold (< 2x floor)"
    return cell


def serve_decode(out_path="BENCH_serve.json", quick=False):
    """Decode-throughput micro-benchmark on the demo config
    (examples/serve_pool.py scale): tokens/s of the single jitted
    decode_step vs the per-layer Python reference loop (the seed
    schedule), plus the fused decode-horizon sweep (H tokens per host
    interaction, greedy outputs bit-identical to the per-token path),
    per-bucket cold-admission prefill cells, the shared-prefix
    warm-vs-cold admission cell (prefix cache + chunked prefill) and
    the tier telemetry.  Asserts conservative perf floors — decode or
    prefix-cache regressions fail the build via the CI bench-smoke
    step.  Writes ``BENCH_serve.json`` so future PRs can track the
    serving-perf trajectory."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_arch
    from repro.core import analytical as A
    from repro.models.api import get_model
    from repro.runtime.serve import PagedServer

    cfg = dataclasses.replace(
        get_arch("granite-3-2b"),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
        vocab_size=512)
    model = get_model(cfg, compute_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # the shared-prefix warm-vs-cold cell runs first, on quiet process
    # state (its ms-scale admission cells are the most noise-sensitive)
    shared_prefix = _shared_prefix_cell(model, params, cfg, rng,
                                        quick=quick)
    capacity = _capacity_cell(model, params, cfg, rng)
    n_req, prompt_len, gen = 4, 24, (8 if quick else 16)
    horizons = [1, 8] if quick else [1, 2, 4, 8]
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
               for _ in range(n_req)]

    server = PagedServer(model, params, page_size=8, hbm_pages=32,
                         dtype=jnp.float32)
    # prefill cells: one per pow2 shape bucket, with the decode cells'
    # discipline — an untimed same-bucket warm-up admission, then
    # best-of-3 timed COLD admissions (every rep a fresh prompt, so no
    # rep rides a prefix hit from the one before; the prefix cache is
    # cleared between reps to keep every admission cache-cold)
    prefill_s = {}
    for plen in (prompt_len, 2 * prompt_len):
        server.add_request(-1, rng.integers(0, cfg.vocab_size, plen,
                                            dtype=np.int32))
        server.free_sequence(-1)               # untimed bucket warm-up
        best = None
        for _ in range(3):
            server.table.clear_prefix_cache()
            p = rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
            t0 = time.perf_counter()
            server.add_request(-1, p)
            dt = time.perf_counter() - t0
            server.free_sequence(-1)
            best = dt if best is None else min(best, dt)
        prefill_s[str(plen)] = best
    server.table.clear_prefix_cache()
    t0 = time.perf_counter()
    for i in range(n_req):
        server.add_request(i, prompts[i])
    t_prefill = time.perf_counter() - t0

    def readmit():
        for s in list(server.sequence_ids()):
            server.free_sequence(s)
        for i in range(n_req):
            server.add_request(i, prompts[i])

    tier = {}
    reps = 3                          # best-of-N per cell (noise guard)

    def timed_decode(horizon, grab_tier=False):
        """One untimed warm-up decode (traces every shape bucket the
        run hits), then best-of-``reps`` timed runs from identical
        re-admitted states.  ``grab_tier`` snapshots the tier telemetry
        right after a timed decode, while its working set is still
        live."""
        server.decode(gen, horizon=horizon)
        best, out = None, None
        for _ in range(reps):
            readmit()
            t0 = time.perf_counter()
            o = server.decode(gen, horizon=horizon)
            dt = time.perf_counter() - t0
            if grab_tier and not tier:
                tier.update(server.tier_stats())
            if best is None or dt < best:
                best, out = dt, o
        readmit()
        return best, out

    t_decode, out_per_token = timed_decode(None, grab_tier=True)
    toks = n_req * gen
    tok_s = toks / t_decode

    # fused decode horizon: H tokens per host interaction
    h_tok_s, identical = {}, True
    for H in horizons:
        dt, out_h = timed_decode(H)
        h_tok_s[H] = toks / dt
        identical &= (out_h == out_per_token)
    h_max = max(horizons)
    h_speedup = h_tok_s[h_max] / tok_s
    host_s, dev_s = A.fit_horizon_overheads(
        horizons[0], h_tok_s[horizons[0]], h_max, h_tok_s[h_max])
    modeled = A.horizon_amortized_terms(gen, h_max, host_s, dev_s)

    # reference: the seed schedule (per-layer Python loop, eager
    # appends).  Same store state, no commit, so the comparison is
    # apples-to-apples per step.
    cur = server.pending_tokens()
    server.step_reference(cur)                    # warm the eager path
    n_ref = 4
    t0 = time.perf_counter()
    for _ in range(n_ref):
        jax.block_until_ready(server.step_reference(cur))
    t_ref = (time.perf_counter() - t0) / n_ref
    ref_tok_s = n_req / t_ref

    speedup = tok_s / ref_tok_s
    # speculative draft-verify cell (own server instance; floors
    # asserted inside — a spec regression fails the build through the
    # same bench-smoke step as the decode floors)
    speculative = _speculative_cell(model, params, cfg, quick=quick)
    # per-request latency percentiles + the end-to-end RAG cell (both
    # assert their own floors, so a regression fails bench-smoke)
    latency = _latency_cell(model, params, cfg, rng, quick=quick)
    rag = _rag_cell(model, params, cfg, rng, quick=quick)
    result = {
        "config": {"n_req": n_req, "prompt_len": prompt_len, "gen": gen,
                   "n_layers": cfg.n_layers, "d_model": cfg.d_model,
                   "page_size": 8, "hbm_pages": 32},
        # per-bucket cold admission latency (untimed same-bucket warm-up
        # + best-of-3, the decode cells' discipline)
        "prefill_s": prefill_s,
        "prefill_batch_s": t_prefill,
        "shared_prefix": shared_prefix,
        "capacity": capacity,
        "decode_tokens_per_s": tok_s,
        "reference_tokens_per_s": ref_tok_s,
        "speedup_vs_reference": speedup,
        "horizon": {
            "tokens_per_s": {str(h): h_tok_s[h] for h in horizons},
            "speedup_vs_per_token": {str(h): h_tok_s[h] / tok_s
                                     for h in horizons},
            "h_max_speedup": h_speedup,
            "outputs_identical": identical,
            "fitted": {"host_overhead_s": host_s, "device_step_s": dev_s},
            "modeled": modeled,
        },
        "speculative": speculative,
        "latency": latency,
        "rag": rag,
        "tier": tier,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    _csv("serve_decode", t_decode / gen * 1e6,
         f"tok_s={tok_s:.1f},speedup={speedup:.1f}x,"
         f"h{h_max}={h_speedup:.1f}x")
    print(f"  jitted decode: {tok_s:.1f} tok/s | per-layer reference: "
          f"{ref_tok_s:.1f} tok/s | speedup {speedup:.1f}x")
    for H in horizons:
        print(f"  horizon H={H:2d}: {h_tok_s[H]:7.1f} tok/s "
              f"({h_tok_s[H] / tok_s:.2f}x vs per-token)")
    print(f"  outputs identical across horizons: {identical} | "
          f"fitted host overhead {host_s*1e3:.2f} ms/interaction, "
          f"device {dev_s*1e3:.2f} ms/token | modeled H={h_max} speedup "
          f"{modeled['modeled_speedup_vs_h1']:.1f}x (-> {out_path})")
    assert identical, "horizon decode diverged from the per-token path"
    # conservative floors: fail the build on a decode-perf regression
    assert speedup >= 3.0, \
        f"jitted decode {speedup:.2f}x < 3x floor vs seed schedule"
    assert h_speedup >= 2.0, \
        f"horizon H={h_max} {h_speedup:.2f}x < 2x floor vs per-token"


# ---------------------------------------------------------------------------
# pool serving — distributed decode across 1/2/4/8 simulated DockerSSDs
# ---------------------------------------------------------------------------


def pool_serving(out_path="BENCH_pool.json", quick=False,
                 fault_plan="none"):
    """Pool-serving scaling benchmark: the same workload through the
    1-node ``PagedServer`` and the mesh-sharded ``PoolServer`` on
    1/2/4/8 simulated nodes (forced host devices — each pool size is a
    subprocess because the device count binds at jax import), each on
    both the per-token path and the fused decode horizon (H=8).
    Asserts the pool path matches the single-node reference to 1e-4 on
    prefill logits and exactly on greedy outputs (per-token AND
    horizon), plus a conservative horizon-speedup floor, then writes
    ``BENCH_pool.json`` with per-pool-size tokens/s.  A final
    degraded-mode cell kills one node of the largest pool mid-run
    (optionally under ``--fault-plan`` fabric chaos) and records the
    recovery latency and goodput dip, with outputs still identical to
    the uninterrupted run.  CPU simulation numbers measure the
    mechanism (one jitted step per token, LSE-merged partials), not
    TPU perf."""
    import subprocess
    import sys as _sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    worker = os.path.join(repo, "benchmarks", "pool_worker.py")
    sizes = [1, 2] if quick else [1, 2, 4, 8]
    # the one source of truth for the workload: passed to every worker
    # and recorded in the artifact
    wl = {"requests": 6, "prompt_len": 24, "gen": 16, "page_size": 8,
          "horizon": 8}

    def run(mode, nodes, extra=()):
        out = subprocess.run(
            [_sys.executable, worker, "--nodes", str(nodes),
             "--mode", mode]
            + [f"--{k.replace('_', '-')}={v}" for k, v in wl.items()]
            + list(extra),
            capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.splitlines()[-1])

    ref = run("single", 1)
    ref_logits = np.asarray(ref["prefill_logits"])
    result = {
        "config": dict(wl, sizes=sizes, match_tol=1e-4),
        "single_node_tokens_per_s": ref["tokens_per_s"],
        "single_node_tokens_per_s_horizon": ref["tokens_per_s_horizon"],
        "single_node_shared_prefix": ref["shared_prefix"],
        "single_node_latency": ref["latency"],
        "pool": {},
    }
    for n in sizes:
        rec = run("pool", n)
        diff = float(np.max(np.abs(
            np.asarray(rec["prefill_logits"]) - ref_logits)))
        assert diff < 1e-4, f"pool({n}) diverged from 1-node: {diff}"
        assert rec["outputs"] == ref["outputs"], \
            f"pool({n}) greedy outputs diverged"
        assert rec["horizon_outputs_match"], \
            f"pool({n}) horizon decode diverged from per-token"
        h_speed = rec["tokens_per_s_horizon"] / rec["tokens_per_s"]
        sp = rec["shared_prefix"]
        # shared-prefix sanity: warm == cold outputs (worker-asserted),
        # and in pool mode every prefix hit landed on a node that
        # actually indexed the template (placed routing works)
        assert sp["outputs_identical_warm_vs_cold"]
        assert sp["node_prefix_hits"][sp["owner_node"]] > 0, \
            f"pool({n}): no prefix hits on the owning node"
        result["pool"][str(n)] = {
            "tokens_per_s": rec["tokens_per_s"],
            "tokens_per_s_horizon": rec["tokens_per_s_horizon"],
            "horizon_speedup": h_speed,
            "scaling_vs_single": rec["tokens_per_s"] / ref["tokens_per_s"],
            "scaling_vs_single_horizon":
                rec["tokens_per_s_horizon"] /
                ref["tokens_per_s_horizon"],
            "max_abs_logit_diff": diff,
            "control_plane": rec["control_plane"],
            "node_tier": rec["node_tier"],
            "shared_prefix": sp,
            "speculative": rec.get("speculative"),
            "latency": rec["latency"],
        }
        _csv(f"pool_serving_{n}", rec["decode_s"] / wl["gen"] * 1e6,
             f"tok_s={rec['tokens_per_s']:.1f},"
             f"h{wl['horizon']}={rec['tokens_per_s_horizon']:.1f},"
             f"diff={diff:.2e}")
        print(f"  {n} node(s): {rec['tokens_per_s']:.1f} tok/s per-token | "
              f"{rec['tokens_per_s_horizon']:.1f} tok/s H={wl['horizon']} "
              f"({h_speed:.2f}x) | max |dlogit| {diff:.2e} | "
              f"{rec['control_plane']['us_per_token']:.2f} us/token "
              f"control plane")
        print(f"    shared-prefix: warm {sp['warm_speedup']:.1f}x vs "
              f"cold | hit rate {sp['prefix_hit_rate']:.2f} | hits on "
              f"owner node {sp['owner_node']}: "
              f"{sp['node_prefix_hits'][sp['owner_node']]}")
        spec = rec.get("speculative")
        if spec and "skipped" not in spec:
            print(f"    speculative: {spec['speedup_vs_horizon']:.2f}x vs "
                  f"plain H={wl['horizon']} | alpha={spec['alpha']:.2f} | "
                  f"{spec['passes']} passes + {spec['fallback_passes']} "
                  f"fallback — outputs identical")
        elif spec:
            print(f"    speculative: skipped ({spec['skipped']})")
        lt = rec["latency"]
        print(f"    latency: TTFT p50 {lt['p50_ttft_s']*1e3:.1f} ms / "
              f"p99 {lt['p99_ttft_s']*1e3:.1f} ms | TPOT p50 "
              f"{lt['p50_tpot_s']*1e3:.1f} ms / p99 "
              f"{lt['p99_tpot_s']*1e3:.1f} ms")
        # conservative floors (CI bench-smoke): on multi-node pools the
        # per-token path pays collectives + dispatch per token, so the
        # fused horizon must win structurally; the 1-node cell's
        # per-token path is already cheap (no merge traffic), so only a
        # catastrophic regression is gated there
        floor = 1.2 if n >= 2 else 0.8
        assert h_speed >= floor, \
            f"pool({n}) horizon speedup {h_speed:.2f}x < {floor}x floor"
    # -- degraded-mode cell: kill 1 of 4 nodes mid-run (the 2-node pool
    # under --quick; ``--fault-plan`` layers seeded fabric chaos on
    # top).  The worker asserts the chaos run's outputs are
    # token-identical to an uninterrupted run on an identically warmed
    # stack; the artifact records the recovery latency (kill -> victims
    # re-placed and decoding on survivors) and the goodput dip.
    dn = 4 if 4 in sizes else max(n for n in sizes if n >= 2)
    deg = run("degraded", dn,
              extra=[f"--fault-plan={fault_plan}"])["degraded"]
    assert deg["outputs_identical_after_kill"], \
        f"degraded({dn}) outputs diverged from the uninterrupted run"
    assert deg["recovery_s"] is not None and deg["requeues"] >= 1, \
        f"degraded({dn}) kill produced no failover requeue"
    result["degraded"] = dict(deg, nodes=dn)
    _csv(f"pool_degraded_{dn}", deg["recovery_s"] * 1e6,
         f"goodput={deg['goodput_vs_uninterrupted']:.2f},"
         f"requeues={deg['requeues']},plan={fault_plan}")
    print(f"  degraded ({dn} nodes, node {deg['killed_node']} killed "
          f"mid-run, plan={fault_plan}): outputs identical | recovery "
          f"{deg['recovery_s']*1e3:.0f} ms | goodput "
          f"{deg['goodput_vs_uninterrupted']:.2f}x of uninterrupted | "
          f"{deg['requeues']} requeued, {deg['rejected']} shed")
    # -- autoscale cell: open-loop Poisson traffic against the elastic
    # pool (steady -> burst -> cooldown).  The worker's Autoscaler grows
    # the serving set on the SLO breach and drains it back on sustained
    # headroom; a mid-cooldown maintenance drain retires a loaded node
    # so the warm path (live device-to-device page migration) is
    # exercised and MIGRATE-accounted.  The worker asserts its own
    # floors (zero shed requests, scale-up AND drain happened, recovery
    # recorded, zero MIGRATE frames while static) and exits non-zero on
    # any miss — the quick lane gates on that.
    asw = os.path.join(repo, "benchmarks", "autoscale_worker.py")
    out = subprocess.run(
        [_sys.executable, asw, "--nodes", "4", "--initial", "2"]
        + (["--quick"] if quick else []),
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    ascale = json.loads(out.stdout.splitlines()[-1])
    assert ascale["rejected"] == 0, "autoscale cell shed requests"
    assert ascale["peak_nodes"] > ascale["initial"] and \
        ascale["final_nodes"] == ascale["initial"]
    assert ascale["migrate_frames"] > 0, \
        "maintenance drain produced no MIGRATE frames"
    result["autoscale"] = ascale
    _csv("pool_autoscale", ascale["slo_recovery_s"] * 1e6,
         f"peak={ascale['peak_nodes']},rejected={ascale['rejected']},"
         f"migrated={ascale['migrate_frames']}")
    b = ascale["phases"]["burst"]
    print(f"  autoscale (Poisson {ascale['initial']}->"
          f"{ascale['peak_nodes']}->{ascale['final_nodes']} nodes): "
          f"SLO recovery {ascale['slo_recovery_s']*1e3:.0f} ms | "
          f"burst TTFT p50 {b['p50_ttft_s']*1e3:.0f} / p99 "
          f"{b['p99_ttft_s']*1e3:.0f} ms | "
          f"{ascale['migrate_frames']} pages migrated warm on drain | "
          f"{ascale['rejected']} shed")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"  outputs match the single-node reference on every pool size, "
          f"per-token and horizon (-> {out_path})")


# ---------------------------------------------------------------------------
# in-storage analytics — host-reads-everything vs in-storage reduce
# ---------------------------------------------------------------------------


def isp_offload(out_path="BENCH_isp.json", quick=False):
    """The paper's first headline claim, measured end to end: an
    analytics job (scan -> filter -> reduce) executed in-storage — JOB
    frame, containerized jitted Pallas kernel over the node's extent
    pages, reduced RESULTS frame back — vs the host baseline that ships
    the whole extent over the tunnel and folds it host-side.  Results
    must be bit-identical; the I/O-intensive configs (pattern,
    rocksdb-read) must clear >=2x, mirroring Fig 11's shape.  Writes
    ``BENCH_isp.json``."""
    import jax.numpy as jnp
    from repro.core import (AnalyticsJob, StoragePool, analytics_blob,
                            from_jsonable)
    from repro.core.analytical import data_plane_terms
    from repro.core.isp_perf import workload_scan_gbs
    from repro.kernels import ops
    from repro.runtime.offload import OffloadPlanner

    # Table-2-shaped workload configs (filter op = the workload's scan
    # flavour: pattern match counting, rocksdb key-range read, TPC-H
    # filtered aggregate).  Each carries its Table-2 per-byte compute
    # intensity (``workload_scan_gbs``) so the planner's modeled
    # host_s/dvirtfw_s differentiate pattern-find from mariadb-tpch4
    # instead of pricing every scan at the planner default.
    configs = [
        ("pattern-find", "eq", 0.25),
        ("rocksdb-read", "ge", 0.0),
    ] if quick else [
        ("pattern-find", "eq", 0.25),
        ("pattern-word", "ne", 0.0),
        ("rocksdb-read", "ge", 0.0),
        ("mariadb-tpch4", "lt", -0.5),
    ]
    rows = 8192 if quick else 16384
    cols = 128
    # flash superpages: fewer, larger grid steps amortize the CPU
    # interpret-mode per-page overhead (on TPU the same kernel runs at
    # HBM bandwidth regardless).  8 pages per extent in both sizes.
    page_rows = 1024 if quick else 2048
    reps = 5                          # best-of-N per path (noise guard)
    pool = StoragePool(
        len(configs),
        extent_cfg={"n_pages": rows // page_rows + 2,
                    "page_rows": page_rows, "n_cols": cols})
    pool.broadcast_pull("isp-analytics", analytics_blob())
    planner = OffloadPlanner(pool)
    rng = np.random.default_rng(0)

    jobs, ips = [], []
    for i, (name, op, thresh) in enumerate(configs):
        ip = pool.alive_nodes()[i]
        data = rng.normal(size=(rows, cols)).astype(np.float32)
        # quantize so `eq` matches make sense (token-id-like values)
        data[:, 0] = np.round(data[:, 0] * 2) / 8
        pool.nodes[ip].extents.put(name, data)
        prog, wname = name.split("-", 1)
        jobs.append(AnalyticsJob(extent=name, filter_col=0, filter_op=op,
                                 threshold=thresh, job_id=i,
                                 scan_gbs=workload_scan_gbs(prog, wname)))
        ips.append(ip)

    result = {"config": {"rows": rows, "cols": cols,
                         "page_rows": page_rows, "quick": quick,
                         "workloads": [c[0] for c in configs]},
              "workloads": {}}
    nbytes = rows * cols * 4
    for (name, op, thresh), job, ip in zip(configs, jobs, ips):
        est = planner.estimate(job)

        # host baseline: fetch every byte over the tunnel, fold on host
        def host_path():
            data = pool.driver.fetch_extent(ip, name)
            return np.asarray(ops.scan_filter_reduce_host(
                jnp.asarray(data), thresh, page_rows=page_rows,
                filter_col=0, filter_op=op))

        # in-storage: one JOB frame, jitted reduce at the node, one
        # RESULTS frame back
        def isp_path():
            out = pool.driver.submit_jobs(ip, [job.to_dict()])
            return from_jsonable(out)[0]

        def best_of(fn):
            fn()                                     # warm the jit
            best = None
            for _ in range(reps):
                t0 = time.perf_counter()
                out = fn()
                dt = time.perf_counter() - t0
                if best is None or dt < best[0]:
                    best = (dt, out)
            return best

        t_host, host_block = best_of(host_path)
        t_isp, isp_block = best_of(isp_path)

        identical = bool(np.array_equal(host_block, isp_block))
        speedup = t_host / t_isp
        result["workloads"][name] = {
            "bytes_scanned": nbytes,
            "host_s": t_host, "isp_s": t_isp,
            "measured_speedup": speedup,
            "bit_identical": identical,
            "modeled": {"host_s": est.host_s, "dvirtfw_s": est.dvirtfw_s,
                        "speedup": est.modeled_speedup,
                        "choice": est.choice,
                        "scan_gbs": job.scan_gbs},
        }
        _csv(f"isp_{name}", t_isp * 1e6,
             f"speedup={speedup:.1f}x,modeled={est.modeled_speedup:.1f}x")
        print(f"  {name:14s} host {t_host*1e3:8.1f} ms | in-storage "
              f"{t_isp*1e3:7.1f} ms | {speedup:5.1f}x measured, "
              f"{est.modeled_speedup:.1f}x modeled ({est.choice}) | "
              f"bit-identical {identical}")
        assert identical, f"{name}: in-storage result != host reference"
        if name.startswith(("pattern", "rocksdb")):
            assert speedup >= 2.0, \
                f"{name}: {speedup:.2f}x < 2x target on I/O-intensive config"

    # planner batch run across the pool (one JOB frame per node) —
    # data-plane terms are computed from the *delta* over this run, so
    # the host-baseline fetches timed above don't contaminate the
    # reduction ratio (same discipline as PR 1's tier-telemetry
    # snapshot)
    import copy
    import types
    s0 = copy.copy(vars(pool.driver.stats))
    recs = planner.execute(jobs)
    assert all(r["where"] == "device" for r in recs), \
        "cost model must offload every I/O-intensive config"
    delta = types.SimpleNamespace(**{
        k: v - s0[k] for k, v in vars(pool.driver.stats).items()})
    result["data_plane"] = data_plane_terms(
        delta, bytes_scanned=nbytes * len(jobs), n_jobs=len(jobs))
    assert result["data_plane"]["reduction_ratio"] > 100, \
        "in-storage reduce must move orders of magnitude fewer bytes"
    # quantized-extent cell: the same reduce over an int8 extent store
    # (codes + per-row f32 scales).  The dequantizing in-storage fold
    # must stay bit-identical to the host path (which now fetches
    # codes+scales over the tunnel and dequantizes at the far end), and
    # the planner must price the smaller reads
    qpool = StoragePool(1, extent_cfg={
        "n_pages": rows // page_rows + 2, "page_rows": page_rows,
        "n_cols": cols, "page_dtype": "int8"})
    qpool.broadcast_pull("isp-analytics", analytics_blob())
    qip = qpool.alive_nodes()[0]
    qdata = rng.normal(size=(rows, cols)).astype(np.float32)
    qpool.nodes[qip].extents.put("q-ext", qdata)
    qjob = AnalyticsJob(extent="q-ext", filter_col=0, filter_op="ge",
                        threshold=0.0, job_id=0)
    qplanner = OffloadPlanner(qpool)
    qest = qplanner.estimate(qjob)
    b0 = qpool.driver.stats.bytes_rx
    qhost = np.asarray(ops.scan_filter_reduce_host(
        jnp.asarray(qpool.driver.fetch_extent(qip, "q-ext")), 0.0,
        page_rows=page_rows, filter_col=0, filter_op="ge"))
    q_wire = qpool.driver.stats.bytes_rx - b0
    qisp = from_jsonable(qpool.driver.submit_jobs(qip,
                                                  [qjob.to_dict()]))[0]
    q_identical = bool(np.array_equal(qhost, qisp))
    result["quantized_extent"] = {
        "page_dtype": "int8",
        "bit_identical": q_identical,
        "nbytes_fp32": nbytes, "nbytes_int8": qest.bytes_scanned,
        "nbytes_ratio": nbytes / qest.bytes_scanned,
        "host_fetch_wire_bytes": q_wire,
        "wire_ratio": nbytes / q_wire,
    }
    print(f"  int8 extent: bit-identical {q_identical} | planner prices "
          f"{nbytes / qest.bytes_scanned:.1f}x fewer bytes | host fetch "
          f"moved {q_wire} B ({nbytes / q_wire:.1f}x less wire)")
    assert q_identical, "quantized in-storage fold != host dequant fold"
    assert nbytes / qest.bytes_scanned >= 2.0, \
        "int8 extents must at least halve the planner's priced bytes"
    assert nbytes / q_wire >= 2.0, \
        "int8 extents must at least halve the host-fetch wire bytes"

    # retrieval cell: scored top-k scan over a node-resident embedding
    # extent.  The in-storage reducer sends back only the padded (id,
    # score) block — the host baseline ships every embedding row over
    # the tunnel before it can rank anything.  Same wire-delta
    # discipline as the quantized cell; the 50x floor is the acceptance
    # bar for retrieval riding the RESULTS frame
    r_rows = 2048 if quick else 4096
    rk = 8
    rpool = StoragePool(1, extent_cfg={
        "n_pages": r_rows // page_rows + 2, "page_rows": page_rows,
        "n_cols": cols})
    rpool.broadcast_pull("isp-analytics", analytics_blob())
    rip = rpool.alive_nodes()[0]
    remb = rng.normal(size=(r_rows, cols)).astype(np.float32)
    rpool.nodes[rip].extents.put("corpus-embed", remb)
    rquery = rng.normal(size=(cols,)).astype(np.float32)
    rjob = AnalyticsJob(extent="corpus-embed", reduce="topk",
                        query=[float(x) for x in rquery], k=rk, job_id=0)
    rplanner = OffloadPlanner(rpool)
    rest = rplanner.estimate(rjob)
    rbytes = r_rows * cols * 4

    def r_host():
        data = rpool.driver.fetch_extent(rip, "corpus-embed")
        return np.asarray(ops.topk_scan_host(
            jnp.asarray(data), jnp.asarray(rquery), page_rows=page_rows,
            k=rk))

    def r_isp():
        out = rpool.driver.submit_jobs(rip, [rjob.to_dict()])
        return from_jsonable(out)[0]

    b0 = rpool.driver.stats.bytes_rx
    rhost_block = r_host()
    r_host_wire = rpool.driver.stats.bytes_rx - b0
    b1 = rpool.driver.stats.bytes_rx
    risp_block = r_isp()
    r_isp_wire = rpool.driver.stats.bytes_rx - b1
    t_rhost, _ = best_of(r_host)
    t_risp, _ = best_of(r_isp)
    r_identical = bool(np.array_equal(rhost_block, risp_block))
    r_wire_ratio = r_host_wire / r_isp_wire
    from repro.core.extent_store import project as _project
    top_pairs = _project(risp_block, rjob)
    result["retrieval"] = {
        "rows": r_rows, "cols": cols, "k": rk,
        "bit_identical": r_identical,
        "host_s": t_rhost, "isp_s": t_risp,
        "measured_speedup": t_rhost / t_risp,
        "extent_bytes": rbytes,
        "host_fetch_wire_bytes": r_host_wire,
        "topk_wire_bytes": r_isp_wire,
        "wire_reduction": r_wire_ratio,
        "modeled": {"host_s": rest.host_s, "dvirtfw_s": rest.dvirtfw_s,
                    "choice": rest.choice,
                    "result_bytes": rest.result_bytes},
        "top1": {"id": top_pairs[0][0], "score": top_pairs[0][1]},
    }
    _csv("isp_retrieval", t_risp * 1e6,
         f"wire={r_wire_ratio:.0f}x,k={rk},rows={r_rows}")
    print(f"  retrieval ({r_rows}x{cols}, k={rk}): bit-identical "
          f"{r_identical} | host fetch {r_host_wire} B vs top-k "
          f"{r_isp_wire} B ({r_wire_ratio:.0f}x less wire) | "
          f"{t_rhost / t_risp:.1f}x measured")
    assert r_identical, \
        "in-storage top-k != host reference fold (bit-identity broken)"
    assert r_wire_ratio >= 50, \
        f"top-k retrieval moved only {r_wire_ratio:.0f}x fewer wire " \
        f"bytes than host-fetches-all-extents (< 50x floor)"

    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    mn = min(w["measured_speedup"] for w in result["workloads"].values())
    print(f"  all configs bit-identical; min speedup {mn:.1f}x "
          f"(target >=2x on pattern/rocksdb) -> {out_path}")


# ---------------------------------------------------------------------------
# roofline table from dry-run artifacts
# ---------------------------------------------------------------------------


def roofline_table(path="results/probe.jsonl"):
    if not os.path.exists(path):
        print(f"  (no {path}; run `python -m repro.launch.probe --all`)")
        return
    best = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") != "ok":
                continue
            best[(r["arch"], r["shape"], r["mesh"])] = r
    _csv("roofline_table", 0.0, f"cells={len(best)}")
    print(f"  {'arch':24s}{'shape':13s}{'mesh':7s}{'compute_ms':>11s}"
          f"{'memory_ms':>10s}{'coll_ms':>9s}{'bottleneck':>11s}"
          f"{'useful':>7s}{'roofline%':>10s}")
    for (a, s, m), r in sorted(best.items()):
        t = r["roofline"]
        print(f"  {a:24s}{s:13s}{m:7s}{t['compute_s']*1e3:11.2f}"
              f"{t['memory_s']*1e3:10.2f}{t['collective_s']*1e3:9.2f}"
              f"{t['bottleneck']:>11s}{t['useful_flops_ratio']:7.2f}"
              f"{t['roofline_fraction']*100:10.1f}")


BENCHES = {
    "fig3": fig3_breakdown,
    "fig10": fig10_footprint,
    "fig11": fig11_overall,
    "fig12a": fig12a_parallelism,
    "fig12b": fig12b_llm_pool,
    "fig13": fig13_sensitivity,
    "table2": table2_workloads,
    "kernels": kernel_micro,
    "serve": serve_decode,
    "pool": pool_serving,
    "isp": isp_offload,
    "roofline": roofline_table,
}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benches", nargs="*", choices=[[]] + list(BENCHES),
                    help="benchmarks to run (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="serve: shorter gen + 2 horizons; "
                         "pool: 1/2 nodes instead of 1/2/4/8; "
                         "isp: 2 small workloads instead of 4 full-size")
    ap.add_argument("--fault-plan", default="none",
                    help="pool: seeded fabric fault plan for the "
                         "degraded-mode cell — a preset name "
                         "(none/lossy/storm), inline JSON, or a path "
                         "(repro.core.faults.load_plan)")
    args = ap.parse_args()
    which = args.benches or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        print(f"== {name} " + "=" * (66 - len(name)))
        if name == "pool":
            BENCHES[name](quick=args.quick, fault_plan=args.fault_plan)
        elif name in ("serve", "isp"):
            BENCHES[name](quick=args.quick)
        else:
            BENCHES[name]()


if __name__ == "__main__":
    main()
