"""AdamW with fully-sharded (same-spec-as-params) optimizer states."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1):
    """Returns (init_fn, update_fn).  States mirror the param tree, so the
    param PartitionSpecs apply verbatim (ZeRO: m/v sharded like params)."""

    def init_fn(params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update_fn(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else jnp.asarray(lr)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v)

    return init_fn, update_fn
