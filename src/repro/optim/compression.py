"""Gradient compression for cross-pod reduction (distributed-optimization
trick for 1000+ node scale).

Int8 row-wise quantization with **error feedback** (the residual of each
step is added to the next step's gradient), plus a cheap bf16 mode.
On real hardware this halves/quarters the bytes on the ``pod``-axis
gradient all-reduce; here the quantize/dequantize pipeline is exact code
(property-tested: with error feedback the *accumulated* update converges
to the true gradient sum).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quant_int8(x):
    """Row-wise symmetric int8 quantization.  x: f32[...]."""
    flat = x.reshape(x.shape[0], -1) if x.ndim > 1 else x.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


def compress_grads(grads, residuals, mode: str = "int8"):
    """Compress+decompress each gradient leaf with error feedback.

    Returns (decompressed_grads, new_residuals).  The decompressed value
    is what the (cheaper) collective would deliver; the residual carries
    the quantization error into the next step.
    """
    if mode == "none":
        return grads, residuals

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if mode == "bf16":
            out = g32.astype(jnp.bfloat16).astype(jnp.float32)
        else:
            q, s = _quant_int8(g32)
            out = _dequant_int8(q, s, g32.shape)
        return out.astype(g.dtype), g32 - out

    out = jax.tree.map(one, grads, residuals)
    dec = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return dec, res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(params, mode: str) -> int:
    """Bytes the gradient all-reduce would move under ``mode``."""
    per = {"none": 4, "bf16": 2, "int8": 1}[mode]
    return sum(p.size * per for p in jax.tree.leaves(params))
