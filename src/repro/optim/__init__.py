from repro.optim.adamw import adamw, clip_by_global_norm  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
