"""Checkpoint manager — fault-tolerant save/restore for 1000+ nodes.

  * **Atomic commits**: leaves are written to ``step_N.tmp/`` and the
    directory is renamed only after a manifest (tree structure, shapes,
    dtypes, step) is fully written — a crash mid-save never corrupts the
    latest checkpoint.
  * **Async saves**: a background thread serializes while training
    continues (the caller passes already-device-fetched arrays or jax
    arrays; fetching is the only sync point).
  * **Sharded layout**: each leaf is a separate ``.npy`` keyed by its
    tree path, so per-host shard saving parallelizes trivially and
    partial restores are possible.
  * **Elastic restore**: ``restore(..., mesh, specs)`` re-device_puts
    every leaf under the *new* mesh's NamedShardings — checkpoints move
    between 256-chip and 512-chip (or degraded) meshes freely.
  * λFS integration: with ``fs=`` the blobs are stored inside a
    DockerSSD's private namespace (the pool's disaggregated checkpoint
    store) instead of the local filesystem.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 fs=None, fs_prefix: str = "/ckpt"):
        self.dir = directory
        self.keep = keep
        self.fs = fs
        self.fs_prefix = fs_prefix
        self._save_thread: Optional[threading.Thread] = None
        self._last_error: Optional[Exception] = None
        if fs is None:
            os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = True):
        """Serialize a pytree.  With blocking=False the write happens on a
        background thread (async checkpointing)."""
        arrays = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        struct = jax.tree.map(lambda x: None, tree)
        treedef = jax.tree_util.tree_structure(struct)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in arrays.items()},
            "treedef": str(treedef),
        }
        if blocking:
            self._write(step, arrays, manifest)
        else:
            self.wait()
            self._save_thread = threading.Thread(
                target=self._write_guarded, args=(step, arrays, manifest),
                daemon=True)
            self._save_thread.start()

    def _write_guarded(self, step, arrays, manifest):
        try:
            self._write(step, arrays, manifest)
        except Exception as e:  # surfaced on next wait()
            self._last_error = e

    def _write(self, step, arrays, manifest):
        if self.fs is not None:
            base = f"{self.fs_prefix}/step_{step}.tmp"
            for k, v in arrays.items():
                buf = io.BytesIO()
                np.save(buf, v)
                self.fs.write(f"{base}/{k.replace('/', '__')}.npy",
                              buf.getvalue())
            self.fs.write(f"{base}/manifest.json",
                          json.dumps(manifest).encode())
            # atomic commit: write the manifest pointer last
            self.fs.write(f"{self.fs_prefix}/step_{step}/COMMITTED",
                          json.dumps(manifest).encode())
            for name in self.fs.listdir(base):
                self.fs.symlink(f"{base}/{name}",
                                f"{self.fs_prefix}/step_{step}/{name}")
            return
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in arrays.items():
            np.save(os.path.join(tmp, k.replace("/", "__") + ".npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # the atomic commit point
        self._gc()

    def wait(self):
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"))

    # -- restore ----------------------------------------------------------------

    def steps(self):
        if self.fs is not None:
            names = [n for n in self.fs.listdir(self.fs_prefix)
                     if n.startswith("step_") and not n.endswith(".tmp")]
            return sorted(int(n.split("_")[1]) for n in names)
        if not os.path.isdir(self.dir):
            return []
        return sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                      if d.startswith("step_") and not d.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, *, step: Optional[int] = None,
                mesh=None, specs=None) -> Any:
        """Restore into the structure of ``template``.  With mesh+specs the
        leaves are device_put under the new mesh (elastic resharding)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoints")
        keys = _flatten(template)

        def load(k):
            fname = k.replace("/", "__") + ".npy"
            if self.fs is not None:
                data = self.fs.read(f"{self.fs_prefix}/step_{step}/{fname}")
                return np.load(io.BytesIO(data))
            return np.load(os.path.join(self.dir, f"step_{step}", fname))

        flat_loaded = {k: load(k) for k in keys}
        leaves_order = list(keys.keys())
        treedef = jax.tree_util.tree_structure(template)
        arrays = [flat_loaded[k] for k in leaves_order]
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding
            tree = jax.tree.map(
                lambda a, sp: jax.device_put(
                    a, NamedSharding(mesh, sp) if not isinstance(
                        sp, NamedSharding) else sp),
                tree, specs)
        return tree
