"""Deterministic sharded data pipeline with straggler mitigation.

Design goals for 1000+ nodes:
  * **Determinism** — batch contents are a pure function of
    (seed, step, shard), so an elastic re-shard or restart replays the
    exact stream with no coordination.
  * **Prefetch** — a background thread keeps ``prefetch_depth`` batches
    ready (hides host-side generation/fetch latency).
  * **Straggler mitigation** — every fetch is issued to a primary and,
    after ``backup_after_ms``, to a backup worker; first result wins
    (the classic tail-latency double-issue).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np


def synthetic_stream(seed: int, step: int, shard: int, *, batch: int,
                     seq_len: int, vocab: int,
                     kind: str = "random") -> Dict[str, np.ndarray]:
    """Pure function of (seed, step, shard) -> one shard's batch.

    kind="learnable": cyclic token runs (next token is predictable), for
    loss-decrease integration tests; kind="random": uniform tokens.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))
    if kind == "learnable":
        start = rng.integers(0, vocab, size=(batch, 1), dtype=np.int32)
        stride = rng.integers(1, 4, size=(batch, 1), dtype=np.int32)
        pos = np.arange(seq_len, dtype=np.int32)[None, :]
        tokens = (start + stride * pos) % vocab
    else:
        tokens = rng.integers(0, vocab, size=(batch, seq_len),
                              dtype=np.int32)
    labels = np.concatenate(
        [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
    return {"tokens": tokens, "labels": labels}


class ShardedLoader:
    """Per-host loader for one data shard of the global batch."""

    def __init__(self, *, global_batch: int, seq_len: int, vocab: int,
                 n_shards: int, shard: int, seed: int = 0,
                 prefetch_depth: int = 2,
                 fetch_fn: Optional[Callable] = None,
                 backup_after_ms: float = 50.0, kind: str = "random"):
        assert global_batch % n_shards == 0
        self.batch = global_batch // n_shards
        self.seq_len = seq_len
        self.vocab = vocab
        self.n_shards = n_shards
        self.shard = shard
        self.seed = seed
        self.kind = kind
        self.step = 0
        self.backup_after_ms = backup_after_ms
        self.stats = {"fetches": 0, "backups_issued": 0, "backup_wins": 0}
        self._fetch = fetch_fn or self._default_fetch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _default_fetch(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic_stream(self.seed, step, self.shard,
                                batch=self.batch, seq_len=self.seq_len,
                                vocab=self.vocab, kind=self.kind)

    # -- straggler-mitigated fetch ------------------------------------------

    def _fetch_with_backup(self, step: int) -> Dict[str, np.ndarray]:
        """Issue to a primary worker; if it exceeds backup_after_ms, issue
        a duplicate to a backup and take whichever finishes first."""
        self.stats["fetches"] += 1
        result: "queue.Queue" = queue.Queue()

        def work(tag):
            try:
                result.put((tag, self._fetch(step)))
            except Exception as e:  # pragma: no cover
                result.put((tag, e))

        t1 = threading.Thread(target=work, args=("primary",), daemon=True)
        t1.start()
        try:
            tag, out = result.get(timeout=self.backup_after_ms / 1e3)
        except queue.Empty:
            self.stats["backups_issued"] += 1
            t2 = threading.Thread(target=work, args=("backup",), daemon=True)
            t2.start()
            tag, out = result.get()
            if tag == "backup":
                self.stats["backup_wins"] += 1
        if isinstance(out, Exception):
            raise out
        return out

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._fetch_with_backup(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def reshard(self, n_shards: int, shard: int) -> "ShardedLoader":
        """Elastic re-partition: same stream semantics under a new mesh."""
        self.close()
        return ShardedLoader(global_batch=self.batch * self.n_shards,
                             seq_len=self.seq_len, vocab=self.vocab,
                             n_shards=n_shards, shard=shard, seed=self.seed,
                             backup_after_ms=self.backup_after_ms,
                             kind=self.kind)

    def close(self):
        self._stop.set()
