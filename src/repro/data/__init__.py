from repro.data.pipeline import ShardedLoader, synthetic_stream  # noqa: F401
