"""λFS — DockerSSD's backend media manager.

Reproduces the paper's design: the media is partitioned into two NVMe
namespaces —

  * **private-NS** — container/OS-virtualization runtime state
    (``/images/``, ``/containers/<id>/rootfs/``); *invisible to the
    host* (host access raises ``PermissionError``).
  * **sharable-NS** — data the host places/retrieves and ISP-containers
    process; guarded by **inode locks**: a reference counter on the
    host-VFS inode, synchronized over Ether-oN.  A file is accessible
    to an ISP-container only when the host refcount is zero; while the
    container holds the lock the host's inode cache is invalidated.
    Locks are synchronization-only and non-persistent (power failure
    clears them; the host restores the FS and restarts the container).

Also implements the I/O-handler services the paper lists: *path
walking* (LBA->filename mapping) with an *I/O-node cache*, plus
counters that feed the Fig-3/Fig-11 cost models.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

PRIVATE_NS = "private"
SHARABLE_NS = "sharable"
BLOCK = 4096


class LambdaFSError(Exception):
    pass


class LockHeld(LambdaFSError):
    pass


@dataclasses.dataclass
class Inode:
    ino: int
    path: str
    kind: str                   # "file" | "dir" | "symlink"
    ns: str
    data: bytes = b""
    target: str = ""            # symlink target
    host_refcount: int = 0      # host VFS openers (inode lock)
    container_holder: Optional[str] = None
    ctime: float = 0.0


class Stats:
    def __init__(self):
        self.path_walks = 0
        self.node_cache_hits = 0
        self.reads = self.writes = 0
        self.bytes_read = self.bytes_written = 0
        self.lock_syncs = 0


class LambdaFS:
    """One DockerSSD's filesystem.  Thread-safe; deterministic."""

    def __init__(self, capacity_bytes: int = 400 * 10 ** 9):
        self._lock = threading.RLock()
        self.capacity = capacity_bytes
        self.used = 0
        self._next_ino = 2
        self._inodes: Dict[str, Inode] = {}      # (ns, path) keyed
        self._node_cache: Dict[str, int] = {}    # path -> ino (I/O node cache)
        self.stats = Stats()
        self._ether = None                        # Ether-oN hook (lock sync)
        for ns in (PRIVATE_NS, SHARABLE_NS):
            self._inodes[self._key(ns, "/")] = Inode(
                1, "/", "dir", ns)

    def attach_ether(self, ether):
        self._ether = ether

    @staticmethod
    def _key(ns, path):
        return f"{ns}:{path.rstrip('/') or '/'}"

    # -- path walking (LBA -> filename mapping, with node cache) ------------

    def _walk(self, ns: str, path: str, *, create_dirs: bool = False) -> str:
        """Walk components, counting walks; returns normalized path."""
        parts = [p for p in path.split("/") if p]
        cur = ""
        for comp in parts[:-1] if parts else []:
            cur += "/" + comp
            key = self._key(ns, cur)
            if key in self._node_cache:
                self.stats.node_cache_hits += 1
            else:
                self.stats.path_walks += 1
                if key not in self._inodes:
                    if not create_dirs:
                        raise FileNotFoundError(f"{ns}:{cur}")
                    self._mknod(ns, cur, "dir")
                self._node_cache[key] = self._inodes[key].ino
        return "/" + "/".join(parts)

    def _mknod(self, ns, path, kind) -> Inode:
        ino = Inode(self._next_ino, path, kind, ns, ctime=time.monotonic())
        self._next_ino += 1
        self._inodes[self._key(ns, path)] = ino
        return ino

    def _get(self, ns, path) -> Inode:
        key = self._key(ns, path)
        if key not in self._inodes:
            raise FileNotFoundError(key)
        node = self._inodes[key]
        if node.kind == "symlink":
            return self._get(ns, node.target)
        return node

    # -- namespace protection ------------------------------------------------

    def _check_host_access(self, ns):
        if ns == PRIVATE_NS:
            raise PermissionError(
                "private-NS is exposed only on Virtual-FW's PCIe function; "
                "the host's function maps the sharable-NS only")

    # -- inode locks (host <-> ISP-container concurrency) --------------------

    def host_open(self, path: str, ns: str = SHARABLE_NS) -> Inode:
        with self._lock:
            self._check_host_access(ns)
            node = self._get(ns, self._walk(ns, path))
            if node.container_holder is not None:
                raise LockHeld(f"{path} held by ISP-container "
                               f"{node.container_holder}")
            node.host_refcount += 1
            self._sync_lock(node)
            return node

    def host_close(self, path: str, ns: str = SHARABLE_NS):
        with self._lock:
            self._check_host_access(ns)
            node = self._get(ns, path)
            if node.host_refcount <= 0:
                raise LambdaFSError("close without open")
            node.host_refcount -= 1
            self._sync_lock(node)

    def container_bind(self, path: str, container_id: str,
                       ns: str = SHARABLE_NS) -> Inode:
        """Bind a host FS file/dir into λFS for processing.  Grantable only
        when the host inode refcount is zero."""
        with self._lock:
            node = self._get(ns, self._walk(ns, path))
            if node.host_refcount != 0:
                raise LockHeld(f"{path} opened by host "
                               f"(refcount={node.host_refcount})")
            if (node.container_holder is not None
                    and node.container_holder != container_id):
                raise LockHeld(f"{path} held by {node.container_holder}")
            node.container_holder = container_id
            self._sync_lock(node)   # host VFS invalidates its inode cache
            return node

    def container_release(self, path: str, container_id: str,
                          ns: str = SHARABLE_NS):
        with self._lock:
            node = self._get(ns, path)
            if node.container_holder != container_id:
                raise LambdaFSError("release by non-holder")
            node.container_holder = None
            self._sync_lock(node)

    def _sync_lock(self, node):
        """Send the lock-sync special packet over Ether-oN (if attached)."""
        self.stats.lock_syncs += 1
        if self._ether is not None:
            self._ether.send_lock_sync(node.path, node.host_refcount,
                                       node.container_holder)

    def power_failure(self):
        """Locks are non-persistent: a crash clears them (the host restores
        the FS and restarts ISP-containers from their initial state)."""
        with self._lock:
            for node in self._inodes.values():
                node.host_refcount = 0
                node.container_holder = None
            self._node_cache.clear()

    # -- file ops (used by the I/O handler + mini-docker) ---------------------

    def write(self, path: str, data: bytes, ns: str = PRIVATE_NS,
              actor: str = "fw"):
        with self._lock:
            if actor == "host":
                self._check_host_access(ns)
            norm = self._walk(ns, path, create_dirs=True)
            key = self._key(ns, norm)
            node = self._inodes.get(key) or self._mknod(ns, norm, "file")
            delta = len(data) - len(node.data)
            if self.used + delta > self.capacity:
                raise LambdaFSError("ENOSPC")
            self.used += delta
            node.data = data
            self.stats.writes += 1
            self.stats.bytes_written += len(data)

    def append(self, path: str, data: bytes, ns: str = PRIVATE_NS):
        with self._lock:
            try:
                old = self._get(ns, path).data
            except FileNotFoundError:
                old = b""
            self.write(path, old + data, ns)

    def read(self, path: str, ns: str = PRIVATE_NS,
             actor: str = "fw") -> bytes:
        with self._lock:
            if actor == "host":
                self._check_host_access(ns)
            node = self._get(ns, self._walk(ns, path))
            self.stats.reads += 1
            self.stats.bytes_read += len(node.data)
            return node.data

    def mkdir(self, path: str, ns: str = PRIVATE_NS):
        with self._lock:
            norm = self._walk(ns, path, create_dirs=True)
            if self._key(ns, norm) not in self._inodes:
                self._mknod(ns, norm, "dir")

    def symlink(self, target: str, path: str, ns: str = PRIVATE_NS):
        with self._lock:
            norm = self._walk(ns, path, create_dirs=True)
            node = self._mknod(ns, norm, "symlink")
            node.target = target

    def unlink(self, path: str, ns: str = PRIVATE_NS):
        with self._lock:
            key = self._key(ns, path)
            if key in self._inodes:
                node = self._inodes.pop(key)
                self.used -= len(node.data)
                self._node_cache.pop(key, None)

    def rmtree(self, path: str, ns: str = PRIVATE_NS):
        """Remove a directory subtree (every inode at or under ``path``)
        — container teardown must not strand rootfs files/symlinks."""
        with self._lock:
            prefix = self._key(ns, path)
            for key in [k for k in self._inodes
                        if k == prefix or k.startswith(prefix + "/")]:
                node = self._inodes.pop(key)
                self.used -= len(node.data)
                self._node_cache.pop(key, None)

    def listdir(self, path: str, ns: str = PRIVATE_NS):
        with self._lock:
            prefix = path.rstrip("/") + "/"
            out = []
            for key, node in self._inodes.items():
                kns, kpath = key.split(":", 1)
                if kns == ns and kpath.startswith(prefix) and kpath != prefix:
                    rest = kpath[len(prefix):]
                    if "/" not in rest:
                        out.append(rest)
            return sorted(out)

    def exists(self, path: str, ns: str = PRIVATE_NS) -> bool:
        try:
            self._get(ns, self._walk(ns, path))
            return True
        except (FileNotFoundError, LambdaFSError):
            return False
