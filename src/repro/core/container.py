"""mini-docker — firmware-level container environment.

Implements the paper's 11 essential Docker commands (of 106): image
management (pull, rmi), container life cycle (create, run, start, stop,
restart, kill, rm) and monitoring (logs, ps).  Images are blobs +
manifests stored in λFS's private-NS under ``/images/``; a container's
rootfs is an overlay of read-only image layers (*lower*) and a writable
*upper* directory, mounted at ``/containers/<id>/rootfs``; stdout and
stderr are logged to ``/containers/<id>/rootfs/log``.

The "application" inside an image is a registered Python callable (the
workload kernel — e.g. the DLRM embed loop or a decode-serving loop),
executed with the container's namespace-isolated FS view and a
cgroup-style memory budget.
"""
from __future__ import annotations

import dataclasses
import io
import json
import time
import urllib.parse
import zlib
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.lambda_fs import PRIVATE_NS, SHARABLE_NS, LambdaFS

MINI_DOCKER_COMMANDS = ["pull", "rmi", "create", "run", "start", "stop",
                        "restart", "kill", "rm", "logs", "ps"]

# global registry of containerized applications (entry-point callables)
APP_REGISTRY: Dict[str, Callable] = {}


def register_app(name: str):
    def deco(fn):
        APP_REGISTRY[name] = fn
        return fn
    return deco


class ContainerError(Exception):
    pass


class ContainerOOM(ContainerError, MemoryError):
    """A running app allocated past its cgroup-style ``mem_budget``.

    Subclasses both ContainerError (the container API contract: budget
    violations are container failures, the container transitions to
    ``dead``) and MemoryError (the POSIX-shaped signal an OOM-killed
    workload sees)."""


def to_jsonable(obj):
    """JSON-encode app results losslessly: ndarrays become tagged hex
    blobs (bit-exact across the wire — floats never round-trip through
    decimal), containers recurse, scalars pass through."""
    if isinstance(obj, np.ndarray):
        return {"__ndarray__": obj.tobytes().hex(),
                "shape": list(obj.shape), "dtype": str(obj.dtype)}
    if isinstance(obj, (np.integer, np.floating)):
        return obj.item()
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(x) for x in obj]
    if isinstance(obj, dict):
        return {k: to_jsonable(v) for k, v in obj.items()}
    return obj


def from_jsonable(obj):
    if isinstance(obj, dict):
        if "__ndarray__" in obj:
            return np.frombuffer(
                bytes.fromhex(obj["__ndarray__"]), obj["dtype"]
            ).reshape(obj["shape"]).copy()
        return {k: from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [from_jsonable(x) for x in obj]
    return obj


def parse_query(query: str) -> Dict[str, str]:
    """docker-cli query-string parsing, ``parse_qsl`` style: valueless
    keys (``?detach``) map to ``""`` and values keep embedded ``=``
    (``?job=a=b``) instead of crashing ``dict(kv.split("="))``."""
    args: Dict[str, str] = {}
    for kv in query.split("&"):
        if not kv:
            continue
        k, _, v = kv.partition("=")
        args[urllib.parse.unquote_plus(k)] = urllib.parse.unquote_plus(v)
    return args


@dataclasses.dataclass
class ImageManifest:
    name: str
    entry: str                       # app registry key
    layers: List[str]
    config: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> bytes:
        return json.dumps(dataclasses.asdict(self)).encode()

    @staticmethod
    def from_json(data: bytes) -> "ImageManifest":
        return ImageManifest(**json.loads(data))


def make_blob(manifest: ImageManifest, layer_data: Dict[str, bytes]) -> bytes:
    """A docker blob: compressed manifest + layers."""
    body = json.dumps({
        "manifest": json.loads(manifest.to_json()),
        "layers": {k: v.hex() for k, v in layer_data.items()},
    }).encode()
    return zlib.compress(body)


@dataclasses.dataclass
class ISPContainer:
    cid: str
    image: str
    entry: str
    state: str = "created"           # created|running|exited|dead
    exit_code: Optional[int] = None
    mem_budget: int = 1 << 30        # cgroup-style budget
    mem_used: int = 0
    created_at: float = 0.0


class MiniDocker:
    """Runs inside Virtual-FW; speaks docker-cli's HTTP dialect."""

    def __init__(self, fw, fs: LambdaFS, extents=None):
        self.fw = fw
        self.fs = fs
        self.extents = extents          # core.extent_store.ExtentStore
        self._containers: Dict[str, ISPContainer] = {}
        self._next_id = 0
        fs.mkdir("/images/blobs", PRIVATE_NS)
        fs.mkdir("/images/manifest", PRIVATE_NS)
        fs.mkdir("/containers", PRIVATE_NS)

    # -- HTTP REST front door (docker-cli compatible shape) --------------------

    def handle_http(self, request: str, body: bytes = b"") -> bytes:
        """e.g. 'POST /images/create?fromImage=embed' (blob in ``body``),
        'POST /containers/3/start?job=<json>' or 'GET /containers/3/logs'.

        Malformed requests return a 400-shaped JSON error instead of
        raising into the Ether-oN handler."""
        try:
            method, rest = request.split(" ", 1)
            path, _, query = rest.partition("?")
            args = parse_query(query)
            return self._route(method, path, args, body)
        except ContainerError as e:
            return json.dumps({"error": str(e), "status": 400}).encode()
        except Exception as e:      # malformed request, bad args, app error
            return json.dumps({"error": f"{type(e).__name__}: {e}",
                               "status": 400}).encode()

    def _route(self, method: str, path: str, args: Dict[str, str],
               body: bytes) -> bytes:
        def reply(obj) -> bytes:
            return obj if isinstance(obj, bytes) \
                else json.dumps(to_jsonable(obj)).encode()

        parts = [p for p in path.split("/") if p]
        if not parts:
            raise ContainerError(f"bad path {path}")
        if parts[0] == "images":
            if parts[-1] == "json":
                return reply(self.images())
            if path == "/images/create":
                name = args.get("fromImage", "")
                if not name or not body:
                    raise ContainerError(
                        "pull needs ?fromImage=<name> and the blob as the "
                        "request body")
                return reply({"status": "pulled",
                              "name": self.cmd_pull(name, body).name})
            raise ContainerError(f"bad path {path}")
        if parts[0] != "containers":
            raise ContainerError(f"bad path {path}")
        if parts[-1] == "json":
            return reply(self.cmd_ps())
        if path == "/containers/create":
            return reply({"Id": self.cmd_create(
                args["image"], mem_budget=int(args.get("mem", 1 << 30)))})
        if path == "/containers/run":
            cid, out = self.cmd_run(args["image"], **self._start_kwargs(args))
            return reply({"Id": cid, "result": out})
        cid = parts[1]
        action = parts[2] if len(parts) > 2 else ""
        if method == "DELETE" or action == "rm":
            self.cmd_rm(cid)
            return reply({"status": "removed"})
        if action == "start":
            return reply({"result": self.cmd_start(
                cid, **self._start_kwargs(args))})
        if action == "restart":
            return reply({"result": self.cmd_restart(
                cid, **self._start_kwargs(args))})
        fn = {"stop": self.cmd_stop, "kill": self.cmd_kill,
              "logs": self.cmd_logs}.get(action)
        if fn is None:
            raise ContainerError(f"bad action {action!r}")
        return reply(fn(cid))

    @staticmethod
    def _start_kwargs(args: Dict[str, str]) -> Dict[str, Any]:
        """Query args an app start accepts: ``job=<json>`` carries an
        analytics program list (the docker-cli front door for the
        in-storage analytics path)."""
        kw: Dict[str, Any] = {}
        if args.get("job"):
            jobs = json.loads(args["job"])
            kw["jobs"] = jobs if isinstance(jobs, list) else [jobs]
        return kw

    # -- image management -------------------------------------------------------

    def cmd_pull(self, name: str, blob: bytes) -> ImageManifest:
        """1. retrieve blob -> 2. unpack per image spec -> store in λFS."""
        self.fs.write(f"/images/blobs/{name}", blob, PRIVATE_NS)
        body = json.loads(zlib.decompress(blob))
        manifest = ImageManifest(**body["manifest"])
        self.fs.write(f"/images/manifest/{name}", manifest.to_json(),
                      PRIVATE_NS)
        for lname, hexdata in body["layers"].items():
            self.fs.write(f"/images/layers/{name}/{lname}",
                          bytes.fromhex(hexdata), PRIVATE_NS)
        return manifest

    def cmd_rmi(self, name: str):
        self.fs.unlink(f"/images/blobs/{name}", PRIVATE_NS)
        self.fs.unlink(f"/images/manifest/{name}", PRIVATE_NS)
        for layer in self.fs.listdir(f"/images/layers/{name}", PRIVATE_NS):
            self.fs.unlink(f"/images/layers/{name}/{layer}", PRIVATE_NS)

    def images(self) -> List[str]:
        return self.fs.listdir("/images/manifest", PRIVATE_NS)

    # -- container life cycle ----------------------------------------------------

    def cmd_create(self, image: str, mem_budget: int = 1 << 30) -> str:
        if not self.fs.exists(f"/images/manifest/{image}", PRIVATE_NS):
            raise ContainerError(f"image {image} not pulled")
        manifest = ImageManifest.from_json(
            self.fs.read(f"/images/manifest/{image}", PRIVATE_NS))
        self._next_id += 1
        cid = str(self._next_id)
        # rootfs = read-only lower (image layers) + writable upper, merged
        root = f"/containers/{cid}/rootfs"
        self.fs.mkdir(root, PRIVATE_NS)
        self.fs.mkdir(f"/containers/{cid}/upper", PRIVATE_NS)
        for layer in manifest.layers:
            self.fs.symlink(f"/images/layers/{image}/{layer}",
                            f"{root}/{layer}", PRIVATE_NS)
        self.fs.write(f"{root}/log", b"", PRIVATE_NS)
        self._containers[cid] = ISPContainer(
            cid=cid, image=image, entry=manifest.entry,
            mem_budget=mem_budget, created_at=time.monotonic())
        return cid

    def cmd_start(self, cid: str, *args, **kw) -> Any:
        c = self._container(cid)
        if c.state == "running":
            raise ContainerError(f"{cid} already running")
        app = APP_REGISTRY.get(c.entry)
        if app is None:
            raise ContainerError(f"entry {c.entry} not registered")
        c.state = "running"
        self._log(cid, f"start entry={c.entry}\n")
        try:
            ctx = ContainerContext(self, c)
            result = app(ctx, *args, **kw)
            c.state = "exited"
            c.exit_code = 0
            self._log(cid, "exit code=0\n")
            return result
        except MemoryError as e:
            # ContainerOOM lands here too (it is-a MemoryError): budget
            # violations kill the container, docker-style exit 137
            c.state = "dead"
            c.exit_code = 137
            self._log(cid, f"OOM-killed: {e}\n")
            raise
        except Exception as e:  # stderr -> log
            c.state = "exited"
            c.exit_code = 1
            self._log(cid, f"stderr: {type(e).__name__}: {e}\n")
            raise

    def cmd_run(self, image: str, *args, **kw):
        cid = self.cmd_create(image)
        return cid, self.cmd_start(cid, *args, **kw)

    def cmd_stop(self, cid: str):
        c = self._container(cid)
        if c.state == "running":
            c.state = "exited"
            c.exit_code = 0
            self._log(cid, "stop\n")
        return {"status": "exited"}

    def cmd_restart(self, cid: str, *args, **kw):
        self.cmd_stop(cid)
        return self.cmd_start(cid, *args, **kw)

    def cmd_kill(self, cid: str):
        c = self._container(cid)
        c.state = "dead"
        c.exit_code = 137
        self._log(cid, "killed\n")
        return {"status": "dead"}

    def cmd_rm(self, cid: str):
        c = self._container(cid)
        if c.state == "running":
            raise ContainerError("cannot rm a running container")
        self._containers.pop(cid)
        # whole container subtree: log, rootfs params (job.json), layer
        # symlinks and the upper dir — nothing strands λFS space
        self.fs.rmtree(f"/containers/{cid}", PRIVATE_NS)

    # -- monitoring ---------------------------------------------------------------

    def cmd_logs(self, cid: str) -> bytes:
        return self.fs.read(f"/containers/{cid}/rootfs/log", PRIVATE_NS)

    def cmd_ps(self) -> List[dict]:
        return [{"id": c.cid, "image": c.image, "state": c.state,
                 "exit_code": c.exit_code}
                for c in self._containers.values()]

    # -- internals ------------------------------------------------------------------

    def _container(self, cid: str) -> ISPContainer:
        if cid not in self._containers:
            raise ContainerError(f"no container {cid}")
        return self._containers[cid]

    def _log(self, cid: str, msg: str):
        self.fs.append(f"/containers/{cid}/rootfs/log", msg.encode(),
                       PRIVATE_NS)


class ContainerContext:
    """What a containerized app sees: namespaced FS, syscalls, logging,
    cgroup memory accounting."""

    def __init__(self, docker: MiniDocker, container: ISPContainer):
        self._docker = docker
        self.c = container
        self.fw = docker.fw
        self.fs = docker.fs
        self.extents = docker.extents

    def log(self, msg: str):
        self._docker._log(self.c.cid, msg if msg.endswith("\n") else msg + "\n")

    def syscall(self, name: str, *a, **kw):
        return self.fw.syscall(name, *a, **kw)

    def alloc(self, nbytes: int):
        if self.c.mem_used + nbytes > self.c.mem_budget:
            raise ContainerOOM(
                f"cgroup budget exceeded: {self.c.mem_used + nbytes} > "
                f"{self.c.mem_budget}")
        self.c.mem_used += nbytes

    def free(self, nbytes: int):
        self.c.mem_used = max(0, self.c.mem_used - nbytes)

    def bind(self, path: str):
        """Bind a sharable-NS file for processing (takes the inode lock)."""
        return self.fs.container_bind(path, self.c.cid, SHARABLE_NS)

    def release(self, path: str):
        self.fs.container_release(path, self.c.cid, SHARABLE_NS)
