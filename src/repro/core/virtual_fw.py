"""Virtual-FW — the lightweight firmware stack.

Reproduces the paper's design points:

  * **Three handlers** between HIL and ICL: thread (65 syscalls), I/O
    (43), network (25) — Table 1a.  System calls are emulated as plain
    function dispatch ("function management cost"), with NO user/kernel
    boundary: no context switch on return, unlike a fully-fledged OS.
  * **Memory pools**: page-granular FW-pool (handler tables; privileged
    mode only, enforced by the MPU model) and ISP-pool (call args and
    data).  Privileged mode may touch the ISP pool directly — no
    copy/mode-switch overhead between pools.
  * **TCP finite state machine** in the network handler.
  * **Binary footprint model** (Fig 10: ~83x smaller than Linux).

The cost constants let the Fig-3/Fig-11 models compare a Virtual-FW
syscall (function call) against host/embedded-Linux syscalls and
context switches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

PAGE = 4096

# latency constants (us) used by the perf models
FUNC_CALL_US = 0.05          # Virtual-FW emulated syscall ~ function cost
HOST_SYSCALL_US = 0.8        # 3.8 GHz host kernel crossing
EMBEDDED_SYSCALL_US = 2.6    # full Linux on 2.2 GHz embedded cores
CONTEXT_SWITCH_US = 4.0      # kernel context switch

# Fig 10: binary sizes (bytes)
LINUX_BINARY_BYTES = int(250e6)          # kernel+rootfs userland stack
VIRTUAL_FW_BYTES = int(LINUX_BINARY_BYTES / 83.4)

THREAD_SYSCALLS = [
    # process management
    "fork", "vfork", "execve", "exit", "exit_group", "wait4", "waitid",
    "getpid", "getppid", "gettid", "clone", "kill", "tgkill", "rt_sigaction",
    "rt_sigprocmask", "rt_sigreturn", "sigaltstack", "setpgid", "getpgid",
    "setsid", "getsid", "prctl", "arch_prctl", "sched_yield",
    "sched_getaffinity", "sched_setaffinity", "getpriority", "setpriority",
    # memory management
    "brk", "mmap", "munmap", "mprotect", "mremap", "msync", "madvise",
    "mlock", "munlock", "membarrier",
    # IPC
    "pipe", "pipe2", "mq_open", "mq_unlink", "mq_timedsend",
    "mq_timedreceive", "shmget", "shmat", "shmdt", "semget", "semop",
    "msgget", "msgsnd", "msgrcv",
    # lock & signal mgmt
    "futex", "set_robust_list", "get_robust_list", "nanosleep",
    "clock_gettime", "clock_nanosleep", "timer_create", "timer_settime",
    "timerfd_create", "timerfd_settime", "eventfd2", "signalfd4",
    "getrusage",
]
IO_SYSCALLS = [
    # file/dir mgmt
    "openat", "open", "creat", "close", "mkdir", "mkdirat", "rmdir",
    "rename", "renameat", "unlink", "unlinkat", "getdents64", "getcwd",
    "chdir", "fchdir", "truncate", "ftruncate", "statx", "fstat", "newfstatat",
    # file I/O & link
    "read", "write", "pread64", "pwrite64", "readv", "writev", "lseek",
    "symlink", "symlinkat", "readlink", "readlinkat", "link", "linkat",
    "fsync", "fdatasync", "fallocate", "copy_file_range", "sendfile",
    # permission
    "chmod", "fchmod", "chown", "fchown", "umask",
]
NETWORK_SYSCALLS = [
    # polling
    "epoll_create", "epoll_create1", "epoll_ctl", "epoll_wait", "poll",
    "ppoll", "select", "pselect6",
    # socket
    "socket", "socketpair", "bind", "listen", "accept", "accept4",
    "connect", "shutdown", "getsockname", "getpeername", "setsockopt",
    "getsockopt",
    # comm
    "sendto", "recvfrom", "sendmsg", "recvmsg", "sendmmsg",
]
assert len(THREAD_SYSCALLS) == 65, len(THREAD_SYSCALLS)
assert len(IO_SYSCALLS) == 43, len(IO_SYSCALLS)
assert len(NETWORK_SYSCALLS) == 25, len(NETWORK_SYSCALLS)


class MPUViolation(Exception):
    pass


class MemoryPools:
    """Bare-metal DRAM in page-granular partitions."""

    def __init__(self, fw_pages: int = 4096, isp_pages: int = 262144):
        self.fw_pool = {}
        self.isp_pool = {}
        self.fw_pages = fw_pages
        self.isp_pages = isp_pages
        self.privileged = False

    def fw_write(self, page: int, value):
        if not self.privileged:
            raise MPUViolation("FW-pool requires privileged CPU mode")
        self.fw_pool[page] = value

    def fw_read(self, page: int):
        if not self.privileged:
            raise MPUViolation("FW-pool requires privileged CPU mode")
        return self.fw_pool.get(page)

    def isp_write(self, page: int, value):
        # privileged mode accesses the ISP pool directly (no copy between
        # pools, no mode-switch overhead) — and so does user mode.
        self.isp_pool[page] = value

    def isp_read(self, page: int):
        return self.isp_pool.get(page)


class TCPConn:
    STATES = ["CLOSED", "LISTEN", "SYN_SENT", "SYN_RCVD", "ESTABLISHED",
              "FIN_WAIT_1", "FIN_WAIT_2", "CLOSE_WAIT", "LAST_ACK",
              "TIME_WAIT"]
    _T = {
        ("CLOSED", "passive_open"): "LISTEN",
        ("CLOSED", "active_open"): "SYN_SENT",
        ("LISTEN", "syn"): "SYN_RCVD",
        ("SYN_SENT", "syn_ack"): "ESTABLISHED",
        ("SYN_RCVD", "ack"): "ESTABLISHED",
        ("ESTABLISHED", "close"): "FIN_WAIT_1",
        ("ESTABLISHED", "fin"): "CLOSE_WAIT",
        ("FIN_WAIT_1", "ack"): "FIN_WAIT_2",
        ("FIN_WAIT_2", "fin"): "TIME_WAIT",
        ("CLOSE_WAIT", "close"): "LAST_ACK",
        ("LAST_ACK", "ack"): "CLOSED",
        ("TIME_WAIT", "timeout"): "CLOSED",
    }

    def __init__(self):
        self.state = "CLOSED"

    def event(self, ev: str):
        key = (self.state, ev)
        if key not in self._T:
            raise ValueError(f"invalid TCP transition {key}")
        self.state = self._T[key]
        return self.state


class VirtualFW:
    """Firmware runtime: handler dispatch + λFS + network FSM."""

    def __init__(self, fs, endpoint=None):
        self.fs = fs
        self.endpoint = endpoint
        self.pools = MemoryPools()
        self.syscall_counts: Dict[str, int] = {}
        self.emulated_us = 0.0
        self._fds: Dict[int, str] = {}
        self._next_fd = 3
        self._next_isp_page = 0
        self._conns: Dict[int, TCPConn] = {}
        self._handler_of = {}
        for name in THREAD_SYSCALLS:
            self._handler_of[name] = "thread"
        for name in IO_SYSCALLS:
            self._handler_of[name] = "io"
        for name in NETWORK_SYSCALLS:
            self._handler_of[name] = "network"
        # install handler tables in the FW pool (privileged)
        self.pools.privileged = True
        self.pools.fw_write(0, {"thread": THREAD_SYSCALLS,
                                "io": IO_SYSCALLS,
                                "network": NETWORK_SYSCALLS})
        self.pools.privileged = False

    # -- syscall emulation: a plain function dispatch -------------------------

    def syscall(self, name: str, *args, **kw):
        if name not in self._handler_of:
            raise NotImplementedError(f"syscall {name} not emulated")
        self.syscall_counts[name] = self.syscall_counts.get(name, 0) + 1
        self.emulated_us += FUNC_CALL_US   # no kernel boundary, no ctx switch
        impl = getattr(self, f"_sys_{name}", None)
        if impl is not None:
            return impl(*args, **kw)
        return 0  # table-dispatched no-op (counted, costed)

    # representative functional implementations
    def _sys_openat(self, path, ns="private", **kw):
        fd = self._next_fd
        self._next_fd += 1
        self._fds[fd] = (ns, path)
        return fd

    _sys_open = _sys_openat

    def _sys_close(self, fd):
        self._fds.pop(fd, None)
        for c in list(self._conns):
            if c == fd:
                self._conns.pop(c)
        return 0

    def _sys_read(self, fd, n=-1):
        ns, path = self._fds[fd]
        data = self.fs.read(path, ns)
        return data if n < 0 else data[:n]

    def _sys_write(self, fd, data: bytes):
        ns, path = self._fds[fd]
        self.fs.append(path, data, ns)
        return len(data)

    def _sys_mkdir(self, path, ns="private"):
        self.fs.mkdir(path, ns)
        return 0

    def _sys_symlink(self, target, path, ns="private"):
        self.fs.symlink(target, path, ns)
        return 0

    def _sys_socket(self, *a):
        fd = self._next_fd
        self._next_fd += 1
        self._conns[fd] = TCPConn()
        return fd

    def _sys_bind(self, fd, addr):
        return 0

    def _sys_listen(self, fd, backlog=16):
        self._conns[fd].event("passive_open")
        return 0

    def _sys_connect(self, fd, addr):
        self._conns[fd].event("active_open")
        self._conns[fd].event("syn_ack")
        return 0

    def _sys_accept(self, fd):
        conn_fd = self._sys_socket()
        self._conns[conn_fd].event("passive_open")
        self._conns[conn_fd].event("syn")
        self._conns[conn_fd].event("ack")
        return conn_fd

    def _sys_sendto(self, fd, data: bytes, dst_ip: str = "10.0.0.1"):
        if self.endpoint is not None:
            self.endpoint.send_to_host(data, dst_ip)
        return len(data)

    # -- ISP job buffers (call args in the ISP memory pool) --------------------

    def stage_job(self, payload: bytes) -> List[int]:
        """Copy call args into page-granular ISP-pool buffers.

        The ISP pool is user-mode accessible (no copy or mode switch
        between pools — the paper's point); the FW pool would trap in
        the MPU model.  The pool is finite (``MemoryPools.isp_pages``):
        callers must :meth:`free_job` when the job retires.  Returns the
        page ids the containerized app reads back with
        :meth:`read_job`."""
        n = max(1, -(-len(payload) // PAGE))
        if len(self.pools.isp_pool) + n > self.pools.isp_pages:
            raise MemoryError(
                f"ISP pool exhausted: {len(self.pools.isp_pool)} pages "
                f"in use of {self.pools.isp_pages}, need {n} more")
        pages = []
        for off in range(0, max(len(payload), 1), PAGE):
            pid = self._next_isp_page
            self._next_isp_page += 1
            self.pools.isp_write(pid, payload[off:off + PAGE])
            pages.append(pid)
        return pages

    def read_job(self, pages: List[int]) -> bytes:
        return b"".join(self.pools.isp_read(p) or b"" for p in pages)

    def free_job(self, pages: List[int]):
        for p in pages:
            self.pools.isp_pool.pop(p, None)

    # -- footprint model (Fig 10) ---------------------------------------------

    @staticmethod
    def binary_footprint() -> dict:
        return {
            "linux_bytes": LINUX_BINARY_BYTES,
            "virtual_fw_bytes": VIRTUAL_FW_BYTES,
            "reduction": LINUX_BINARY_BYTES / VIRTUAL_FW_BYTES,
        }
