"""ExtentStore — device-resident analytics data, in stacked pages.

The analytics sibling of ``core.kv_tier.PageStore``: one pool of
stacked pages ``[n_pages, page_rows, n_cols]`` (float32) holds every
extent's rows, an *extent* is a named run of physical pages plus a row
count, and the jitted scan/filter/reduce kernel
(``kernels.isp_scan``) consumes the pool directly through a per-extent
page table — the flash the paper's ISP-containers process in place.

A MiniDocker analytics app is no longer an opaque callable: it is an
:class:`AnalyticsJob` — a declarative scan -> filter -> reduce program
that serializes to JSON (so it rides Ether-oN job frames and λFS
rootfs params) and executes as one jitted Pallas kernel over the
node's extent pages.  The registered ``isp-analytics`` image is the
single generic interpreter for these programs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.container import (ContainerError, ImageManifest, make_blob,
                                  register_app)
from repro.core.kv_tier import (PAGE_DTYPES, _fp8_dtype, dequantize_page_kv,
                                quantize_page_kv)
from repro.kernels import ops
from repro.kernels.isp_scan import (BIG_ID, FILTER_OPS, MAX_TOPK,
                                    REDUCE_ROWS, TOPK_METRICS, topk_pad)

#: the generic analytics image every DockerSSD runs (entry = the program
#: interpreter below)
ANALYTICS_IMAGE = "isp-analytics"

#: host-side projections of the kernel's aggregate block ("topk" runs
#: the scored-scan reducer instead of scan/filter/reduce)
REDUCE_KINDS = ("count", "sum", "min", "max", "avg", "table", "topk")


class ExtentStoreError(Exception):
    pass


@dataclasses.dataclass
class Extent:
    name: str
    page_ids: List[int]
    n_rows: int
    n_cols: int                     # logical columns (<= store n_cols)
    # stored bytes per row (codes + per-row scale for quantized
    # stores); None falls back to f32 rows — keeping old pickles valid
    row_bytes: Optional[int] = None

    @property
    def nbytes(self) -> int:
        """Stored bytes the host baseline must move to read this —
        dtype-aware, so the OffloadPlanner prices quantized extent
        reads at their (smaller) real transfer size."""
        if self.row_bytes is not None:
            return self.n_rows * self.row_bytes
        return self.n_rows * self.n_cols * 4


class ExtentStore:
    """One DockerSSD's flash-resident analytics pages.

    ``pages``: [n_pages, page_rows, n_cols] float32.  Extents are
    page-granular allocations out of a free list (mirroring λFS block
    allocation); the kernel addresses them through per-extent page
    tables, so extents never need to be physically contiguous.
    """

    def __init__(self, *, n_pages: int = 64, page_rows: int = 128,
                 n_cols: int = 128, page_dtype: str = "fp32"):
        if page_dtype not in PAGE_DTYPES:
            raise ValueError(f"page_dtype must be one of {PAGE_DTYPES}, "
                             f"got {page_dtype!r}")
        if page_dtype == "fp8" and _fp8_dtype() is None:
            raise ValueError("page_dtype='fp8' needs jnp.float8_e4m3fn "
                             "(unavailable on this jax build); use 'int8'")
        self.n_pages = n_pages
        self.page_rows = page_rows
        self.n_cols = n_cols
        self.page_dtype = page_dtype
        self.quantized = page_dtype in ("int8", "fp8")
        if page_dtype == "int8":
            self.code_dtype, self.qmax = jnp.int8, 127.0
        elif page_dtype == "fp8":
            self.code_dtype, self.qmax = _fp8_dtype(), 448.0
        else:
            self.code_dtype, self.qmax = jnp.float32, 0.0
        self.pages = jnp.zeros((n_pages, page_rows, n_cols),
                               self.code_dtype)
        # per-row scales of a quantized pool (1.0 keeps untouched pages
        # dequantizing to zero); None for full precision
        self.scales = (jnp.ones((n_pages, page_rows), jnp.float32)
                       if self.quantized else None)
        self.extents: Dict[str, Extent] = {}
        self._free: List[int] = list(range(n_pages))

    # -- capacity ------------------------------------------------------------

    @property
    def row_nbytes(self) -> int:
        """Stored bytes per row: codes (+ the row's f32 scale when
        quantized)."""
        per = self.n_cols * jnp.dtype(self.code_dtype).itemsize
        return per + (4 if self.quantized else 0)

    @property
    def page_nbytes(self) -> int:
        return self.page_rows * self.row_nbytes

    def free_pages(self) -> int:
        return len(self._free)

    # -- extent life cycle ----------------------------------------------------

    def put(self, name: str, arr: np.ndarray) -> Extent:
        """Ingest host data as a new extent (pad rows to page granularity,
        pad columns to the store width)."""
        arr = np.asarray(arr, np.float32)
        if arr.ndim != 2:
            raise ExtentStoreError(f"extent data must be 2-D [rows, cols], "
                                   f"got shape {arr.shape}")
        rows, cols = arr.shape
        if cols > self.n_cols:
            raise ExtentStoreError(f"extent has {cols} cols; store width "
                                   f"is {self.n_cols}")
        if name in self.extents:
            raise ExtentStoreError(f"extent {name!r} already exists")
        need = -(-max(rows, 1) // self.page_rows)
        if need > len(self._free):
            raise ExtentStoreError(
                f"ENOSPC: extent {name!r} needs {need} pages, "
                f"{len(self._free)} free")
        ids = [self._free.pop(0) for _ in range(need)]
        padded = np.zeros((need * self.page_rows, self.n_cols), np.float32)
        padded[:rows, :cols] = arr
        blocks = padded.reshape(need, self.page_rows, self.n_cols)
        idx = jnp.asarray(ids, jnp.int32)
        if self.quantized:
            # per-row symmetric quantization at ingest: the flash holds
            # codes + a [page_rows] scale column per page
            codes, scale = quantize_page_kv(jnp.asarray(blocks),
                                            self.qmax, self.code_dtype)
            self.pages = self.pages.at[idx].set(codes)
            self.scales = self.scales.at[idx].set(scale)
        else:
            self.pages = self.pages.at[idx].set(jnp.asarray(blocks))
        ext = Extent(name, ids, rows, cols, row_bytes=self.row_nbytes)
        self.extents[name] = ext
        return ext

    def get(self, name: str) -> np.ndarray:
        """Read a whole extent back to the host (the baseline's full
        transfer; the ISP path never calls this).  Quantized extents
        dequantize host-side — the same elementwise f32 multiply the
        kernel applies per page in VMEM, so a page-sequential fold over
        this array is bit-identical to the in-storage path."""
        ext = self._extent(name)
        idx = jnp.asarray(ext.page_ids, jnp.int32)
        pages = self.pages[idx]
        if self.quantized:
            pages = dequantize_page_kv(pages, self.scales[idx])
        flat = np.asarray(pages).reshape(-1, self.n_cols)
        return flat[:ext.n_rows, :ext.n_cols]

    def raw_extent(self, name: str):
        """The extent as stored: ``(codes [n_rows, n_cols], scales
        [n_rows] | None)`` — what crosses the wire on a remote read
        (Ether-oN data frames ship the quantized bytes, never an
        inflated f32 copy; the reader dequantizes at the far end)."""
        ext = self._extent(name)
        idx = jnp.asarray(ext.page_ids, jnp.int32)
        codes = np.asarray(self.pages[idx]).reshape(-1, self.n_cols)
        codes = codes[:ext.n_rows, :ext.n_cols]
        if not self.quantized:
            return codes, None
        scales = np.asarray(self.scales[idx]).reshape(-1)[:ext.n_rows]
        return codes, scales

    def drop(self, name: str):
        ext = self.extents.pop(name, None)
        if ext is not None:
            self._free.extend(ext.page_ids)

    def page_table(self, name: str) -> jnp.ndarray:
        return jnp.asarray(self._extent(name).page_ids, jnp.int32)

    def _extent(self, name: str) -> Extent:
        if name not in self.extents:
            raise ExtentStoreError(f"no extent {name!r}")
        return self.extents[name]


# ---------------------------------------------------------------------------
# the analytics program (what a MiniDocker app now *is*)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalyticsJob:
    """A declarative scan -> filter -> reduce program over one extent.

    Serializes to JSON, so the same object rides the docker-cli front
    door (``start?job=...``), Ether-oN job frames, and λFS rootfs
    params.  ``reduce`` picks the host-visible projection of the
    kernel's aggregate block; ``table`` returns the full block (what
    the correctness contract compares bit-for-bit)."""
    extent: str
    filter_col: int = 0
    filter_op: str = "all"          # one of kernels.isp_scan.FILTER_OPS
    threshold: float = 0.0
    reduce: str = "table"           # one of REDUCE_KINDS
    reduce_col: int = 0
    job_id: int = 0
    # operator intensity hint: effective GB/s the operator scans at on
    # the host (0 = the planner's default).  Low values mark a
    # compute-bound operator — the per-request input that flips the
    # offload decision to the host (Fig 11's losing regime).
    scan_gbs: float = 0.0
    # retrieval (reduce="topk"): the query vector (zero-padded to the
    # store width at execution), result count, and scoring metric
    query: Optional[List[float]] = None
    k: int = 0
    metric: str = "dot"             # one of kernels.isp_scan.TOPK_METRICS

    def validate(self):
        if self.filter_op not in FILTER_OPS:
            raise ContainerError(f"bad filter_op {self.filter_op!r}; "
                                 f"expected one of {FILTER_OPS}")
        if self.reduce not in REDUCE_KINDS:
            raise ContainerError(f"bad reduce {self.reduce!r}; "
                                 f"expected one of {REDUCE_KINDS}")
        if self.reduce == "topk":
            if not self.query:
                raise ContainerError("topk job needs a query vector")
            if not 1 <= self.k <= MAX_TOPK:
                raise ContainerError(f"topk k must be in [1, {MAX_TOPK}], "
                                     f"got {self.k}")
            if self.metric not in TOPK_METRICS:
                raise ContainerError(f"bad metric {self.metric!r}; "
                                     f"expected one of {TOPK_METRICS}")
        elif self.query is not None:
            raise ContainerError(f"query only applies to reduce='topk', "
                                 f"not {self.reduce!r}")
        return self

    def padded_query(self, n_cols: int) -> np.ndarray:
        """The query zero-padded to the executing store's width — the
        same padding ``ExtentStore.put`` applied to narrow extents, so
        padded columns contribute 0 to every score on both paths."""
        qv = np.asarray(self.query, np.float32)
        if qv.ndim != 1 or qv.shape[0] > n_cols:
            raise ContainerError(f"query must be 1-D with <= {n_cols} "
                                 f"entries, got shape {qv.shape}")
        q = np.zeros((n_cols,), np.float32)
        q[:qv.shape[0]] = qv
        return q

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "AnalyticsJob":
        return AnalyticsJob(**d).validate()


def project(block: np.ndarray, job: AnalyticsJob):
    """Host-side projection of the kernel's [8, n_cols] aggregate."""
    if job.reduce == "table":
        return block
    if job.reduce == "topk":
        # [[row_id, score], ...] best-first; (NEG_INF, BIG_ID) empty
        # slots (k > n_rows) are dropped
        scores, ids = block[0], block[1]
        return [[int(i), float(s)]
                for i, s in zip(ids[:job.k], scores[:job.k]) if i < BIG_ID]
    if job.reduce == "count":
        return float(block[0, 0])
    col = job.reduce_col
    if job.reduce == "sum":
        return float(block[1, col])
    if job.reduce == "min":
        return float(block[2, col])
    if job.reduce == "max":
        return float(block[3, col])
    n = block[0, 0]
    return float(block[1, col] / n) if n else float("nan")   # avg


def analytics_blob() -> bytes:
    """The docker blob every node pulls: the generic analytics image."""
    return make_blob(
        ImageManifest(ANALYTICS_IMAGE, ANALYTICS_IMAGE,
                      ["kernel-layer", "runtime-layer"],
                      config={"kernel": "scan_filter_reduce"}),
        {"kernel-layer": b"pallas scan/filter/reduce",
         "runtime-layer": b"job interpreter"})


@register_app(ANALYTICS_IMAGE)
def isp_analytics(ctx, jobs=None, job_pages=None):
    """The containerized analytics interpreter.

    Parameters arrive the D-VirtFW way: packaged in the container's
    rootfs (λFS ``job.json``, read through function-call syscalls — no
    Kernel-ctx) with the raw call args staged in the MPU-checked ISP
    memory pool.  Each job executes as one jitted Pallas
    ``scan_filter_reduce`` over the node's extent pages and returns the
    reduced aggregate — the only bytes that travel back to the host.
    """
    if jobs is None:
        # rootfs-packaged params: /containers/<cid>/rootfs/job.json
        fd = ctx.syscall("openat", f"/containers/{ctx.c.cid}/rootfs/job.json")
        raw = ctx.syscall("read", fd)
        ctx.syscall("close", fd)
        jobs = json.loads(raw)
    jobs = [j if isinstance(j, AnalyticsJob) else AnalyticsJob.from_dict(j)
            for j in jobs]
    if job_pages is not None:
        # call args staged in the ISP pool (user-mode readable; the FW
        # pool would trap) — verify the MPU-checked buffer round-trips.
        # Compare canonicalized: clients may send sparse dicts and let
        # AnalyticsJob defaults fill the rest.
        staged = [AnalyticsJob.from_dict(d).to_dict()
                  for d in json.loads(ctx.fw.read_job(job_pages))]
        if staged != [j.to_dict() for j in jobs]:
            raise ContainerError("ISP-pool job buffer does not match "
                                 "rootfs params")
    store = ctx.extents
    if store is None:
        raise ContainerError("node has no ExtentStore attached")
    results = []
    for job in jobs:
        if job.extent not in store.extents:
            raise ContainerError(f"no extent {job.extent!r} on this node")
        # cgroup accounting: one VMEM-resident page + the aggregate
        out_cols = topk_pad(job.k) if job.reduce == "topk" else store.n_cols
        work = store.page_nbytes + REDUCE_ROWS * out_cols * 4
        ctx.alloc(work)
        try:
            if job.reduce == "topk":
                block = ops.topk_scan(
                    store.pages, store.page_table(job.extent),
                    store.extents[job.extent].n_rows,
                    job.padded_query(store.n_cols),
                    k=job.k, metric=job.metric, scales=store.scales)
            else:
                block = ops.scan_filter_reduce(
                    store.pages, store.page_table(job.extent),
                    store.extents[job.extent].n_rows, job.threshold,
                    scales=store.scales,
                    filter_col=job.filter_col, filter_op=job.filter_op)
            results.append(np.asarray(jax.block_until_ready(block)))
        finally:
            ctx.free(work)
        ctx.log(f"job {job.job_id}: scanned {job.extent} "
                f"({store.extents[job.extent].n_rows} rows) "
                f"filter={job.filter_op} -> {job.reduce}")
    return results
