"""ExtentStore — device-resident analytics data, in stacked pages.

The analytics sibling of ``core.kv_tier.PageStore``: one pool of
stacked pages ``[n_pages, page_rows, n_cols]`` (float32) holds every
extent's rows, an *extent* is a named run of physical pages plus a row
count, and the jitted scan/filter/reduce kernel
(``kernels.isp_scan``) consumes the pool directly through a per-extent
page table — the flash the paper's ISP-containers process in place.

A MiniDocker analytics app is no longer an opaque callable: it is an
:class:`AnalyticsJob` — a declarative scan -> filter -> reduce program
that serializes to JSON (so it rides Ether-oN job frames and λFS
rootfs params) and executes as one jitted Pallas kernel over the
node's extent pages.  The registered ``isp-analytics`` image is the
single generic interpreter for these programs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.container import (ContainerError, ImageManifest, make_blob,
                                  register_app)
from repro.kernels import ops
from repro.kernels.isp_scan import FILTER_OPS, REDUCE_ROWS

#: the generic analytics image every DockerSSD runs (entry = the program
#: interpreter below)
ANALYTICS_IMAGE = "isp-analytics"

#: host-side projections of the kernel's aggregate block
REDUCE_KINDS = ("count", "sum", "min", "max", "avg", "table")


class ExtentStoreError(Exception):
    pass


@dataclasses.dataclass
class Extent:
    name: str
    page_ids: List[int]
    n_rows: int
    n_cols: int                     # logical columns (<= store n_cols)

    @property
    def nbytes(self) -> int:
        """Logical bytes the host baseline must move to read this."""
        return self.n_rows * self.n_cols * 4


class ExtentStore:
    """One DockerSSD's flash-resident analytics pages.

    ``pages``: [n_pages, page_rows, n_cols] float32.  Extents are
    page-granular allocations out of a free list (mirroring λFS block
    allocation); the kernel addresses them through per-extent page
    tables, so extents never need to be physically contiguous.
    """

    def __init__(self, *, n_pages: int = 64, page_rows: int = 128,
                 n_cols: int = 128):
        self.n_pages = n_pages
        self.page_rows = page_rows
        self.n_cols = n_cols
        self.pages = jnp.zeros((n_pages, page_rows, n_cols), jnp.float32)
        self.extents: Dict[str, Extent] = {}
        self._free: List[int] = list(range(n_pages))

    # -- capacity ------------------------------------------------------------

    @property
    def page_nbytes(self) -> int:
        return self.page_rows * self.n_cols * 4

    def free_pages(self) -> int:
        return len(self._free)

    # -- extent life cycle ----------------------------------------------------

    def put(self, name: str, arr: np.ndarray) -> Extent:
        """Ingest host data as a new extent (pad rows to page granularity,
        pad columns to the store width)."""
        arr = np.asarray(arr, np.float32)
        if arr.ndim != 2:
            raise ExtentStoreError(f"extent data must be 2-D [rows, cols], "
                                   f"got shape {arr.shape}")
        rows, cols = arr.shape
        if cols > self.n_cols:
            raise ExtentStoreError(f"extent has {cols} cols; store width "
                                   f"is {self.n_cols}")
        if name in self.extents:
            raise ExtentStoreError(f"extent {name!r} already exists")
        need = -(-max(rows, 1) // self.page_rows)
        if need > len(self._free):
            raise ExtentStoreError(
                f"ENOSPC: extent {name!r} needs {need} pages, "
                f"{len(self._free)} free")
        ids = [self._free.pop(0) for _ in range(need)]
        padded = np.zeros((need * self.page_rows, self.n_cols), np.float32)
        padded[:rows, :cols] = arr
        blocks = padded.reshape(need, self.page_rows, self.n_cols)
        self.pages = self.pages.at[jnp.asarray(ids, jnp.int32)].set(
            jnp.asarray(blocks))
        ext = Extent(name, ids, rows, cols)
        self.extents[name] = ext
        return ext

    def get(self, name: str) -> np.ndarray:
        """Read a whole extent back to the host (the baseline's full
        transfer; the ISP path never calls this)."""
        ext = self._extent(name)
        flat = np.asarray(
            self.pages[jnp.asarray(ext.page_ids, jnp.int32)]
        ).reshape(-1, self.n_cols)
        return flat[:ext.n_rows, :ext.n_cols]

    def drop(self, name: str):
        ext = self.extents.pop(name, None)
        if ext is not None:
            self._free.extend(ext.page_ids)

    def page_table(self, name: str) -> jnp.ndarray:
        return jnp.asarray(self._extent(name).page_ids, jnp.int32)

    def _extent(self, name: str) -> Extent:
        if name not in self.extents:
            raise ExtentStoreError(f"no extent {name!r}")
        return self.extents[name]


# ---------------------------------------------------------------------------
# the analytics program (what a MiniDocker app now *is*)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalyticsJob:
    """A declarative scan -> filter -> reduce program over one extent.

    Serializes to JSON, so the same object rides the docker-cli front
    door (``start?job=...``), Ether-oN job frames, and λFS rootfs
    params.  ``reduce`` picks the host-visible projection of the
    kernel's aggregate block; ``table`` returns the full block (what
    the correctness contract compares bit-for-bit)."""
    extent: str
    filter_col: int = 0
    filter_op: str = "all"          # one of kernels.isp_scan.FILTER_OPS
    threshold: float = 0.0
    reduce: str = "table"           # one of REDUCE_KINDS
    reduce_col: int = 0
    job_id: int = 0
    # operator intensity hint: effective GB/s the operator scans at on
    # the host (0 = the planner's default).  Low values mark a
    # compute-bound operator — the per-request input that flips the
    # offload decision to the host (Fig 11's losing regime).
    scan_gbs: float = 0.0

    def validate(self):
        if self.filter_op not in FILTER_OPS:
            raise ContainerError(f"bad filter_op {self.filter_op!r}; "
                                 f"expected one of {FILTER_OPS}")
        if self.reduce not in REDUCE_KINDS:
            raise ContainerError(f"bad reduce {self.reduce!r}; "
                                 f"expected one of {REDUCE_KINDS}")
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "AnalyticsJob":
        return AnalyticsJob(**d).validate()


def project(block: np.ndarray, job: AnalyticsJob):
    """Host-side projection of the kernel's [8, n_cols] aggregate."""
    if job.reduce == "table":
        return block
    if job.reduce == "count":
        return float(block[0, 0])
    col = job.reduce_col
    if job.reduce == "sum":
        return float(block[1, col])
    if job.reduce == "min":
        return float(block[2, col])
    if job.reduce == "max":
        return float(block[3, col])
    n = block[0, 0]
    return float(block[1, col] / n) if n else float("nan")   # avg


def analytics_blob() -> bytes:
    """The docker blob every node pulls: the generic analytics image."""
    return make_blob(
        ImageManifest(ANALYTICS_IMAGE, ANALYTICS_IMAGE,
                      ["kernel-layer", "runtime-layer"],
                      config={"kernel": "scan_filter_reduce"}),
        {"kernel-layer": b"pallas scan/filter/reduce",
         "runtime-layer": b"job interpreter"})


@register_app(ANALYTICS_IMAGE)
def isp_analytics(ctx, jobs=None, job_pages=None):
    """The containerized analytics interpreter.

    Parameters arrive the D-VirtFW way: packaged in the container's
    rootfs (λFS ``job.json``, read through function-call syscalls — no
    Kernel-ctx) with the raw call args staged in the MPU-checked ISP
    memory pool.  Each job executes as one jitted Pallas
    ``scan_filter_reduce`` over the node's extent pages and returns the
    reduced aggregate — the only bytes that travel back to the host.
    """
    if jobs is None:
        # rootfs-packaged params: /containers/<cid>/rootfs/job.json
        fd = ctx.syscall("openat", f"/containers/{ctx.c.cid}/rootfs/job.json")
        raw = ctx.syscall("read", fd)
        ctx.syscall("close", fd)
        jobs = json.loads(raw)
    jobs = [j if isinstance(j, AnalyticsJob) else AnalyticsJob.from_dict(j)
            for j in jobs]
    if job_pages is not None:
        # call args staged in the ISP pool (user-mode readable; the FW
        # pool would trap) — verify the MPU-checked buffer round-trips.
        # Compare canonicalized: clients may send sparse dicts and let
        # AnalyticsJob defaults fill the rest.
        staged = [AnalyticsJob.from_dict(d).to_dict()
                  for d in json.loads(ctx.fw.read_job(job_pages))]
        if staged != [j.to_dict() for j in jobs]:
            raise ContainerError("ISP-pool job buffer does not match "
                                 "rootfs params")
    store = ctx.extents
    if store is None:
        raise ContainerError("node has no ExtentStore attached")
    results = []
    for job in jobs:
        if job.extent not in store.extents:
            raise ContainerError(f"no extent {job.extent!r} on this node")
        # cgroup accounting: one VMEM-resident page + the aggregate
        work = store.page_nbytes + REDUCE_ROWS * store.n_cols * 4
        ctx.alloc(work)
        try:
            block = ops.scan_filter_reduce(
                store.pages, store.page_table(job.extent),
                store.extents[job.extent].n_rows, job.threshold,
                filter_col=job.filter_col, filter_op=job.filter_op)
            results.append(np.asarray(jax.block_until_ready(block)))
        finally:
            ctx.free(work)
        ctx.log(f"job {job.job_id}: scanned {job.extent} "
                f"({store.extents[job.extent].n_rows} rows) "
                f"filter={job.filter_op} -> {job.reduce}")
    return results
