"""ISP data-processing performance model — Figures 3 and 11, Table 2.

Implements ALL SIX data-processing models of the paper over the 13
workloads of Table 2:

  * ``Host``     — baseline non-ISP system.
  * ``P.ISP-R``  — programmable ISP, RPC interface (Willow-style [3]).
  * ``P.ISP-V``  — programmable ISP, NVMe vendor-specific commands
                   (Biscuit-style [4]); no RPC/network responses.
  * ``D-Naive``  — ISP-container on a separate processor complex running
                   full Linux (SDC'18-style [30]): inter-complex copies.
  * ``D-FullOS`` — container + firmware on one complex, full Linux.
  * ``D-VirtFW`` — DockerSSD: Virtual-FW function-call syscalls, λFS
                   (no LBA-set), rootfs-packaged params (no Kernel-ctx).

Latency decomposes into the paper's six components: Network,
Kernel-ctx, LBA-set, Storage, System, Compute.  Workload characteristics
are the exact Table 2 constants.  Cost constants are calibrated
(benchmarks/calibrate.py) to the paper's aggregate claims:
Fig 3 (Storage ~38% of Host; P.ISP ~1.4x Host e2e; Communicate ~43% of
P.ISP) and Fig 11 (D-VirtFW beats P.ISP-R/V 1.6x, D-Naive 1.8x,
D-FullOS 1.6x, Host 1.3x; P.ISP-V 13.7% under P.ISP-R; D-FullOS +9.3%
over P.ISP-V; D-Naive +12.8% over D-FullOS; P.ISP beats Host only on
rocksdb-read / nginx-filedown).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.virtual_fw import (CONTEXT_SWITCH_US, EMBEDDED_SYSCALL_US,
                                   FUNC_CALL_US, HOST_SYSCALL_US)


@dataclasses.dataclass(frozen=True)
class Workload:
    program: str
    name: str
    io_size_gb: float
    io_count: float
    syscalls: float
    path_walks: float
    files_opened: float
    tcp_packets: float
    exec_time_s: float


# Table 2, verbatim.  (nginx-web0's TCP count is printed as "543M" in the
# paper table — inconsistent with its 9 s runtime; we read it as 543K,
# matching web1/filedown's magnitude, and note the discrepancy.)
WORKLOADS: List[Workload] = [
    Workload("embed", "rm1", 1.3, 317e3, 1.3e6, 9e3, 260, 0, 8),
    Workload("embed", "rm2", 5.8, 1.4e6, 1.7e6, 9e3, 320, 0, 24),
    Workload("mariadb", "tpch4", 17.1, 1.1e6, 1.1e6, 37e3, 250, 160, 25),
    Workload("mariadb", "tpch11", 6.2, 400e3, 361e3, 38e3, 260, 190, 8),
    Workload("rocksdb", "read", 4.1, 431e3, 1.1e6, 9e3, 1.2e3, 0, 14),
    Workload("rocksdb", "write", 18.5, 24e3, 285e3, 9e3, 3.6e3, 0, 24),
    Workload("pattern", "find", 2.4, 381e3, 1.8e6, 359e3, 352e3, 0, 11),
    Workload("pattern", "line", 1.7, 262e3, 1.7e6, 476e3, 235e3, 0, 11),
    Workload("pattern", "word", 2.1, 340e3, 2.2e6, 618e3, 307e3, 0, 10),
    Workload("nginx", "web0", 7.5, 126e3, 665e3, 126e3, 4.4e3, 543e3, 9),
    Workload("nginx", "web1", 0.9, 50e3, 344e3, 109e3, 2e3, 154e3, 3),
    Workload("nginx", "filedown", 13.5, 109e3, 30e3, 1e3, 40, 155e3, 6),
    Workload("vsftpd", "fileup", 12.1, 93e3, 5.4e6, 127e3, 115e3, 1.2e6, 2),
]

MODELS = ["Host", "P.ISP-R", "P.ISP-V", "D-Naive", "D-FullOS", "D-VirtFW"]
COMPONENTS = ["Network", "Kernel-ctx", "LBA-set", "Storage", "System",
              "Compute"]


@dataclasses.dataclass(frozen=True)
class IspCosts:
    """Calibrated per-op latency constants (us unless noted).

    Random-search fit against the paper's aggregate claims (see
    benchmarks/calibrate.py).  Achieved vs paper:
      D-VirtFW vs P.ISP 1.56x (1.6x) | vs D-Naive 1.76x (1.8x)
      vs D-FullOS 1.56x (1.6x) | vs Host 1.23x (1.3x)
      P.ISP-V 13.7% under P.ISP-R (13.7%) | D-FullOS +7.7% (9.3%)
      D-Naive +12.9% (12.8%) | Host storage share 40% (38%)
      P.ISP communicate share 42% (43%) | storage reduction 50% (50%).
    Deviation noted in EXPERIMENTS.md: our P.ISP beats Host on
    {nginx-filedown, vsftpd-fileup}; the paper lists
    {rocksdb-read, nginx-filedown}."""
    # storage paths
    host_io_us: float = 6.668        # host NVMe stack + PCIe per IO
    flash_io_us: float = 5.044       # internal flash access per IO
    host_bw_gbs: float = 2.866       # host-visible transfer bandwidth
    flash_bw_gbs: float = 12.143     # internal multi-channel bandwidth
    # compute
    ssd_slowdown: float = 1.5        # 2.2 GHz frontend vs 3.8 GHz host
    # system path
    host_syscall_us: float = HOST_SYSCALL_US
    embedded_syscall_us: float = EMBEDDED_SYSCALL_US
    virtfw_call_us: float = FUNC_CALL_US
    path_walk_us: float = 8.235      # host VFS path resolution
    virtfw_walk_us: float = 0.016    # λFS walk w/ I/O-node cache
    # network path
    host_net_pkt_us: float = 0.0745
    etheron_pkt_us: float = 6.448    # Ether-oN tunneled packet
    # ISP communicate path
    rpc_us: float = 15.191           # P.ISP-R per-offload RPC (Kernel-ctx)
    vendor_cmd_us: float = 3.099     # P.ISP-V vendor-specific command
    lba_set_us: float = 12.637       # per-IO LBA handshake batch share
    ctx_switch_us: float = CONTEXT_SWITCH_US
    intercomplex_us: float = 3.308   # D-Naive per-IO complex-to-complex hop
    offload_per_ios: float = 1663.0  # IOs batched per offload invocation


def host_components(w: Workload, c: IspCosts) -> Dict[str, float]:
    """Decompose the measured host runtime into components (seconds)."""
    storage = (w.io_count * c.host_io_us * 1e-6 +
               w.io_size_gb / c.host_bw_gbs)
    system = (w.syscalls * c.host_syscall_us +
              w.path_walks * c.path_walk_us) * 1e-6
    network = w.tcp_packets * c.host_net_pkt_us * 1e-6
    compute = max(w.exec_time_s - storage - system - network,
                  0.05 * w.exec_time_s)
    return {"Network": network, "Kernel-ctx": 0.0, "LBA-set": 0.0,
            "Storage": storage, "System": system, "Compute": compute}


def components(w: Workload, model: str,
               c: IspCosts = IspCosts()) -> Dict[str, float]:
    h = host_components(w, c)
    if model == "Host":
        return h
    compute_ssd = h["Compute"] * c.ssd_slowdown
    storage_int = (w.io_count * c.flash_io_us * 1e-6 +
                   w.io_size_gb / c.flash_bw_gbs)
    offloads = max(1.0, w.io_count / c.offload_per_ios)

    if model in ("P.ISP-R", "P.ISP-V"):
        per = c.rpc_us if model == "P.ISP-R" else c.vendor_cmd_us
        kernel_ctx = offloads * (per + 2 * c.ctx_switch_us) * 1e-6 * 1e3
        lba_set = w.io_count * c.lba_set_us * 1e-6
        # bare-metal kernels: no OS/syscall machinery on-device
        return {"Network": h["Network"], "Kernel-ctx": kernel_ctx,
                "LBA-set": lba_set, "Storage": storage_int,
                "System": 0.0, "Compute": compute_ssd}

    if model == "D-Naive":
        system = (w.syscalls * c.embedded_syscall_us +
                  w.path_walks * c.path_walk_us) * 1e-6
        inter = w.io_count * c.intercomplex_us * 1e-6 + \
            w.io_size_gb / c.flash_bw_gbs          # extra complex hop copy
        return {"Network": w.tcp_packets * c.etheron_pkt_us * 1e-6,
                "Kernel-ctx": 0.0, "LBA-set": 0.0,
                "Storage": storage_int + inter, "System": system,
                "Compute": compute_ssd}

    if model == "D-FullOS":
        system = (w.syscalls * c.embedded_syscall_us +
                  w.path_walks * c.path_walk_us) * 1e-6
        return {"Network": w.tcp_packets * c.etheron_pkt_us * 1e-6,
                "Kernel-ctx": 0.0, "LBA-set": 0.0, "Storage": storage_int,
                "System": system, "Compute": compute_ssd}

    if model == "D-VirtFW":
        system = (w.syscalls * c.virtfw_call_us +
                  w.path_walks * c.virtfw_walk_us) * 1e-6
        return {"Network": w.tcp_packets * c.etheron_pkt_us * 1e-6,
                "Kernel-ctx": 0.0, "LBA-set": 0.0, "Storage": storage_int,
                "System": system, "Compute": compute_ssd}
    raise ValueError(model)


def total(w: Workload, model: str, c: IspCosts = IspCosts()) -> float:
    return sum(components(w, model, c).values())


def workload_scan_gbs(program: str, name: str, c: IspCosts = IspCosts(),
                      *, scale: float = 8.0) -> float:
    """Per-byte compute intensity of a Table-2 workload, expressed as
    the effective host scan rate (GB/s) of its operator.

    Table 2 fixes the *relative* intensity: bytes touched divided by
    the Compute component of the decomposed host runtime
    (:func:`host_components`) — pattern matching burns more cycles per
    byte than TPC-H's semi-join counting.  The absolute scale belongs
    to the operator implementation, not the platform (the planner's
    ``scan_gbs`` default), so the relative intensity is normalized by
    the geometric mean across all Table-2 workloads and multiplied by
    ``scale``.  This is what threads into ``AnalyticsJob.scan_gbs`` so
    the :class:`~repro.runtime.offload.OffloadPlanner`'s modeled
    ``host_s``/``dvirtfw_s`` differentiate workloads instead of pricing
    every scan identically."""
    import numpy as np
    w = next((w for w in WORKLOADS
              if w.program == program and w.name == name), None)
    if w is None:
        raise KeyError(f"no Table-2 workload {program}-{name}")
    intensity = lambda w: w.io_size_gb / host_components(w, c)["Compute"]
    ref = float(np.exp(np.mean([np.log(intensity(x)) for x in WORKLOADS])))
    return scale * intensity(w) / ref


def evaluate_all(c: IspCosts = IspCosts()):
    """Fig 11 data: components for every model x workload."""
    return {f"{w.program}-{w.name}": {m: components(w, m, c) for m in MODELS}
            for w in WORKLOADS}


def fig3_breakdown(c: IspCosts = IspCosts()):
    """Fig 3: Host vs P.ISP (avg across workloads), 3-component view."""
    import numpy as np
    rows = {}
    for model in ("Host", "P.ISP-V"):
        comp = store = comm = tot = 0.0
        for w in WORKLOADS:
            d = components(w, model, c)
            comp += d["Compute"] + d["System"]
            store += d["Storage"]
            comm += d["Network"] + d["Kernel-ctx"] + d["LBA-set"]
            tot += sum(d.values())
        rows[model] = {"Compute": comp, "Storage": store,
                       "Communicate": comm, "total": tot}
    return rows


def headline_ratios(c: IspCosts = IspCosts()) -> Dict[str, float]:
    import numpy as np
    g = lambda xs: float(np.exp(np.mean(np.log(xs))))
    t = {m: [total(w, m, c) for w in WORKLOADS] for m in MODELS}
    pisp = [(a + b) / 2 for a, b in zip(t["P.ISP-R"], t["P.ISP-V"])]
    r = {
        "dvirtfw_vs_pisp": g([a / b for a, b in zip(pisp, t["D-VirtFW"])]),
        "dvirtfw_vs_dnaive": g([a / b for a, b in
                                zip(t["D-Naive"], t["D-VirtFW"])]),
        "dvirtfw_vs_dfullos": g([a / b for a, b in
                                 zip(t["D-FullOS"], t["D-VirtFW"])]),
        "dvirtfw_vs_host": g([a / b for a, b in
                              zip(t["Host"], t["D-VirtFW"])]),
        "pispv_vs_pispr": 1.0 - g([a / b for a, b in
                                   zip(t["P.ISP-V"], t["P.ISP-R"])]),
        "dfullos_over_pispv": g([a / b for a, b in
                                 zip(t["D-FullOS"], t["P.ISP-V"])]) - 1.0,
        "dnaive_over_dfullos": g([a / b for a, b in
                                  zip(t["D-Naive"], t["D-FullOS"])]) - 1.0,
        "pisp_vs_host": g([a / b for a, b in zip(pisp, t["Host"])]),
    }
    # Fig 3 shares
    f3 = fig3_breakdown(c)
    r["host_storage_share"] = f3["Host"]["Storage"] / f3["Host"]["total"]
    r["pisp_comm_share"] = (f3["P.ISP-V"]["Communicate"] /
                            f3["P.ISP-V"]["total"])
    r["pisp_storage_reduction"] = 1.0 - (f3["P.ISP-V"]["Storage"] /
                                         f3["Host"]["Storage"])
    return r
