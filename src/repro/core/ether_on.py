"""Ether-oN — Ethernet over NVMe.

Faithful control-plane model of the paper's transport: Ethernet frames
are tunneled through two vendor-specific NVMe commands

  * ``0xE0`` **transmit frame** — host -> SSD.  The driver copies the
    sk_buff (headers+payload+checksum) into 4 KiB-aligned kernel pages
    and points the command's PRP list at them.
  * ``0xE1`` **receive frame** — the *asynchronous upcall*: the driver
    pre-posts ``UPCALL_SLOTS`` (=4, the paper's tuned value) receive
    commands per SQ; the SSD completes one whenever an ISP-container
    sends a frame to the host, and the driver immediately re-posts a
    fresh one.  This is how a PCIe device that cannot issue NVMe
    commands nonetheless *initiates* communication.

The event loop is deterministic; per-operation cost accounting feeds
the Fig-3/Fig-11 models.  On the TPU mapping (DESIGN.md) this layer is
the pool's control plane; bulk tensor traffic rides jax collectives.

Delivery is **reliable** (DESIGN.md §Fault model): every frame carries
a per-flow sequence number; receivers ACK/NACK synchronously (the NVMe
completion status — a reliable side channel, never a frame of its
own), dedup by seq, and stash out-of-order arrivals until the gap
fills; senders retransmit on timeout with exponential backoff, bounded
by ``max_retries``.  A checksum mismatch is a NACK -> retransmit, not
an exception.  On a fault-free fabric the reliable path is
byte-identical in cost accounting to the historical direct delivery —
retransmit/NACK/dedup counters stay exactly zero.  Faults come only
from an attached :class:`~repro.core.faults.FaultInjector`.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

PAGE = 4096
OPC_TRANSMIT = 0xE0
OPC_RECEIVE = 0xE1
UPCALL_SLOTS = 4      # pre-allocated receive commands per SQ (paper-tuned)
ETH_HEADER = 14
MTU = 1500


class EtherONError(Exception):
    pass


@dataclasses.dataclass
class EthernetFrame:
    src_ip: str
    dst_ip: str
    payload: bytes
    ethertype: int = 0x0800
    checksum: int = 0
    # per-flow delivery sequence number (-1 = unsequenced legacy frame);
    # a header field, so payload corruption never damages it
    seq: int = -1

    def seal(self) -> "EthernetFrame":
        self.checksum = zlib.crc32(self.payload)
        return self

    def verify(self) -> bool:
        return self.checksum == zlib.crc32(self.payload)

    @property
    def wire_bytes(self) -> int:
        return ETH_HEADER + len(self.payload) + 4


@dataclasses.dataclass
class NVMeCommand:
    opcode: int
    cid: int
    sq_id: int
    prp: List[int]                   # page ids of the kernel pages
    n_pages: int
    frame: Optional[EthernetFrame] = None   # contents of those pages
    reception_code: int = 0


@dataclasses.dataclass
class Costs:
    """Per-op latencies (us) — cost accounting for the perf models."""
    doorbell: float = 0.3
    dma_per_page: float = 0.9
    completion_msi: float = 1.2
    page_copy_per_kb: float = 0.08
    # base retransmit timeout; attempt k waits 2^k of these
    retransmit_timeout_us: float = 25.0


#: bounded retries per frame (attempts = max_retries + 1)
MAX_RETRIES = 8


class EtherONStats:
    def __init__(self):
        self.tx_commands = 0
        self.rx_completions = 0
        self.pages_allocated = 0
        self.bytes_tx = 0
        self.bytes_rx = 0
        self.reposts = 0
        self.lock_syncs = 0
        self.control_frames = 0
        self.job_frames = 0          # analytics JOB submissions
        self.result_bytes = 0        # reduced aggregates shipped back
        self.extent_reads = 0        # host-reads-everything fetches
        # reliable-delivery counters — exactly zero on a fault-free
        # fabric (the chaos suite pins both directions of that claim)
        self.retransmits = 0         # timed-out frames resent
        self.nacks = 0               # checksum-mismatch rejections
        self.dup_frames = 0          # receive-side dedup hits
        self.backoff_us = 0.0        # virtual time spent in backoff
        # elastic drain (warm path) — exactly zero on a static pool
        # (the elastic suite pins that): one MIGRATE announcement per
        # page moved device-to-device, plus the moved page bytes
        self.migrate_frames = 0
        self.migrate_bytes = 0
        self.time_us = 0.0


class EtherONDriver:
    """Host-side kernel driver + virtual network adapter."""

    def __init__(self, host_ip: str, costs: Costs = Costs(),
                 max_retries: int = MAX_RETRIES):
        self.host_ip = host_ip
        self.costs = costs
        self.max_retries = max_retries
        self.stats = EtherONStats()
        self._cid = 0
        self._devices: Dict[str, "DockerSSDEndpoint"] = {}
        self._outstanding_rx: Dict[str, Deque[NVMeCommand]] = {}
        self._rx_backlog: Dict[str, Deque[EthernetFrame]] = {}
        self._inbox: Deque[EthernetFrame] = deque()
        self._next_page = 0
        # reliable delivery state: per-destination tx seq, per-source
        # expected upcall seq + reorder stash
        self._tx_seq: Dict[str, int] = {}
        self._up_expected: Dict[str, int] = {}
        self._up_stash: Dict[str, Dict[int, EthernetFrame]] = {}
        #: attached chaos source (core.faults.FaultInjector) or None
        self.faults = None

    # -- device attach / init ------------------------------------------------

    def attach(self, dev: "DockerSSDEndpoint"):
        self._devices[dev.ip] = dev
        dev._driver = self
        self._outstanding_rx[dev.ip] = deque()
        self._rx_backlog[dev.ip] = deque()
        self._tx_seq[dev.ip] = 0
        self._up_expected[dev.ip] = 0
        self._up_stash[dev.ip] = {}
        # kernel init: pre-submit the upcall commands
        for _ in range(UPCALL_SLOTS):
            self._post_receive(dev.ip)

    def attach_faults(self, injector):
        """Wire a :class:`~repro.core.faults.FaultInjector` onto the
        fabric boundary (None detaches)."""
        self.faults = injector

    def _lat_mult(self, ip: str) -> float:
        """Straggler latency multiplier for fabric ops touching ``ip``."""
        return self.faults.latency_mult(ip) if self.faults is not None \
            else 1.0

    def _alloc_pages(self, nbytes: int) -> List[int]:
        n = max(1, -(-nbytes // PAGE))
        pages = list(range(self._next_page, self._next_page + n))
        self._next_page += n
        self.stats.pages_allocated += n
        return pages

    def _post_receive(self, ip: str):
        self._cid += 1
        cmd = NVMeCommand(OPC_RECEIVE, self._cid, sq_id=0,
                          prp=self._alloc_pages(PAGE), n_pages=1,
                          reception_code=self._cid)
        self._outstanding_rx[ip].append(cmd)
        self.stats.reposts += 1
        self.stats.time_us += self.costs.doorbell

    # -- host -> SSD ----------------------------------------------------------

    def transmit(self, frame: EthernetFrame):
        """Translate an Ethernet frame into a 0xE0 NVMe command and
        deliver it reliably: stop-and-wait per destination — each
        attempt pays the full command cost; an unacked attempt pays an
        exponentially-backed-off timeout and retransmits, bounded by
        ``max_retries``.  On a fault-free fabric attempt 0 acks and the
        accounting is byte-identical to unconditional delivery."""
        if frame.dst_ip not in self._devices:
            raise EtherONError(f"no route to {frame.dst_ip}")
        frame.seal()
        seq = self._tx_seq[frame.dst_ip]
        self._tx_seq[frame.dst_ip] = seq + 1
        frame.seq = seq
        dev = self._devices[frame.dst_ip]
        c = self.costs
        mult = self._lat_mult(frame.dst_ip)
        for attempt in range(self.max_retries + 1):
            pages = self._alloc_pages(frame.wire_bytes)
            self._cid += 1
            cmd = NVMeCommand(OPC_TRANSMIT, self._cid, sq_id=0, prp=pages,
                              n_pages=len(pages), frame=frame)
            self.stats.tx_commands += 1
            self.stats.bytes_tx += frame.wire_bytes
            self.stats.time_us += mult * (
                c.page_copy_per_kb * frame.wire_bytes / 1024 +
                c.doorbell + c.dma_per_page * len(pages) +
                c.completion_msi)
            if self._deliver_transmit(dev, cmd):
                return
            # timeout: exponential backoff before the retransmit
            self.stats.retransmits += 1
            wait = c.retransmit_timeout_us * (1 << attempt)
            self.stats.backoff_us += wait
            self.stats.time_us += wait
        raise EtherONError(
            f"delivery to {frame.dst_ip} failed after "
            f"{self.max_retries + 1} attempts (seq {seq}): node down "
            f"or fabric dropping every copy")

    def _deliver_transmit(self, dev: "DockerSSDEndpoint",
                          cmd: NVMeCommand) -> bool:
        """One delivery attempt through the (possibly faulty) fabric.
        Returns True when the destination acked OUR sequence number —
        released held frames and stale duplicates resolve to dup-acks
        that never complete the current command."""
        frame = cmd.frame
        if self.faults is not None:
            delivery = self.faults.transit(frame, "down", dev.ip)
        else:
            delivery = [frame]
        if not dev.alive:
            # a dead node consumes nothing and acks nothing; released
            # held frames die with it
            return False
        acked = False
        for f in delivery:
            fc = cmd if f is frame else NVMeCommand(
                OPC_TRANSMIT, cmd.cid, sq_id=0, prp=cmd.prp,
                n_pages=cmd.n_pages, frame=f)
            status = dev._receive_from_host(fc)
            if status == "nack":
                self.stats.nacks += 1
                continue
            if status == "dup":
                self.stats.dup_frames += 1
            if f.seq == frame.seq and status in ("ack", "dup"):
                acked = True
        return acked

    # -- serving control plane -------------------------------------------------

    def send_control(self, dst_ip: str, verb: str, seq_id: int,
                     extra: str = ""):
        """Pool-serving control message (``SERVE place|free|... <seq>``).

        Admission, placement and free notifications ride the same
        0xE0/0xE1 tunnel as every other frame — and pay the same
        per-operation costs — so the analytical model's traffic terms
        (``core.analytical.control_plane_terms``) see the serving
        control plane exactly as Fig 3 sees the docker-cli one.  Bulk
        tensor traffic never comes through here; it rides the jax mesh
        collectives (DESIGN.md §Pool serving)."""
        payload = f"SERVE {verb} {seq_id} {extra}".rstrip().encode()
        self.stats.control_frames += 1
        self.transmit(EthernetFrame(self.host_ip, dst_ip, payload))

    def send_migrate(self, dst_ip: str, seq_id: int, page_idx: int,
                     nbytes: int, src_node: int, dst_node: int):
        """Warm-path page-migration announcement (elastic drain).

        One ``SERVE migrate`` frame per moved page tells the receiving
        node a page of ``seq_id`` now lives in its window.  The frame
        rides the reliable tunnel (ack'd, CRC-checked, retried with
        backoff), so under chaos its retransmits land in the same
        delivery counters as every other frame.  The page payload
        itself never crosses the host fabric — it moves
        device-to-device (``PageStore.copy_page``) — but the moved
        bytes are accounted here (``migrate_bytes`` + the per-kb copy
        cost) so ``analytical.migration_terms`` can price a drain."""
        self.stats.migrate_frames += 1
        self.stats.migrate_bytes += int(nbytes)
        self.stats.time_us += self.costs.page_copy_per_kb * (nbytes / 1024.0)
        payload = (f"SERVE migrate {seq_id} "
                   f"{page_idx}:{src_node}>{dst_node}:{nbytes}").encode()
        self.transmit(EthernetFrame(self.host_ip, dst_ip, payload))

    # -- analytics data plane ---------------------------------------------------
    #
    # Job and result frames ride the same 0xE0/0xE1 tunnel as docker-cli
    # traffic and pay the same per-operation costs.  Responses larger
    # than one MTU are length-framed (``<TAG> <nbytes>\n<body>``) and
    # reassembled from consecutive upcall frames — the event loop is
    # synchronous, so a response's chunks arrive back to back.

    def submit_jobs(self, dst_ip: str, jobs: List[dict]) -> List[dict]:
        """Ship a batch of analytics programs to one node; return the
        decoded per-job results (tagged-hex ndarrays stay encoded — the
        caller decodes with ``container.from_jsonable``)."""
        payload = b"JOB " + json.dumps(jobs).encode()
        self.stats.job_frames += 1
        self.transmit(EthernetFrame(self.host_ip, dst_ip, payload))
        body = self._collect_response(b"RESULTS ")
        self.stats.result_bytes += len(body)
        out = json.loads(body)
        if isinstance(out, dict) and "error" in out:
            raise EtherONError(f"node {dst_ip} rejected jobs: "
                               f"{out['error']}")
        return out

    def fetch_extent(self, dst_ip: str, name: str):
        """The host baseline: read a whole extent back over the tunnel
        (every byte pays frame costs — the traffic ISP offload avoids).
        A quantized extent (``qscale`` in the header) arrives as codes
        followed by per-row f32 scales; the host dequantizes here, so
        the wire carried only the quantized bytes."""
        import numpy as np
        self.stats.extent_reads += 1
        self.transmit(EthernetFrame(self.host_ip, dst_ip,
                                    b"READ " + name.encode()))
        body = self._collect_response(b"EXTENT ")
        header, _, raw = body.partition(b"\n")
        meta = json.loads(header)
        if "error" in meta:
            raise EtherONError(f"node {dst_ip}: {meta['error']}")
        rows, cols = meta["rows"], meta["cols"]
        try:
            dt = np.dtype(meta["dtype"])
        except TypeError:
            import ml_dtypes                       # fp8 codes (jax dep)
            dt = np.dtype(getattr(ml_dtypes, meta["dtype"]))
        if meta.get("qscale"):
            nb = rows * cols * dt.itemsize
            codes = np.frombuffer(raw[:nb], dt).reshape(rows, cols)
            scales = np.frombuffer(raw[nb:nb + rows * 4], np.float32)
            return codes.astype(np.float32) * scales[:, None]
        return np.frombuffer(raw, dt).reshape(rows, cols).copy()

    def _collect_response(self, tag: bytes) -> bytes:
        frame = self.poll()
        skipped = 0
        # stale chunks from abandoned responses (e.g. a logs read the
        # client polled only once) must not poison the next request
        while frame is not None and not frame.payload.startswith(tag):
            skipped += 1
            frame = self.poll()
        if frame is None:
            raise EtherONError(
                f"no {tag!r} response on the upcall inbox "
                f"(skipped {skipped} stale frames)")
        header, _, rest = frame.payload.partition(b"\n")
        n = int(header[len(tag):])
        buf = bytearray(rest)
        while len(buf) < n:
            frame = self.poll()
            if frame is None:
                raise EtherONError(f"truncated {tag!r} response: "
                                   f"{len(buf)}/{n} bytes")
            buf += frame.payload
        return bytes(buf[:n])

    # -- SSD -> host (upcall path) ---------------------------------------------

    def _deliver_upcall(self, ip: str, frame: EthernetFrame) -> str:
        """One SSD->host delivery attempt through the (possibly faulty)
        fabric.  Returns the receive status for ``frame``'s own seq —
        "ack" (consumed or stashed), "nack" (checksum mismatch), "dup"
        (already have it), or "lost" (dropped/held in flight) — the
        reliable completion-status side channel the device's retransmit
        loop keys on."""
        if self.faults is not None:
            delivery = self.faults.transit(frame, "up", ip)
        else:
            delivery = [frame]
        status = "lost"
        for f in delivery:
            st = self._upcall_rx(ip, f)
            if f.seq == frame.seq and status != "ack":
                status = st
        return status

    def _upcall_rx(self, ip: str, frame: EthernetFrame) -> str:
        """Receive-side delivery state machine: CRC check -> NACK,
        seq dedup, reorder stash, in-order release into the upcall
        consume path."""
        if not frame.verify():
            self.stats.nacks += 1
            return "nack"
        if frame.seq < 0:               # unsequenced legacy frame
            self._upcall(ip, frame)
            return "ack"
        exp = self._up_expected[ip]
        if frame.seq < exp:
            self.stats.dup_frames += 1
            return "dup"
        stash = self._up_stash[ip]
        if frame.seq > exp:
            if frame.seq in stash:
                self.stats.dup_frames += 1
                return "dup"
            # out of order: hold (acked — received, just early) until
            # the gap fills, so reassembly never sees a reordering
            stash[frame.seq] = frame
            return "ack"
        self._up_expected[ip] = exp + 1
        self._upcall(ip, frame)
        while self._up_expected[ip] in stash:
            nxt = stash.pop(self._up_expected[ip])
            self._up_expected[ip] += 1
            self._upcall(ip, nxt)
        return "ack"

    def _upcall(self, ip: str, frame: EthernetFrame):
        """Device completes an outstanding 0xE1 command."""
        q = self._outstanding_rx[ip]
        if not q:
            # all slots in flight: device-side backpressure queue
            self._rx_backlog[ip].append(frame)
            return
        cmd = q.popleft()
        assert cmd.opcode == OPC_RECEIVE
        if not frame.verify():
            raise EtherONError("checksum mismatch on upcall frame")
        c = self.costs
        self.stats.rx_completions += 1
        self.stats.bytes_rx += frame.wire_bytes
        self.stats.time_us += self._lat_mult(ip) * (
            c.dma_per_page * cmd.n_pages + c.completion_msi +
            c.page_copy_per_kb * frame.wire_bytes / 1024)
        self._inbox.append(frame)
        # immediately re-post to keep communication alive
        self._post_receive(ip)
        if self._rx_backlog[ip]:
            self._upcall(ip, self._rx_backlog[ip].popleft())

    def poll(self) -> Optional[EthernetFrame]:
        return self._inbox.popleft() if self._inbox else None

    def outstanding_slots(self, ip: str) -> int:
        return len(self._outstanding_rx[ip])

    # λFS inode-lock synchronization rides Ether-oN as a special packet
    def send_lock_sync(self, path: str, refcount: int, holder):
        self.stats.lock_syncs += 1
        self.stats.time_us += self.costs.doorbell + self.costs.completion_msi


class DockerSSDEndpoint:
    """Device-side Ether-oN terminus: owns an IP, hands frames to the
    Virtual-FW network handler, sends responses via the upcall path."""

    def __init__(self, ip: str):
        self.ip = ip
        self._driver: Optional[EtherONDriver] = None
        self._handler: Optional[Callable[[EthernetFrame], Optional[bytes]]] = None
        self.rx_frames = 0
        #: fabric-level liveness: a dead endpoint consumes nothing and
        #: acks nothing (DockerSSDNode.fail/recover toggles this)
        self.alive = True
        # reliable delivery state
        self._rx_expected = 0           # next host->SSD seq to process
        self._up_seq = 0                # next SSD->host seq to assign

    def set_handler(self, fn: Callable[[EthernetFrame], Optional[bytes]]):
        self._handler = fn

    def _receive_from_host(self, cmd: NVMeCommand) -> str:
        """Process one 0xE0 command; the return value is the NVMe
        completion status the driver's retransmit loop keys on: "ack"
        (processed), "nack" (checksum mismatch — retransmit), "dup"
        (already processed — acked without re-running side effects)."""
        assert cmd.opcode == OPC_TRANSMIT
        frame = cmd.frame
        if not frame.verify():
            return "nack"               # NACK -> driver retransmits
        if frame.seq >= 0:
            if frame.seq < self._rx_expected:
                return "dup"
            # stop-and-wait sender: a gap means the sender gave up on
            # that seq (and told its caller) — accept and advance
            self._rx_expected = frame.seq + 1
        self.rx_frames += 1
        if self._handler is not None:
            resp = self._handler(frame)
            if resp is not None:
                self.send_to_host(resp, dst_ip=frame.src_ip)
        return "ack"

    def send_to_host(self, payload: bytes, dst_ip: str):
        """ISP-container initiated traffic — possibly multiple MTU
        frames, delivered reliably: the whole burst goes out pipelined,
        then unacked frames retransmit in bounded exponential-backoff
        rounds (the receive side dedups and reorders by seq, so
        reassembly survives any loss/duplication/reordering mix)."""
        frames = []
        for off in range(0, max(len(payload), 1), MTU):
            chunk = payload[off:off + MTU]
            frame = EthernetFrame(self.ip, dst_ip, chunk).seal()
            frame.seq = self._up_seq
            self._up_seq += 1
            frames.append(frame)
        drv = self._driver
        pending = frames
        for round_no in range(drv.max_retries + 1):
            # "ack" covers consumed AND stashed-out-of-order frames;
            # "dup" means the receiver already holds it — both settle
            # the frame.  "nack"/"lost" leave it for the next round.
            pending = [f for f in pending
                       if drv._deliver_upcall(self.ip, f)
                       not in ("ack", "dup")]
            if not pending:
                return
            drv.stats.retransmits += len(pending)
            wait = drv.costs.retransmit_timeout_us * (1 << round_no)
            drv.stats.backoff_us += wait
            drv.stats.time_us += wait
        raise EtherONError(
            f"upcall delivery from {self.ip} lost {len(pending)} "
            f"frame(s) after {drv.max_retries + 1} rounds")
