"""Computing-enabled storage pool — DockerSSD disaggregation.

Each DockerSSD (Ether-oN IP + Virtual-FW + mini-docker + λFS) is an
independent node; nodes form an *array* behind a PCIe switch, arrays
form a *cluster* behind a switch tray (Fig 8a).  The pool orchestrates
containers across nodes (docker-compose/Kubernetes-style), supports
the two offloading modes from the paper (independent apps per node vs
one distributed job spanning nodes), and provides the fleet features a
1000+-node deployment needs: heartbeats, failure detection and
container rescheduling, straggler re-replication, elastic membership.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.container import MiniDocker, to_jsonable
from repro.core.ether_on import (DockerSSDEndpoint, EtherONDriver,
                                 EtherONError)
from repro.core.extent_store import ANALYTICS_IMAGE, ExtentStore
from repro.core.lambda_fs import SHARABLE_NS, LambdaFS
from repro.core.virtual_fw import VirtualFW


@dataclasses.dataclass
class NodeSpec:
    ghz: float = 2.2
    cores: int = 6
    dram_gb: float = 2.0
    flash_gb: float = 400.0
    channels: int = 12


class DockerSSDNode:
    """One disaggregated computational SSD."""

    def __init__(self, ip: str, spec: Optional[NodeSpec] = None,
                 extent_cfg: Optional[Dict[str, int]] = None):
        self.ip = ip
        # default must be constructed per node: a shared NodeSpec instance
        # would alias every node's spec, so mutating one (e.g. a degraded
        # channel count) would silently change the whole pool
        spec = spec if spec is not None else NodeSpec()
        self.spec = spec
        self.fs = LambdaFS(capacity_bytes=int(spec.flash_gb * 1e9))
        self.endpoint = DockerSSDEndpoint(ip)
        self.fw = VirtualFW(self.fs, self.endpoint)
        # flash-resident analytics pages, addressed by the scan kernel
        self.extents = ExtentStore(**(extent_cfg or {}))
        self.docker = MiniDocker(self.fw, self.fs, extents=self.extents)
        # λFS lock syncs ride the pool's Ether-oN driver
        self.alive = True
        # straggler != dead: a suspect node keeps its sequences and
        # extents but receives no NEW placements until it clears
        self.suspect = False
        self.last_heartbeat = 0.0
        self.latency_ema_ms = 1.0
        self.serving_log: List[Tuple[str, int]] = []
        self.endpoint.set_handler(self._on_frame)

    def _on_frame(self, frame):
        """HTTP-over-Ether-oN: docker-cli requests land here; serving
        control messages (``SERVE <verb> <seq>``) are logged by the
        node's serving agent and acknowledged over the upcall path;
        ``JOB``/``READ`` frames are the analytics data plane."""
        # requests with a body (e.g. an image blob for pull) carry it
        # after a blank line, HTTP-style
        head, _, body = frame.payload.partition(b"\n\n")
        req = head.decode(errors="replace")
        if req.startswith("SERVE "):
            parts = req.split()
            verb, seq_id = parts[1], int(parts[2])
            self.serving_log.append((verb, seq_id))
            return f"ACK {verb} {seq_id}".encode()
        if req.startswith("JOB "):
            return self._run_jobs(frame.payload[4:])
        if req.startswith("READ "):
            return self._read_extent(req[5:].strip())
        if req.startswith(("GET ", "POST ", "DELETE ")):
            return self.docker.handle_http(req, body)
        return None

    # -- analytics data plane (device side) -------------------------------------

    def _run_jobs(self, raw: bytes) -> bytes:
        """One batched JOB frame -> one container run -> one RESULTS
        response carrying only the reduced aggregates.

        The D-VirtFW execution path end to end: call args staged in the
        MPU-checked ISP memory pool, job params packaged into the
        container's λFS rootfs via function-call syscalls (no
        Kernel-ctx), then the jitted Pallas reduce over the node's
        extent pages."""
        job_pages = None
        try:
            # args into the ISP pool (page-granular, user-mode — Fig 6)
            job_pages = self.fw.stage_job(raw)
            cid = self.docker.cmd_create(ANALYTICS_IMAGE)
            # rootfs-packaged params through the I/O handler's syscalls
            fd = self.fw.syscall("openat",
                                 f"/containers/{cid}/rootfs/job.json")
            self.fw.syscall("write", fd, raw)
            self.fw.syscall("close", fd)
            results = self.docker.cmd_start(cid, job_pages=job_pages)
            body = json.dumps(to_jsonable(results)).encode()
            # batch retired: reclaim the container (a failed one stays
            # around dead/exited for `docker logs` debugging)
            self.docker.cmd_rm(cid)
        except Exception as e:
            body = json.dumps({"error": f"{type(e).__name__}: {e}"}).encode()
        finally:
            if job_pages is not None:
                self.fw.free_job(job_pages)     # ISP pool is finite
        return b"RESULTS %d\n" % len(body) + body

    def _read_extent(self, name: str) -> bytes:
        """Host-reads-everything: ship the whole extent back (the
        baseline traffic the in-storage reduce eliminates).  A
        quantized pool ships its stored codes plus the per-row f32
        scales — never an inflated f32 copy — so the wire pays the
        quantized byte count and the host dequantizes at the far end."""
        if name not in self.extents.extents:
            hdr = json.dumps({"error": f"no extent {name!r}"}).encode()
            body = hdr + b"\n"
        elif self.extents.quantized:
            codes, scales = self.extents.raw_extent(name)
            hdr = json.dumps({"rows": codes.shape[0],
                              "cols": codes.shape[1],
                              "dtype": str(codes.dtype),
                              "qscale": True}).encode()
            body = (hdr + b"\n" + np.ascontiguousarray(codes).tobytes() +
                    np.ascontiguousarray(scales).tobytes())
        else:
            arr = self.extents.get(name)
            hdr = json.dumps({"rows": arr.shape[0], "cols": arr.shape[1],
                              "dtype": str(arr.dtype)}).encode()
            body = hdr + b"\n" + np.ascontiguousarray(arr).tobytes()
        return b"EXTENT %d\n" % len(body) + body

    def ingest_extent(self, name: str, path: str, n_cols: int,
                      dtype=np.float32) -> Tuple[int, int]:
        """Move a sharable-NS file the host placed into flash extent
        pages, through the I/O handler (counted, costed syscalls)."""
        fd = self.fw.syscall("openat", path, SHARABLE_NS)
        raw = self.fw.syscall("read", fd)
        self.fw.syscall("close", fd)
        arr = np.frombuffer(raw, dtype).reshape(-1, n_cols)
        self.extents.put(name, arr)
        return arr.shape

    def heartbeat(self, now: float) -> bool:
        if self.alive:
            self.last_heartbeat = now
        return self.alive

    def fail(self):
        self.alive = False
        # the fabric endpoint dies with the node: in-flight deliveries
        # time out and the driver's bounded retransmit gives up
        self.endpoint.alive = False

    def recover(self):
        self.alive = True
        self.endpoint.alive = True
        self.suspect = False


@dataclasses.dataclass
class Placement:
    """A distributed job's shard assignment (the pool-level DP/TP/PP of
    the paper's Fig 8b)."""
    job: str
    node_ips: List[str]
    dp: int = 1
    tp: int = 1
    pp: int = 1
    stage_of: Dict[str, int] = dataclasses.field(default_factory=dict)


class StoragePool:
    """Array/cluster of DockerSSDs with a docker-compose-like scheduler."""

    def __init__(self, n_nodes: int, host_ip: str = "10.0.0.1",
                 spec: Optional[NodeSpec] = None, array_size: int = 16,
                 heartbeat_timeout: float = 3.0,
                 straggler_factor: float = 3.0,
                 extent_cfg: Optional[Dict[str, int]] = None):
        self.driver = EtherONDriver(host_ip)
        self.nodes: Dict[str, DockerSSDNode] = {}
        self.arrays: List[List[str]] = []
        self.array_size = array_size
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.extent_cfg = extent_cfg
        self.placements: Dict[str, Placement] = {}
        self.events: List[Tuple[str, str]] = []
        self.fault_injector = None
        # pool-serving frontend state (attach_server)
        self._server = None
        self._serve_job: Optional[str] = None
        self._requeue: List[int] = []
        for i in range(n_nodes):
            self._add_node(i, spec)

    # -- chaos wiring ---------------------------------------------------------

    def attach_faults(self, plan_or_injector) -> "FaultInjector":
        """Put a seeded fault injector on the pool's fabric boundary.

        Scheduled crashes fail the node and run serving/container
        failover immediately (deterministic — no dependence on
        heartbeat wall-clock); straggler latency feeds each node's
        latency EMA so the heartbeat sweep flips it to *suspect*."""
        from repro.core.faults import FaultInjector, FaultPlan

        if isinstance(plan_or_injector, FaultPlan):
            inj = FaultInjector(plan_or_injector)
        else:
            inj = plan_or_injector

        def _crash(ip: str):
            node = self.nodes.get(ip)
            if node is None or not node.alive:
                return
            node.fail()
            self.events.append(("fault-crash", ip))
            self._serve_failover(ip)
            self._reschedule_off(ip)

        def _lat(ip: str, mult: float):
            node = self.nodes.get(ip)
            if node is not None:
                # nominal fabric latency is ~1 ms; a straggler pays
                # mult x, so the EMA converges toward mult
                node.latency_ema_ms = (0.8 * node.latency_ema_ms +
                                       0.2 * float(mult))

        inj.on_crash = _crash
        inj.on_latency = _lat
        self.fault_injector = inj
        self.driver.attach_faults(inj)
        return inj

    # -- membership -----------------------------------------------------------

    def alive_nodes(self) -> List[str]:
        return [ip for ip, n in self.nodes.items() if n.alive]

    def check_heartbeats(self, now: Optional[float] = None) -> List[str]:
        """Returns newly-dead node ips and reschedules their containers."""
        now = time.monotonic() if now is None else now
        dead = []
        for ip, node in self.nodes.items():
            if not node.heartbeat(now) and \
                    now - node.last_heartbeat > self.heartbeat_timeout:
                dead.append(ip)
        for ip in dead:
            # serving failover first: the shard index must be read from
            # the serving placement before _reschedule_off rewires it
            self._serve_failover(ip)
            self._reschedule_off(ip)
        # suspect sweep: stragglers are *degraded*, not dead — existing
        # work stays, new placements steer away until the EMA clears
        slow = set(self.stragglers())
        for ip, node in self.nodes.items():
            was = node.suspect
            node.suspect = node.alive and ip in slow
            if node.suspect and not was:
                self.events.append(("suspect", ip))
            elif was and not node.suspect:
                self.events.append(("suspect-cleared", ip))
        return dead

    def suspect_nodes(self) -> List[str]:
        return [ip for ip, n in self.nodes.items() if n.suspect]

    def mark_unreachable(self, ip: str):
        """Delivery to ``ip`` exhausted the fabric's retransmit budget:
        treat the node as dead *now* — run serving/container failover —
        instead of waiting for the heartbeat sweep to notice."""
        node = self.nodes.get(ip)
        if node is not None and node.alive:
            node.fail()
            self.events.append(("unreachable", ip))
        self._serve_failover(ip)
        self._reschedule_off(ip)

    def stragglers(self) -> List[str]:
        alive = [self.nodes[ip] for ip in self.alive_nodes()]
        if not alive:
            return []
        med = sorted(n.latency_ema_ms for n in alive)[len(alive) // 2]
        return [n.ip for n in alive
                if n.latency_ema_ms > self.straggler_factor * max(med, 1e-6)]

    # -- image distribution / scheduling ----------------------------------------

    def broadcast_pull(self, name: str, blob: bytes, ips=None):
        for ip in (ips or self.alive_nodes()):
            self.nodes[ip].docker.cmd_pull(name, blob)

    def locate_extent(self, name: str) -> Optional[str]:
        """IP of the alive node whose flash holds extent ``name`` (data
        placement is the scheduling input of the offload planner).
        Prefers a non-suspect replica when one exists."""
        hits = self.locate_replicas(name)
        good = [ip for ip in hits if not self.nodes[ip].suspect]
        return (good or hits)[0] if hits else None

    def locate_replicas(self, name: str) -> List[str]:
        """Every alive node holding extent ``name`` — the retry set for
        a job whose first delivery attempt lost its node."""
        return [ip for ip in self.alive_nodes()
                if name in self.nodes[ip].extents.extents]

    def place_distributed(self, job: str, image: str, *, dp: int = 1,
                          tp: int = 1, pp: int = 1) -> Placement:
        """Group nodes into one distributed system (the paper's preferred
        mode).  Needs dp*tp*pp healthy nodes; stage id = pipeline stage."""
        need = dp * tp * pp
        avail = [ip for ip in self.alive_nodes()
                 if ip not in self._occupied()]
        if len(avail) < need:
            raise RuntimeError(f"pool has {len(avail)} free nodes; "
                               f"need {need}")
        chosen = avail[:need]
        pl = Placement(job=job, node_ips=chosen, dp=dp, tp=tp, pp=pp)
        for i, ip in enumerate(chosen):
            pl.stage_of[ip] = (i // (dp * tp)) % pp
        self.placements[job] = pl
        self.events.append(("place", job))
        return pl

    def place_independent(self, job: str, image: str, n: int) -> Placement:
        """Mode 1: independent app instances across nodes."""
        avail = [ip for ip in self.alive_nodes()
                 if ip not in self._occupied()][:n]
        pl = Placement(job=job, node_ips=avail)
        self.placements[job] = pl
        return pl

    def run_on(self, job: str, fn: Callable[[DockerSSDNode, int], Any]):
        """Execute fn(node, rank) over a placement's nodes; EMA latency."""
        pl = self.placements[job]
        out = []
        for rank, ip in enumerate(pl.node_ips):
            node = self.nodes[ip]
            if not node.alive:
                raise RuntimeError(f"node {ip} died mid-job")
            t0 = time.monotonic()
            out.append(fn(node, rank))
            dt = (time.monotonic() - t0) * 1e3
            node.latency_ema_ms = 0.8 * node.latency_ema_ms + 0.2 * dt
        return out

    # -- pool-serving frontend -------------------------------------------------
    #
    # One request flows: frontend (here) -> Ether-oN control frame to the
    # chosen DockerSSD -> PoolServer admission on that node's shard ->
    # the mesh-sharded jitted decode.  Only control messages ride frames;
    # token-rate tensor traffic rides the jax collectives inside the
    # jitted step (DESIGN.md §Pool serving).

    def attach_server(self, server, job: str = "llm-serve") -> Placement:
        """Bind a ``runtime.pool.PoolServer`` to this pool: each fabric
        node in the serving placement backs one mesh shard.  Needs one
        free healthy node per *active* shard (an elastic server's
        parked shards may start unbacked — ``scale_to`` /
        ``grow_serving`` wire nodes to them later).  Spare free nodes
        back parked shards eagerly, so a later join is pure
        activation."""
        active = server.alive_nodes()
        free = [ip for ip in self.alive_nodes()
                if ip not in self._occupied()]
        k = max(len(active), min(server.n_nodes, len(free)))
        pl = self.place_distributed(job, "llm-serve", tp=k)
        self._server = server
        self._serve_job = job
        # stable shard-indexed ip map: container rescheduling may rewire
        # the *placement* after a failure, but mesh shard i keeps its
        # identity (a lost window is not revived by a restarted
        # container).  Active shards are backed first; None marks a
        # parked shard still waiting for a fabric node.
        self._serve_ips = [None] * server.n_nodes
        for ip, s in zip(pl.node_ips, list(active) + server.parked_nodes()):
            self._serve_ips[s] = ip
        return pl

    def serving_ips(self) -> List[str]:
        return list(self._serve_ips)

    def suspect_shards(self) -> set:
        """Mesh shard indices currently backed by a suspect node."""
        if self._server is None:
            return set()
        return {i for i, ip in enumerate(self._serve_ips)
                if ip in self.nodes and self.nodes[ip].suspect}

    def _pick_serving_node(self, n_tokens: int) -> int:
        """Least-loaded healthy shard, steering around suspects unless
        every alive shard is suspect (advisory state must never
        deadlock admission)."""
        srv = self._server
        alive = srv.alive_nodes()
        if not alive:
            raise EtherONError("no serving nodes alive")
        sus = self.suspect_shards()
        cand = [s for s in alive if s not in sus] or alive
        return max(cand, key=lambda s: (srv.table.shard_free_pages(s), -s))

    def place_sequence(self, seq_id: int, n_tokens: int,
                       node: Optional[int] = None,
                       prompt=None) -> int:
        """Admit a sequence: choose a node (the node already holding
        ``prompt``'s prefix when one exists, else least-loaded by free
        window pages, unless the router already picked one), announce
        the placement to that node over Ether-oN, and return the shard
        index for ``PoolServer.add_request``/``begin_request``.

        A placement announcement that exhausts the fabric's retransmit
        budget means the chosen node is unreachable — it is failed over
        on the spot and the sequence re-placed on a surviving shard."""
        srv = self._server
        if node is None and prompt is not None:
            node = srv.pick_prefix_node(prompt, n_tokens)
            if node is not None and node in self.suspect_shards() and \
                    set(srv.alive_nodes()) - self.suspect_shards():
                node = None     # warm prefix isn't worth a straggler
        while True:
            if node is None:
                node = self._pick_serving_node(n_tokens)
            try:
                self.driver.send_control(
                    self._serve_ips[node], "place", seq_id,
                    extra=str(srv.pages_needed(n_tokens)))
                self._drain_acks()
                return node
            except EtherONError:
                ip = self._serve_ips[node]
                self.events.append(("place-retry", f"{seq_id}:{ip}"))
                self.mark_unreachable(ip)
                node = None
                if not srv.alive_nodes():
                    raise

    def retire_sequence(self, seq_id: int) -> int:
        """Free a finished sequence: notify the owning node (every node,
        for a striped extent) over Ether-oN, then release its pages in
        both tiers through the server's public API."""
        srv = self._server
        owner = srv.node_of(seq_id)
        shards = [owner] if owner is not None else srv.alive_nodes()
        for s in shards:
            if s in srv.alive_nodes():      # no frames to dead nodes
                try:
                    self.driver.send_control(self._serve_ips[s], "free",
                                             seq_id)
                except EtherONError:
                    # the owner died with the free in flight: its pages
                    # died with it — fail it over and fall through to
                    # the (idempotent) server-side release
                    self.mark_unreachable(self._serve_ips[s])
        self._drain_acks()
        return srv.free_sequence(seq_id)

    def serving_tier_stats(self) -> Dict[str, object]:
        """Aggregate serving telemetry: the pool totals plus the
        per-node breakdown (the aggregate is the field-wise sum of the
        nodes — each DockerSSD owns its window and flash tier)."""
        return {"pool": self._server.tier_stats(),
                "nodes": self._server.node_tier_stats()}

    def take_requeued(self) -> List[int]:
        """Sequence ids dropped by node failures since the last call —
        the router re-prefills them on the surviving nodes."""
        out, self._requeue = self._requeue, []
        return out

    def _serve_failover(self, dead_ip: str):
        """Heartbeat-driven serving failover: when a serving node dies,
        its shard's window and tier are lost — drop the sequences homed
        there and queue them for router re-admission."""
        if self._server is None or dead_ip not in self._serve_ips:
            return
        shard = self._serve_ips.index(dead_ip)
        if shard in self._server._dead:
            return                      # already handled (idempotent)
        victims = self._server.fail_node(shard)
        self._requeue.extend(victims)
        self.events.append(("serve-requeue",
                            f"{dead_ip}:{','.join(map(str, victims))}"))

    def _drain_acks(self):
        """Pull control-frame ACKs off the upcall inbox (their cost is
        already accounted by the driver)."""
        while self.driver.poll() is not None:
            pass

    def _occupied(self):
        occ = set()
        for pl in self.placements.values():
            occ.update(pl.node_ips)
        return occ

    def _reschedule_off(self, dead_ip: str):
        """Failure handling: replace a dead node in every placement with a
        free healthy one (container restart on the new node)."""
        for pl in self.placements.values():
            if dead_ip in pl.node_ips:
                free = [ip for ip in self.alive_nodes()
                        if ip not in self._occupied()]
                if not free:
                    self.events.append(("degraded", pl.job))
                    pl.node_ips.remove(dead_ip)
                    continue
                new_ip = free[0]
                idx = pl.node_ips.index(dead_ip)
                pl.node_ips[idx] = new_ip
                pl.stage_of[new_ip] = pl.stage_of.pop(dead_ip, 0)
                self.events.append(("reschedule", f"{pl.job}:{dead_ip}->{new_ip}"))

    # -- elastic membership --------------------------------------------------------

    def _add_node(self, i: int, spec: Optional[NodeSpec]):
        """Provision node ``i``: wired into the Ether-oN fabric, λFS lock
        syncs attached, and slotted into its array (array topology follows
        the pool's configured ``array_size``).  Each node gets its own
        NodeSpec copy — per-node state never aliases across the pool."""
        ip = f"10.0.{1 + i // self.array_size}.{2 + i % self.array_size}"
        node = DockerSSDNode(
            ip, dataclasses.replace(spec) if spec is not None else None,
            extent_cfg=self.extent_cfg)
        node.fs.attach_ether(self.driver)
        self.nodes[ip] = node
        self.driver.attach(node.endpoint)
        if i % self.array_size == 0:
            self.arrays.append([])
        self.arrays[-1].append(ip)
        return node

    def scale_to(self, n: int, spec: Optional[NodeSpec] = None):
        """Grow the fabric to ``n`` nodes.  With a serving mesh
        attached, every new node must be wired into the shard map (an
        unbacked parked shard, which it backs and activates) — a node
        that could never serve pages is rejected up front rather than
        silently joining the fabric.  Without a server the nodes join
        the fabric plain (analytics pools).  Shrinking is not this
        knob: drain serving nodes with ``drain_serving_node``."""
        cur = len(self.nodes)
        if n < cur:
            raise ValueError(
                f"scale_to grows the fabric (have {cur}, asked {n}); "
                "remove serving nodes with drain_serving_node instead")
        if self._server is not None:
            slots = self._serve_ips.count(None)
            if n - cur > slots:
                raise RuntimeError(
                    f"serving mesh has {slots} unbacked shard(s) left "
                    f"(capacity {self._server.n_nodes}, the pow2 bucket "
                    f"compiled at startup); scale_to({n}) would attach "
                    f"{n - cur - slots} node(s) that could never serve "
                    "pages — provision a PoolServer with a larger "
                    "n_nodes bucket instead")
        for i in range(cur, n):
            node = self._add_node(i, spec)
            if self._server is not None:
                self._wire_serving_node(node.ip)
        self.events.append(("scale", str(n)))

    def _wire_serving_node(self, ip: str) -> int:
        """Back one unbacked mesh shard with fabric node ``ip`` and
        activate it (join announced over Ether-oN).  Zero retrace: the
        shard's device program has existed since startup."""
        srv = self._server
        shard = self._serve_ips.index(None)
        self._serve_ips[shard] = ip
        pl = self.placements[self._serve_job]
        pl.node_ips.append(ip)
        pl.stage_of[ip] = 0
        self.driver.send_control(ip, "join", shard)
        self._drain_acks()
        srv.activate_node(shard)
        self.events.append(("serve-join", f"{ip}:{shard}"))
        return shard

    def grow_serving(self, n_active: int):
        """Raise the serving set to ``n_active`` nodes: re-activate
        parked shards that kept their backing node, wire free fabric
        nodes to unbacked shards, and only then grow the fabric itself
        (``scale_to``).  Each step is one node — the autoscaler's unit
        of change."""
        srv = self._server
        if srv is None:
            raise RuntimeError("no server attached")
        if n_active > srv.n_nodes:
            raise RuntimeError(
                f"asked for {n_active} serving nodes but the mesh "
                f"bucket compiled at startup holds {srv.n_nodes}; "
                "provision a PoolServer with a larger n_nodes bucket")
        while len(srv.alive_nodes()) < n_active:
            backed = [s for s in srv.parked_nodes()
                      if s not in srv._dead
                      and self._serve_ips[s] is not None
                      and self.nodes[self._serve_ips[s]].alive]
            if backed:
                s = backed[0]
                self.driver.send_control(self._serve_ips[s], "join", s)
                self._drain_acks()
                srv.activate_node(s)
                self.events.append(
                    ("serve-join", f"{self._serve_ips[s]}:{s}"))
                continue
            free = [ip for ip in self.alive_nodes()
                    if ip not in self._occupied()]
            if free:
                self._wire_serving_node(free[0])
            else:
                self.scale_to(len(self.nodes) + 1)

    def drain_serving_node(self, node: int) -> Dict:
        """Zero-drop drain of serving node ``node`` (planned removal —
        the autoscaler's scale-down step).  Announces the drain, then
        walks the server's two-path drain: each warm page move is
        announced to its destination with a MIGRATE frame (reliable
        tunnel — chaos retransmits land in the delivery counters), and
        cold victims enter the requeue list the router already drains
        (PR-2 failover re-prefill), so nothing is shed."""
        srv = self._server
        if srv is None:
            raise RuntimeError("no server attached")
        ip = self._serve_ips[node]
        self.events.append(("serve-drain", f"{ip}:{node}"))
        try:
            self.driver.send_control(ip, "drain", node)
            self._drain_acks()
        except EtherONError:
            # unreachable drainee: the planned drain degenerates into
            # the unplanned-failure path (requeue via failover)
            self.mark_unreachable(ip)
        if node in srv._dead:
            return {"victims": [], "migrated_pages": 0, "cold": [],
                    "moved": {}}
        page_bytes = srv.store.page_bytes()

        def on_migrate(seq_id, page_idx, src, dst):
            dst_ip = self._serve_ips[dst]
            try:
                self.driver.send_migrate(dst_ip, seq_id, page_idx,
                                         page_bytes, src, dst)
            except EtherONError:
                self.mark_unreachable(dst_ip)
                raise

        rep = srv.drain_node(node, on_migrate=on_migrate)
        self._drain_acks()
        self._requeue.extend(rep["cold"])
        return rep
