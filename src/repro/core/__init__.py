"""DockerSSD core layer: the paper's contribution as composable modules."""
from repro.core.container import (APP_REGISTRY, ContainerError,  # noqa: F401
                                  ContainerOOM, MiniDocker, from_jsonable,
                                  make_blob, ImageManifest, register_app,
                                  to_jsonable)
from repro.core.ether_on import (DockerSSDEndpoint, EtherONDriver,  # noqa: F401
                                 EthernetFrame, UPCALL_SLOTS)
from repro.core.extent_store import (ANALYTICS_IMAGE, AnalyticsJob,  # noqa: F401
                                     Extent, ExtentStore, ExtentStoreError,
                                     analytics_blob)
from repro.core.kv_tier import (PagedKVCache, PageStore,  # noqa: F401
                                PageTableManager)
from repro.core.lambda_fs import (LambdaFS, LockHeld, PRIVATE_NS,  # noqa: F401
                                  SHARABLE_NS)
from repro.core.storage_pool import (DockerSSDNode, NodeSpec,  # noqa: F401
                                     StoragePool)
from repro.core.virtual_fw import MPUViolation, TCPConn, VirtualFW  # noqa: F401
