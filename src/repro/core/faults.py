"""Deterministic fault injection at the Ether-oN fabric boundary.

A disaggregated pool lives on a lossy fabric: frames drop, payloads
corrupt in flight, switches duplicate and reorder, nodes straggle and
nodes die.  The chaos layer models all of it *deterministically*: a
:class:`FaultPlan` is a declarative, JSON-round-trippable schedule, and
a :class:`FaultInjector` seeded from it makes every chaos run
replayable bit for bit — the property the chaos invariant tests lean
on (same plan => same faults => same retransmit counters => identical
outputs).

The injector sits on the one seam every frame crosses
(:meth:`~repro.core.ether_on.EtherONDriver.transmit` down,
:meth:`~repro.core.ether_on.DockerSSDEndpoint.send_to_host` up): the
driver hands it each sealed frame and delivers whatever comes back —
possibly nothing (drop), the frame plus a stale copy (duplicate), a
bit-flipped *copy* (corruption — the original stays intact for the
retransmit path), frames held back and released later (delay /
reorder).  Node crashes and straggler latency are *scheduled* against
the injector's fabric-op clock and surfaced through callbacks, so the
pool's heartbeat/suspect machinery reacts to them exactly as it would
to a real failure.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: frame travel directions at the fabric boundary
DOWN = "down"          # host -> SSD (0xE0 transmit)
UP = "up"              # SSD -> host (0xE1 upcall)


@dataclasses.dataclass
class FaultPlan:
    """Declarative, replayable chaos schedule.

    Probabilities are per fabric frame (evaluated in deterministic
    fabric-op order from ``seed``); ``crashes`` and ``stragglers``
    are scheduled against the injector's op clock — the count of
    frames that have crossed the boundary — so a plan replays
    identically regardless of wall-clock.

    * ``p_drop`` — frame vanishes (sender retransmits on timeout).
    * ``p_corrupt`` — one payload byte flips on a *copy* of the frame
      (CRC catches it; receiver NACKs; sender retransmits the intact
      original).
    * ``p_dup`` — the frame arrives twice (receiver dedups by seq).
    * ``p_delay`` — the frame is held back and released after the next
      ``delay_ops`` same-flow frames (``delay_ops=1`` is an adjacent
      reorder).
    * ``crashes`` — ``{ip: op_clock}``: node ``ip`` dies once the op
      clock reaches that tick.
    * ``stragglers`` — ``{ip: latency_multiplier}``: every frame
      touching ``ip`` pays ``x`` the normal fabric latency (surfaced
      via ``on_latency`` so the pool's EMA/suspect detection sees it).
      The wildcard key ``"*"`` applies to every node — it lets a plan
      written before the pool's ips exist (a preset, a CLI flag, the
      chaos-during-drain suite) slow the whole fabric down.
    """
    seed: int = 0
    p_drop: float = 0.0
    p_corrupt: float = 0.0
    p_dup: float = 0.0
    p_delay: float = 0.0
    delay_ops: int = 1
    crashes: Dict[str, int] = dataclasses.field(default_factory=dict)
    stragglers: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for name in ("p_drop", "p_corrupt", "p_dup", "p_delay"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.delay_ops < 1:
            raise ValueError(f"delay_ops must be >= 1, got "
                             f"{self.delay_ops}")

    # -- JSON round trip (the --fault-plan file format) ----------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls(**json.loads(text))

    @property
    def lossy(self) -> bool:
        return (self.p_drop > 0 or self.p_corrupt > 0 or
                self.p_dup > 0 or self.p_delay > 0)


class FaultInjectorStats:
    """What the injector actually did (the ground truth the delivery
    counters in ``EtherONStats`` are checked against)."""

    def __init__(self):
        self.frames_seen = 0
        self.dropped = 0
        self.corrupted = 0
        self.duplicated = 0
        self.delayed = 0
        self.crashed_nodes: List[str] = []

    def as_dict(self) -> Dict[str, object]:
        return dict(vars(self))


class FaultInjector:
    """Seeded fault source wrapping the Ether-oN fabric boundary.

    The driver calls :meth:`transit` for every frame crossing the
    boundary and delivers exactly the frames it returns, in order.
    Randomness comes from one PCG64 generator consumed in fabric-op
    order, so a run is a pure function of (plan, traffic) — replaying
    the same workload under the same plan injects the same faults at
    the same frames.

    ``on_crash(ip)`` fires (once per ip) when the op clock crosses a
    scheduled crash tick; ``on_latency(ip, mult)`` fires for every
    frame touching a straggler node.  Both are wired up by
    ``StoragePool.attach_faults``.
    """

    def __init__(self, plan: FaultPlan,
                 on_crash: Optional[Callable[[str], None]] = None,
                 on_latency: Optional[Callable[[str, float],
                                              None]] = None):
        self.plan = plan
        self.on_crash = on_crash
        self.on_latency = on_latency
        self.stats = FaultInjectorStats()
        self._rng = np.random.Generator(np.random.PCG64(plan.seed))
        self._ops = 0
        self._crashed: set = set()
        # held-back frames per (direction, ip) flow: (release_op, frame)
        self._held: Dict[Tuple[str, str], List[Tuple[int, object]]] = {}

    # -- op clock / scheduled events -----------------------------------------

    @property
    def op_clock(self) -> int:
        return self._ops

    def _tick(self, ip: str):
        self._ops += 1
        for cip, when in self.plan.crashes.items():
            if self._ops >= int(when) and cip not in self._crashed:
                self._crashed.add(cip)
                self.stats.crashed_nodes.append(cip)
                if self.on_crash is not None:
                    self.on_crash(cip)
        mult = self.plan.stragglers.get(ip, self.plan.stragglers.get("*"))
        if mult is not None and self.on_latency is not None:
            self.on_latency(ip, float(mult))

    def latency_mult(self, ip: str) -> float:
        """Straggler multiplier for fabric ops touching ``ip`` (the
        ``"*"`` wildcard slows every node)."""
        return float(self.plan.stragglers.get(
            ip, self.plan.stragglers.get("*", 1.0)))

    def node_crashed(self, ip: str) -> bool:
        return ip in self._crashed

    # -- the boundary hook ---------------------------------------------------

    def _corrupt_copy(self, frame):
        """Bit-flip one payload byte on a COPY — the sender's original
        must stay intact or the retransmit would resend the damage."""
        payload = bytearray(frame.payload)
        if payload:
            i = int(self._rng.integers(len(payload)))
            payload[i] ^= 0xFF
        bad = dataclasses.replace(frame, payload=bytes(payload))
        # keep the ORIGINAL checksum: the whole point is a payload that
        # no longer matches its CRC
        bad.checksum = frame.checksum
        return bad

    def transit(self, frame, direction: str, ip: str) -> List:
        """One frame crossing the boundary.  Returns the frames to
        deliver (possibly none, possibly with copies or released
        held-back frames), in delivery order."""
        self._tick(ip)
        self.stats.frames_seen += 1
        key = (direction, ip)
        out: List = []
        # release any held frames whose tick has come (same flow only —
        # a delayed frame must rejoin its own reassembly stream)
        held = self._held.get(key, [])
        due = [f for when, f in held if when <= self._ops]
        self._held[key] = [(w, f) for w, f in held if w > self._ops]

        p = self.plan
        r = self._rng.random(4)
        if r[0] < p.p_drop:
            self.stats.dropped += 1
            return out + due
        if r[1] < p.p_corrupt:
            self.stats.corrupted += 1
            out.append(self._corrupt_copy(frame))
            return out + due
        if r[3] < p.p_delay:
            self.stats.delayed += 1
            self._held.setdefault(key, []).append(
                (self._ops + int(p.delay_ops), frame))
            return out + due
        out.append(frame)
        if r[2] < p.p_dup:
            self.stats.duplicated += 1
            out.append(frame)         # same object: receiver dedups it
        return out + due


#: canned plans for the chaos suite / --fault-plan presets
PRESET_PLANS: Dict[str, FaultPlan] = {
    "none": FaultPlan(),
    "lossy": FaultPlan(seed=7, p_drop=0.08, p_corrupt=0.05, p_dup=0.06,
                       p_delay=0.06, delay_ops=2),
    "storm": FaultPlan(seed=13, p_drop=0.2, p_corrupt=0.12, p_dup=0.1,
                       p_delay=0.1, delay_ops=3),
}


def load_plan(spec: str) -> FaultPlan:
    """Resolve a ``--fault-plan`` argument: a preset name, a path to a
    JSON plan file, or inline JSON."""
    if spec in PRESET_PLANS:
        return PRESET_PLANS[spec]
    if spec.lstrip().startswith("{"):
        return FaultPlan.from_json(spec)
    with open(spec) as f:
        return FaultPlan.from_json(f.read())
