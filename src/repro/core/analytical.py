"""Analytical distributed-LLM-inference model (the paper's Calculon-style
simulator, extended — as the paper did — with a KV-cache model and a
DP/TP/PP parallelism sweep).

Reproduces Fig 12a (optimal parallelism per disaggregation model),
Fig 12b (8 LLMs x {H,D} x {NoCache,Cache}), Fig 13a/b (sequence-length
sensitivity: crossover + ~9.5x converged speedup) and Fig 13c/d (batch
sensitivity, <=~1.3x).

Physical story (paper section "Disaggregated Computing Storage"):
  * H-NoCache — hosts recompute all K/V every step (O(n^2) compute),
    all data in local DRAM.
  * H-Cache  — hosts keep a KV cache; it exceeds DRAM, so the overflow
    lives on a 400 GB SSD behind **Linux swap** (page faults, cache
    pollution, mode switches, extra copies -> low effective bandwidth).
  * D-NoCache — recompute inside DockerSSDs (slower cores: 2.2 vs
    3.8 GHz -> ~1.7x slower than H-NoCache).
  * D-Cache  — KV cache on flash **local to the compute**, accessed as
    memory through λFS at aggregate multi-channel bandwidth — no swap
    machinery.  This is the paper's headline winner (~7.9x over
    H-Cache).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# model zoo of the paper's LLM case study (public configs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LLM:
    name: str
    n_params: float
    n_layers: int
    d_model: int
    n_heads: int


POOL_LLMS = [
    LLM("lamda-137B", 137e9, 64, 8192, 128),
    LLM("gpt3-175B", 175e9, 96, 12288, 96),
    LLM("jurassic-178B", 178e9, 76, 13824, 96),
    LLM("pangu-200B", 200e9, 64, 16384, 128),
    LLM("gopher-280B", 280e9, 80, 16384, 128),
    LLM("turing-530B", 530e9, 105, 20480, 128),
    LLM("palm-540B", 540e9, 118, 18432, 48),
    LLM("megatron-1T", 1000e9, 128, 25600, 160),
]


# ---------------------------------------------------------------------------
# hardware constants (calibrated to the paper's prototype numbers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HW:
    """Calibrated against the paper's headline numbers (random-search fit;
    see benchmarks/calibrate.py).  Achieved vs paper:
      D-Cache/H-Cache 7.3 (7.9) | H-Cache/H-NoCache 420 (421)
      D-Cache/D-NoCache 4.8K (4.6K) | D-Cache/H-NoCache 3.1K (3.2K)
      D-NoCache slowdown 1.6x (1.7x) | crossover lamda 256 (256),
      megatron 512 (1024) | converged speedup 9.6x (~9.5x)."""
    # compute (effective dense FLOP/s per node; CPU-class inference path)
    host_flops: float = 2.953e11        # 3.8 GHz host
    ssd_flops: float = 1.902e11         # 2.2 GHz frontend (~1.6x slower)
    # memory paths
    dram_bw: float = 1.080e10           # host DDR4 effective
    dram_gb: float = 64.0               # per host node
    swap_eff_bw: float = 8.73e8         # Linux swap: page-fault + copy +
    #                                     cache-pollution machinery
    flash_local_bw: float = 1.331e10    # 12-channel aggregate, λFS direct
    ssd_dram_gb: float = 2.0
    # interconnect (TP collectives / PP boundaries)
    link_bw: float = 2.576e10
    bytes_per = 2                       # bf16
    # "all other data is also maintained in memory": framework + weight
    # copies occupy DRAM beyond the raw fp16 weights
    weight_overhead: float = 1.255
    # the KV region is allocated swap-backed from the start: most of it
    # pays page machinery even when DRAM-resident
    swap_floor: float = 0.773


# ---------------------------------------------------------------------------
# single-step latency model
# ---------------------------------------------------------------------------


# effective bytes per stored KV element by page format (runtime.serve's
# ``page_dtype`` knob); quantized formats add the per-slot f32 scale,
# amortized over the head_dim lanes it covers
PAGE_DTYPE_BYTES = {"fp32": 4.0, "bf16": 2.0, "int8": 1.0, "fp8": 1.0}


def page_dtype_bytes_per_elem(page_dtype: str, head_dim: int = 128) -> float:
    base = PAGE_DTYPE_BYTES[page_dtype]
    if page_dtype in ("int8", "fp8"):
        base += 4.0 / max(head_dim, 1)
    return base


def kv_bytes_per_token(m: LLM, hw: HW, page_dtype: str = None) -> float:
    """KV bytes per cached token.  Default prices at ``hw.bytes_per``
    (the calibrated bf16 story); passing a page format prices at that
    format's code+scale size — the knob the Fig-13 sensitivity sweeps
    turn to see quantization shift the D/H crossover."""
    if page_dtype is None:
        return 2 * m.n_layers * m.d_model * hw.bytes_per
    hd = max(m.d_model // max(m.n_heads, 1), 1)
    return 2 * m.n_layers * m.d_model * page_dtype_bytes_per_elem(
        page_dtype, hd)


def step_time(m: LLM, *, t: int, batch: int, dp: int, tp: int, pp: int,
              cache: bool, device: str, hw: HW = HW(),
              page_dtype: str = None) -> Dict[str, float]:
    """Latency of generating token t (context length t), per microstep.

    Returns dict with compute/memory/comm components (seconds).
    """
    flops_dev = hw.host_flops if device == "host" else hw.ssd_flops
    b_local = max(1, batch // dp)

    attn = 4 * m.n_layers * m.d_model            # attention MACs/token/ctx
    if cache:
        flops = (2 * m.n_params + attn * t) * b_local   # one token forward
        kv_read = kv_bytes_per_token(m, hw, page_dtype) * t * b_local
    else:
        # recompute the whole prefix: O(t) weight flops + O(t^2) attention
        flops = (2 * m.n_params * t + attn * t * t) * b_local
        kv_read = 0.0

    # Parallelism semantics (the reason Fig 12a flips):
    #  * cache (one token/step): the token passes PP stages *sequentially*
    #    -> pp does NOT divide per-token latency; only tp does.  pp still
    #    divides per-node weight footprint (capacity -> less swap).
    #  * nocache (recompute t tokens): the prefix streams through the
    #    pipeline as microbatches -> pp divides latency with efficiency
    #    t/(t+pp-1).
    weight_read = m.n_params * hw.bytes_per / tp      # summed across stages
    if cache:
        div = tp
    else:
        pipe_eff = t / (t + pp - 1)
        div = tp * pp * pipe_eff
    compute = flops / (flops_dev * div)

    # memory path.  KV reads: per-node footprint is /(tp*pp) (capacity),
    # but a decoded token reads the KV of *every* stage sequentially, so
    # the latency-relevant read volume divides by tp only.
    if device == "host":
        if cache:
            kv_total_gb = (kv_bytes_per_token(m, hw, page_dtype) * t *
                           b_local / (tp * pp) / 1e9)
            # DP replicates weights; only tp*pp shrinks the footprint
            dram_free = max(hw.dram_gb - hw.weight_overhead * m.n_params *
                            hw.bytes_per / (tp * pp) / 1e9, 0.5)
            swap_frac = max(hw.swap_floor,
                            1.0 - dram_free / max(kv_total_gb, 1e-9))
            mem = (kv_read / tp) * (
                (1 - swap_frac) / hw.dram_bw + swap_frac / hw.swap_eff_bw)
        else:
            mem = 0.0
        mem += weight_read / hw.dram_bw
    else:
        bw = hw.flash_local_bw
        mem = (kv_read / tp) / bw + weight_read / bw

    # communication: TP all-reduce twice per layer on the activations of
    # the tokens being processed; PP passes boundary activations
    tokens_proc = b_local * (t if not cache else 1)
    act = tokens_proc * m.d_model * hw.bytes_per
    comm = 0.0
    if tp > 1:
        comm += 2 * m.n_layers / pp * 2 * (tp - 1) / tp * act / hw.link_bw
    if pp > 1:
        comm += (pp - 1) * act / hw.link_bw
    return {"compute": compute, "memory": mem, "comm": comm,
            "total": compute + mem + comm}


def generation_time(m: LLM, *, seq_len: int, batch: int, dp: int, tp: int,
                    pp: int, cache: bool, device: str, hw: HW = HW(),
                    page_dtype: str = None,
                    sample_points: int = 24) -> Dict[str, float]:
    """Total time to generate ``seq_len`` tokens (trapezoidal sampling of
    the per-step cost over t)."""
    ts = sorted({max(1, int(seq_len * i / sample_points))
                 for i in range(sample_points + 1)})
    comp = mem = comm = 0.0
    prev_t = 0
    for t in ts:
        st = step_time(m, t=t, batch=batch, dp=dp, tp=tp, pp=pp,
                       cache=cache, device=device, hw=hw,
                       page_dtype=page_dtype)
        w = t - prev_t
        comp += st["compute"] * w
        mem += st["memory"] * w
        comm += st["comm"] * w
        prev_t = t
    return {"compute": comp, "memory": mem, "comm": comm,
            "total": comp + mem + comm}


# ---------------------------------------------------------------------------
# parallelism sweep (Fig 12a)
# ---------------------------------------------------------------------------


def factorizations(n: int) -> List[Tuple[int, int, int]]:
    out = []
    for dp in [2 ** i for i in range(int(math.log2(n)) + 1)]:
        if n % dp:
            continue
        rest = n // dp
        for tp in [2 ** i for i in range(int(math.log2(rest)) + 1)]:
            if rest % tp:
                continue
            out.append((dp, tp, rest // tp))
    return out


def best_parallelism(m: LLM, *, n_nodes: int, seq_len: int, batch: int,
                     cache: bool, device: str, hw: HW = HW(),
                     page_dtype: str = None):
    """Sweep (dp, tp, pp); return (best cfg, its time breakdown)."""
    best, best_t = None, None
    for dp, tp, pp in factorizations(n_nodes):
        if dp > max(batch, 1):
            continue
        if pp > m.n_layers:
            continue
        if device == "host":
            # hard capacity: the weight shard must fit host DRAM
            w_gb = hw.weight_overhead * m.n_params * hw.bytes_per / (tp * pp) / 1e9
            if w_gb > hw.dram_gb:
                continue
        else:
            # DockerSSD: the weight shard must fit the node's 400GB flash.
            # (KV extents can span the pool's aggregate flash via λFS —
            # the disaggregated-storage point of the paper.)
            w_gb = m.n_params * hw.bytes_per / (tp * pp) / 1e9
            if w_gb > 400.0:
                continue
        t = generation_time(m, seq_len=seq_len, batch=batch, dp=dp, tp=tp,
                            pp=pp, cache=cache, device=device, hw=hw,
                            page_dtype=page_dtype)
        if best_t is None or t["total"] < best_t["total"]:
            best, best_t = (dp, tp, pp), t
    return best, best_t


# ---------------------------------------------------------------------------
# the four disaggregation configurations (Fig 12b)
# ---------------------------------------------------------------------------

CONFIGS = ["H-NoCache", "H-Cache", "D-NoCache", "D-Cache"]


def config_args(config: str):
    return {"cache": config.endswith("-Cache"),
            "device": "host" if config.startswith("H") else "ssd"}


def nodes_for(m: LLM) -> int:
    """16..128 DockerSSDs/hosts depending on model size (paper setup).
    Sized so the fp16 weights (+ framework overhead) fit the host fleet's
    DRAM when fully model-parallel (the H-* configurations must have at
    least one feasible parallelization)."""
    hw = HW()
    w_gb = hw.weight_overhead * m.n_params * hw.bytes_per / 1e9
    need = max(16.0, w_gb / hw.dram_gb, m.n_params * 2 / 350e9)
    return int(min(128, 2 ** math.ceil(math.log2(need))))


def evaluate_pool(seq_len: int = 32768, batch_per_node: int = 1,
                  hw: HW = HW()):
    """Fig 12: for each LLM x config, optimal parallelism + breakdown."""
    results = {}
    for m in POOL_LLMS:
        n = nodes_for(m)
        batch = batch_per_node * n
        row = {}
        for config in CONFIGS:
            ca = config_args(config)
            best, t = best_parallelism(m, n_nodes=n, seq_len=seq_len,
                                       batch=batch, hw=hw, **ca)
            row[config] = {"parallelism": best, "time": t}
        results[m.name] = {"nodes": n, "configs": row}
    return results


def headline_ratios(results) -> Dict[str, float]:
    """The paper's claims: D-Cache/H-Cache ~7.9x, H-Cache/H-NoCache ~421x,
    D-Cache/D-NoCache ~4.6Kx, D-Cache/H-NoCache ~3.2Kx, D-NoCache ~1.7x
    slower than H-NoCache."""
    import numpy as np
    g = lambda xs: float(np.exp(np.mean(np.log(xs))))
    r = {}
    r["d_cache_vs_h_cache"] = g([v["configs"]["H-Cache"]["time"]["total"] /
                                 v["configs"]["D-Cache"]["time"]["total"]
                                 for v in results.values()])
    r["h_cache_vs_h_nocache"] = g([v["configs"]["H-NoCache"]["time"]["total"] /
                                   v["configs"]["H-Cache"]["time"]["total"]
                                   for v in results.values()])
    r["d_cache_vs_d_nocache"] = g([v["configs"]["D-NoCache"]["time"]["total"] /
                                   v["configs"]["D-Cache"]["time"]["total"]
                                   for v in results.values()])
    r["d_cache_vs_h_nocache"] = g([v["configs"]["H-NoCache"]["time"]["total"] /
                                   v["configs"]["D-Cache"]["time"]["total"]
                                   for v in results.values()])
    r["d_nocache_slowdown_vs_h"] = g(
        [v["configs"]["D-NoCache"]["time"]["total"] /
         v["configs"]["H-NoCache"]["time"]["total"] for v in results.values()])
    return r


# ---------------------------------------------------------------------------
# serving control-plane traffic (pool frontend over Ether-oN)
# ---------------------------------------------------------------------------


def control_plane_terms(ether_stats, n_tokens: int) -> Dict[str, float]:
    """Traffic terms for the pool-serving control plane.

    ``ether_stats`` is the frontend driver's ``EtherONStats`` after a
    serving run: admission/placement/free messages ride 0xE0/0xE1 frames
    (cost-accounted per operation, like Fig 3's docker-cli path), while
    the token-rate tensor traffic rides jax collectives and never shows
    up here.  The per-token figures quantify the paper's claim that the
    control plane is off the serving hot path — a few frames per
    *sequence*, amortized to noise per generated token.  On a lossy
    fabric the reliability terms price what delivery actually cost:
    retransmitted frames, checksum NACKs, dedup hits and the virtual
    time spent in retransmit backoff (all exactly zero fault-free)."""
    toks = max(int(n_tokens), 1)
    wire = ether_stats.bytes_tx + ether_stats.bytes_rx
    terms = {
        "control_frames": float(ether_stats.control_frames),
        "frames_per_1k_tokens":
            1e3 * ether_stats.control_frames / toks,
        "wire_bytes": float(wire),
        "wire_bytes_per_token": wire / toks,
        "us_total": float(ether_stats.time_us),
        "us_per_token": ether_stats.time_us / toks,
    }
    terms.update(reliability_terms(ether_stats))
    terms.update(migration_terms(ether_stats, toks))
    return terms


def migration_terms(ether_stats, n_tokens: int) -> Dict[str, float]:
    """Elastic-drain (warm-path live migration) cost terms.

    One MIGRATE frame per page moved device-to-device off a draining
    node; ``migrate_bytes`` are the moved page payloads (they ride the
    mesh, not the host fabric, but the copy cost is priced into the
    driver's virtual time).  Every term is exactly zero on a static
    pool — the elastic suite pins that, the same discipline as the
    reliability counters.  ``getattr`` keeps pre-elastic stats objects
    (or mocks) pricing as a static pool."""
    toks = max(int(n_tokens), 1)
    frames = float(getattr(ether_stats, "migrate_frames", 0))
    mbytes = float(getattr(ether_stats, "migrate_bytes", 0))
    return {
        "migrate_frames": frames,
        "migrate_frames_per_1k_tokens": 1e3 * frames / toks,
        "migrate_bytes": mbytes,
        "migrate_bytes_per_token": mbytes / toks,
    }


def reliability_terms(ether_stats) -> Dict[str, float]:
    """Delivery-reliability cost terms shared by the control- and
    data-plane breakdowns (``getattr`` so pre-reliability stats objects
    — or mocks — price as a clean fabric)."""
    backoff = float(getattr(ether_stats, "backoff_us", 0.0))
    time_us = float(getattr(ether_stats, "time_us", 0.0))
    return {
        "retransmits": float(getattr(ether_stats, "retransmits", 0)),
        "nacks": float(getattr(ether_stats, "nacks", 0)),
        "dup_frames": float(getattr(ether_stats, "dup_frames", 0)),
        "backoff_us": backoff,
        # fraction of the fabric's virtual time lost to retry waits —
        # the goodput tax the fault plan levied
        "backoff_frac": backoff / time_us if time_us > 0 else 0.0,
    }


def horizon_amortized_terms(n_tokens: int, horizon: int,
                            host_overhead_s: float,
                            device_step_s: float) -> Dict[str, float]:
    """Amortized control-plane model of the fused decode horizon.

    The per-token decode path pays one host interaction per generated
    token (page-table planning, jit dispatch, the logits/argmax
    transfer); the fused horizon pays it once per ``horizon`` tokens
    while the on-device token loop runs uninterrupted.  With
    ``host_overhead_s`` the cost of one host interaction and
    ``device_step_s`` the on-device per-token cost, generating
    ``n_tokens`` costs::

        ceil(n_tokens / horizon) * host_overhead_s
            + n_tokens * device_step_s

    — the H-fold amortization that turns control-plane cost into noise,
    the serving-side analogue of batching docker-cli ops into one
    Ether-oN frame.  The two constants are measurable from any pair of
    horizon runs (two equations, two unknowns)."""
    toks = max(int(n_tokens), 1)
    h = max(int(horizon), 1)
    interactions = -(-toks // h)
    total = interactions * host_overhead_s + toks * device_step_s
    per_token_h1 = host_overhead_s + device_step_s
    return {
        "horizon": float(h),
        "host_interactions": float(interactions),
        "interactions_per_token": interactions / toks,
        "host_s_per_token": interactions * host_overhead_s / toks,
        "modeled_tokens_per_s": toks / total,
        "modeled_speedup_vs_h1": per_token_h1 * toks / total,
    }


def prefix_chunk_terms(n_prompt: int, n_cached: int, chunk: int,
                       host_overhead_s: float,
                       token_prefill_s: float) -> Dict[str, float]:
    """Amortized admission model of the shared-prefix cache + chunked
    prefill.

    Cold admission computes every prompt token through
    ``ceil(n_prompt / chunk)`` jitted chunk calls; a warm admission
    computes only the uncached suffix (``n_prompt - n_cached`` tokens —
    the cached pages are refcount shares, zero compute and zero data
    movement, the redundancy DockerSSD's disaggregated pool exists to
    eliminate).  With ``host_overhead_s`` the cost of one host
    interaction (page planning, jit dispatch, the logits transfer) and
    ``token_prefill_s`` the per-token device cost::

        admission(n) = ceil(n / chunk) * host_overhead_s
                         + n * token_prefill_s

    The chunk term also bounds how long an admission can stall the
    in-flight decode horizons: one chunk, not one prompt — the
    admission-side analogue of the decode horizon's H-fold
    amortization."""
    prompt = max(int(n_prompt), 1)
    cached = min(max(int(n_cached), 0), prompt - 1)
    ch = max(int(chunk), 1)

    def admission_s(n):
        return -(-n // ch) * host_overhead_s + n * token_prefill_s

    cold = admission_s(prompt)
    warm = admission_s(prompt - cached)
    one_shot_stall = host_overhead_s + prompt * token_prefill_s
    return {
        "prompt_tokens": float(prompt),
        "cached_tokens": float(cached),
        "prefix_hit_rate": cached / prompt,
        "chunk": float(ch),
        "cold_admission_s": cold,
        "warm_admission_s": warm,
        "modeled_warm_speedup": cold / max(warm, 1e-12),
        "max_decode_stall_s": host_overhead_s + ch * token_prefill_s,
        "one_shot_stall_s": one_shot_stall,
        "stall_reduction": one_shot_stall /
            max(host_overhead_s + ch * token_prefill_s, 1e-12),
    }


def fit_prefill_overheads(n_a: int, chunks_a: int, t_a: float,
                          n_b: int, chunks_b: int,
                          t_b: float) -> Tuple[float, float]:
    """Solve (host_overhead_s, token_prefill_s) from two measured
    admissions: t = n_chunks * host_overhead_s + n_tokens *
    token_prefill_s (two equations, two unknowns — the prefill-side
    sibling of :func:`fit_horizon_overheads`)."""
    det = chunks_a * n_b - chunks_b * n_a
    if det == 0:
        raise ValueError("need two admissions with independent "
                         "(chunks, tokens) mixes to fit")
    host = (t_a * n_b - t_b * n_a) / det
    host = max(host, 0.0)
    tok = max((t_a - chunks_a * host) / max(n_a, 1), 0.0)
    return host, tok


def fit_horizon_overheads(h_a: int, tok_s_a: float, h_b: int,
                          tok_s_b: float) -> Tuple[float, float]:
    """Solve (host_overhead_s, device_step_s) from two measured horizon
    runs: per-token time t(H) = host_overhead_s / H + device_step_s."""
    if h_a == h_b:
        raise ValueError("need two distinct horizons to fit")
    ta, tb = 1.0 / tok_s_a, 1.0 / tok_s_b
    host = max((ta - tb) / (1.0 / h_a - 1.0 / h_b), 0.0)
    # derive dev from the CLAMPED host so the pair stays consistent
    # with the measurements even when noise inverts the two cells
    # (host clamps to 0 -> dev falls back to the faster measured rate)
    dev = min(max(ta - host / h_a, 0.0), min(ta, tb))
    return host, dev


def speculative_terms(n_tokens: int, horizon: int, alpha: float,
                      host_overhead_s: float,
                      verify_pos_s: float) -> Dict[str, float]:
    """Amortized model of speculative decoding on the fused-horizon
    scaffold (the draft-verify loop of ``spec_horizon_batch``).

    One pass drafts ``horizon - 1`` candidates and verifies them in a
    single chunk-shaped forward (``horizon`` query positions through
    one layer scan), then commits the longest accepted prefix plus the
    bonus token.  With per-candidate acceptance rate ``alpha`` the
    expected tokens per pass is the truncated geometric sum::

        E[tokens/pass] = 1 + alpha + alpha^2 + ... + alpha^(H-1)
                       = (1 - alpha^H) / (1 - alpha)

    (H at alpha=1 — every candidate lands; 1 at alpha=0 — every pass
    still nets its bonus token).  A pass costs one host interaction
    (``host_overhead_s`` — planning, dispatch, the packed transfer)
    plus ``horizon * verify_pos_s`` of device compute (every position
    runs the full stack whether accepted or not), so::

        t(n) = passes * (host_overhead_s + horizon * verify_pos_s),
        passes = ceil(n / E[tokens/pass])

    ``modeled_speedup_vs_horizon`` compares against the plain fused
    horizon at the same H (one forward per token, one host interaction
    per H tokens) — the BENCH_serve cell's baseline.  Above ~1/H
    effective acceptance the pass wins; at alpha=0 it degrades toward
    1/H, which is why ``spec_horizon_batch`` falls back to the plain
    horizon when no sequence can draft."""
    toks = max(int(n_tokens), 1)
    h = max(int(horizon), 1)
    a = min(max(float(alpha), 0.0), 1.0)
    exp_tokens = float(h) if a >= 1.0 else (1.0 - a ** h) / (1.0 - a)
    passes = -(-toks // max(exp_tokens, 1e-9))
    total = passes * (host_overhead_s + h * verify_pos_s)
    # plain fused horizon on the same budget: one forward per token,
    # one host interaction per H tokens
    plain = (-(-toks // h)) * host_overhead_s + toks * verify_pos_s
    return {
        "horizon": float(h),
        "alpha": a,
        "expected_tokens_per_pass": exp_tokens,
        "passes": float(passes),
        "modeled_tokens_per_s": toks / max(total, 1e-12),
        "modeled_speedup_vs_horizon": plain / max(total, 1e-12),
    }


def fit_speculation_overheads(h_a: int, tokens_per_pass_a: float,
                              tok_s_a: float, h_b: int,
                              tokens_per_pass_b: float,
                              tok_s_b: float) -> Tuple[float, float]:
    """Solve (host_overhead_s, verify_pos_s) from two measured
    speculative runs with different draft lengths: per-pass time
    t(H) = host_overhead_s + H * verify_pos_s, and the measured
    tokens/s gives t(H) = tokens_per_pass / tok_s (two equations, two
    unknowns — the speculation sibling of
    :func:`fit_horizon_overheads`, with the same clamping discipline
    when noise inverts the cells)."""
    if h_a == h_b:
        raise ValueError("need two distinct draft lengths to fit")
    ta = tokens_per_pass_a / tok_s_a        # measured seconds per pass
    tb = tokens_per_pass_b / tok_s_b
    pos = max((ta - tb) / float(h_a - h_b), 0.0)
    host = min(max(ta - h_a * pos, 0.0), min(ta, tb))
    return host, pos


def kv_tier_terms(tier_stats, hw: HW = HW()) -> Dict[str, float]:
    """Tier-traffic terms from a serving run's ``tier_stats()``
    aggregate: host<->HBM KV page movement, priced dtype-aware (a
    quantized page ships its codes+scales, never an inflated f32 copy —
    the counters already reflect that).  ``modeled_tier_s`` prices the
    movement at the D-Cache λFS flash path, the tier the host window
    spills to in the paper's placement."""
    moved = float(tier_stats.get(
        "kv_bytes_moved",
        tier_stats.get("bytes_in", 0) + tier_stats.get("bytes_out", 0)))
    page_bytes = float(tier_stats.get("page_bytes", 0) or 0)
    return {
        "kv_bytes_moved": moved,
        "page_bytes": page_bytes,
        "pages_moved": moved / page_bytes if page_bytes else 0.0,
        "bytes_in": float(tier_stats.get("bytes_in", 0)),
        "bytes_out": float(tier_stats.get("bytes_out", 0)),
        "modeled_tier_s": moved / hw.flash_local_bw,
    }


def data_plane_terms(ether_stats, bytes_scanned: int,
                     n_jobs: int) -> Dict[str, float]:
    """Traffic terms for the analytics data plane (ISP job offload).

    ``ether_stats`` is the driver's ``EtherONStats`` after an offload
    run: JOB submissions and RESULTS aggregates ride 0xE0/0xE1 frames,
    cost-accounted per operation exactly like Fig 3's docker-cli path.
    ``bytes_scanned`` is what the host baseline would have moved;
    ``reduction_ratio`` quantifies the paper's first headline claim —
    ship the operator to the data and only the aggregate crosses the
    wire."""
    jobs = max(int(n_jobs), 1)
    wire = ether_stats.bytes_tx + ether_stats.bytes_rx
    terms = {
        "job_frames": float(ether_stats.job_frames),
        "result_bytes": float(ether_stats.result_bytes),
        "wire_bytes": float(wire),
        "wire_bytes_per_job": wire / jobs,
        "us_total": float(ether_stats.time_us),
        "us_per_job": ether_stats.time_us / jobs,
        "reduction_ratio": bytes_scanned / max(wire, 1),
    }
    terms.update(reliability_terms(ether_stats))
    return terms


# ---------------------------------------------------------------------------
# sensitivity sweeps (Fig 13)
# ---------------------------------------------------------------------------


def seq_sensitivity(model_name: str, seq_lens=None, hw: HW = HW()):
    """D-Cache vs H-Cache speedup across sequence lengths; crossover is
    where speedup crosses 1.0."""
    m = next(x for x in POOL_LLMS if x.name == model_name)
    n = nodes_for(m)
    seq_lens = seq_lens or [64, 128, 256, 512, 1024, 2048, 4096, 8192,
                            16384, 32768, 65536, 131072]
    out = []
    for s in seq_lens:
        _, th = best_parallelism(m, n_nodes=n, seq_len=s, batch=n,
                                 cache=True, device="host", hw=hw)
        _, td = best_parallelism(m, n_nodes=n, seq_len=s, batch=n,
                                 cache=True, device="ssd", hw=hw)
        out.append({"seq_len": s, "h_cache": th["total"],
                    "d_cache": td["total"],
                    "speedup": th["total"] / td["total"]})
    return out


def crossover_point(rows) -> int:
    for r in rows:
        if r["speedup"] >= 1.0:
            return r["seq_len"]
    return -1


def batch_sensitivity(model_name: str, seq_len: int = 8192,
                      batches=(1, 4, 16, 64, 256, 512), hw: HW = HW()):
    m = next(x for x in POOL_LLMS if x.name == model_name)
    n = nodes_for(m)
    out = []
    for b in batches:
        _, th = best_parallelism(m, n_nodes=n, seq_len=seq_len, batch=b * n,
                                 cache=True, device="host", hw=hw)
        _, td = best_parallelism(m, n_nodes=n, seq_len=seq_len, batch=b * n,
                                 cache=True, device="ssd", hw=hw)
        out.append({"batch_per_node": b, "speedup": th["total"] / td["total"]})
    return out
