"""Tiered paged KV cache — the D-Cache mechanism on TPU terms.

The paper's core serving insight: the KV cache lives on storage local
to the compute (flash inside the DockerSSD) instead of behind a host
swap path.  TPU adaptation (DESIGN.md): a **page-granular KV cache**
whose hot window sits in device HBM and whose cold extent sits in the
host tier ("flash"), with asynchronous prefetch so page-in overlaps
compute.  ``repro.kernels.paged_attention`` consumes the HBM window
directly via the page table.

The accounting (hits/misses/bytes moved) feeds the analytical model's
D-Cache-vs-H-Cache comparison; the page-table management mirrors λFS
block allocation.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class KVTierStats:
    page_ins: int = 0
    page_outs: int = 0
    hits: int = 0
    misses: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    prefetch_hits: int = 0


class PagedKVCache:
    """Two-tier paged KV store for one layer group.

    HBM window: ``hbm_pages`` physical pages of shape
    [page, n_kv_heads, head_dim] (x2 for k and v).  Host tier: unbounded
    numpy storage.  Logical pages are (seq_id, page_idx).
    """

    def __init__(self, *, page_size: int, hbm_pages: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        self.page = page_size
        self.hbm_pages = hbm_pages
        self.hkv = n_kv_heads
        self.hd = head_dim
        self.dtype = dtype
        shape = (hbm_pages, page_size, n_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self._free: List[int] = list(range(hbm_pages))
        # logical -> physical, LRU-ordered
        self._resident: "OrderedDict[Tuple[int,int], int]" = OrderedDict()
        self._host: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray]] = {}
        self._lengths: Dict[int, int] = {}
        self._prefetched: set = set()
        self._pinned: set = set()
        self.stats = KVTierStats()

    # -- sequence management -------------------------------------------------

    def add_sequence(self, seq_id: int):
        self._lengths[seq_id] = 0

    def length(self, seq_id: int) -> int:
        return self._lengths[seq_id]

    def _page_bytes(self) -> int:
        return int(self.page * self.hkv * self.hd *
                   jnp.dtype(self.dtype).itemsize) * 2

    # -- page lifecycle ---------------------------------------------------------

    def _evict_one(self):
        # LRU among unpinned pages (pinned = part of an in-flight view)
        victim = None
        for lkey in self._resident:                          # LRU order
            if lkey not in self._pinned:
                victim = lkey
                break
        if victim is None:
            raise RuntimeError(
                "HBM window too small for the pinned working set "
                f"({len(self._pinned)} pages pinned, {self.hbm_pages} total)")
        phys = self._resident.pop(victim)
        k = np.asarray(self.k_pages[phys])
        v = np.asarray(self.v_pages[phys])
        self._host[victim] = (k, v)
        self._free.append(phys)
        self.stats.page_outs += 1
        self.stats.bytes_out += self._page_bytes()

    def _alloc(self, lkey) -> int:
        if not self._free:
            self._evict_one()
        phys = self._free.pop()
        self._resident[lkey] = phys
        return phys

    def _page_in(self, lkey) -> int:
        """Bring a host-tier page into HBM."""
        phys = self._alloc(lkey)
        k, v = self._host.pop(lkey)
        self.k_pages = self.k_pages.at[phys].set(jnp.asarray(k, self.dtype))
        self.v_pages = self.v_pages.at[phys].set(jnp.asarray(v, self.dtype))
        self.stats.page_ins += 1
        self.stats.bytes_in += self._page_bytes()
        return phys

    def ensure_resident(self, seq_id: int, *, pin: bool = False) -> List[int]:
        """Make every page of a sequence resident; returns physical ids in
        logical order.  With ``pin=True`` the pages are protected from
        eviction until :meth:`unpin_all` (used while assembling a batched
        kernel view so later page-ins cannot invalidate earlier entries)."""
        n_pages = -(-max(self._lengths[seq_id], 1) // self.page)
        out = []
        for pi in range(n_pages):
            lkey = (seq_id, pi)
            if lkey in self._resident:
                self._resident.move_to_end(lkey)
                if lkey in self._prefetched:
                    self.stats.prefetch_hits += 1
                    self._prefetched.discard(lkey)
                self.stats.hits += 1
            elif lkey in self._host:
                self.stats.misses += 1
                self._page_in(lkey)
            else:  # brand-new page
                self._alloc(lkey)
            if pin:
                self._pinned.add(lkey)
            out.append(self._resident[(seq_id, pi)])
        return out

    def unpin_all(self):
        self._pinned.clear()

    def prefetch(self, seq_id: int):
        """Async prefetch model: pages needed by the *next* step are pulled
        in now so the transfer overlaps compute (double buffering)."""
        n_pages = -(-(self._lengths[seq_id] + 1) // self.page)
        for pi in range(n_pages):
            lkey = (seq_id, pi)
            if lkey in self._host:
                self._page_in(lkey)
                self._prefetched.add(lkey)

    # -- writes -------------------------------------------------------------------

    def append_token(self, seq_id: int, k_tok: jnp.ndarray,
                     v_tok: jnp.ndarray):
        """k_tok/v_tok: [n_kv_heads, head_dim] for the new position."""
        pos = self._lengths[seq_id]
        pi, off = divmod(pos, self.page)
        lkey = (seq_id, pi)
        if lkey not in self._resident:
            if lkey in self._host:
                self._page_in(lkey)
            else:
                self._alloc(lkey)
        phys = self._resident[lkey]
        self._resident.move_to_end(lkey)
        self.k_pages = self.k_pages.at[phys, off].set(
            k_tok.astype(self.dtype))
        self.v_pages = self.v_pages.at[phys, off].set(
            v_tok.astype(self.dtype))
        self._lengths[seq_id] = pos + 1

    # -- read view for the kernel ---------------------------------------------------

    def kernel_view(self, seq_ids: List[int]):
        """Returns (k_pages, v_pages, page_table, lengths) ready for
        ``repro.kernels.ops.paged_attention``."""
        tables = []
        max_pages = max(-(-max(self._lengths[s], 1) // self.page)
                        for s in seq_ids)
        try:
            for s in seq_ids:
                phys = self.ensure_resident(s, pin=True)
                phys = phys + [0] * (max_pages - len(phys))
                tables.append(phys)
        finally:
            self.unpin_all()
        page_table = jnp.asarray(tables, jnp.int32)
        lengths = jnp.asarray([self._lengths[s] for s in seq_ids], jnp.int32)
        # k_pages/v_pages are immutable jnp snapshots: the returned view
        # stays valid even if later appends/evictions rewrite the window.
        return self.k_pages, self.v_pages, page_table, lengths

    # -- occupancy ---------------------------------------------------------------

    def residency(self) -> float:
        return len(self._resident) / self.hbm_pages
