"""Tiered paged KV cache — the D-Cache mechanism on TPU terms.

The paper's core serving insight: the KV cache lives on storage local
to the compute (flash inside the DockerSSD) instead of behind a host
swap path.  TPU adaptation (DESIGN.md): a **page-granular KV cache**
whose hot window sits in device HBM and whose cold extent sits in the
host tier ("flash"), with asynchronous prefetch so page-in overlaps
compute.  ``repro.kernels.paged_attention`` consumes the HBM window
directly via the page table.

The cache is split along the host/device boundary:

  * :class:`PageStore` — device-resident storage.  One *stacked* pair of
    arrays ``[n_layers, hbm_pages, page, n_kv_heads, head_dim]`` holds
    every layer's pages, so a physical page id addresses the KV of all
    layers at once and one transfer moves a whole stacked page.  The
    jitted serving step consumes/produces these arrays directly.
  * :class:`PageTableManager` — host-side policy.  Owns the logical
    (seq_id, page_idx) -> physical mapping, LRU eviction into the host
    tier, pinning, prefetch, per-tier stats, sequence lifetime
    (:meth:`PageTableManager.free_sequence`), and the **prefix page
    cache**: a per-shard content-addressed index (token-prefix digest
    -> physical page) that lets identical prompt prefixes share pages
    by refcount with copy-on-write splits before any write
    (DESIGN.md §Prefix page cache).  Runs *between* jitted steps;
    never inside them.

:class:`PagedKVCache` remains as a thin single-layer facade over the
pair for code that wants the classic per-layer append/view API.

The accounting (hits/misses/bytes moved) feeds the analytical model's
D-Cache-vs-H-Cache comparison; the page-table management mirrors λFS
block allocation.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


#: accepted values of the ``page_dtype`` knob.  ``fp32`` is shorthand
#: for "full precision at the store's compute dtype" (the default and
#: correctness baseline); ``int8``/``fp8`` store quantized codes with a
#: parallel per-slot, per-head f32 scale array.
PAGE_DTYPES = ("fp32", "int8", "fp8")

#: version tag mixed into every prefix-cache digest: bump when the
#: page layout changes so persisted/shared digests can never alias
#: across incompatible formats
PAGE_FORMAT_VERSION = 2


def _fp8_dtype():
    return getattr(jnp, "float8_e4m3fn", None)


def quantize_page_kv(x, qmax: float, code_dtype):
    """Symmetric per-slot (per-token), per-head quantization of KV.

    x: [..., D] float -> (codes [..., D] ``code_dtype``, scale [...]
    f32).  Same semantics as ``models.layers.quantize_kv`` (scale =
    amax/qmax clamped away from zero); usable inside jit — the serving
    hot path quantizes at append time, on device.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / qmax
    y = xf / scale[..., None]
    if jnp.dtype(code_dtype) == jnp.int8:
        codes = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:                                   # fp8: cast rounds, clip first
        codes = jnp.clip(y, -qmax, qmax).astype(code_dtype)
    return codes, scale


def dequantize_page_kv(codes, scale):
    """Exact inverse map: codes [..., D] x scale [...] -> f32 [..., D]."""
    return codes.astype(jnp.float32) * scale[..., None]


@dataclasses.dataclass
class KVTierStats:
    page_ins: int = 0
    page_outs: int = 0
    hits: int = 0
    misses: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    prefetch_hits: int = 0
    # prefix page cache (content-addressed sharing)
    prefix_hits: int = 0        # pages mapped by sharing, not prefill
    prefix_tokens: int = 0      # prompt tokens whose KV was never computed
    cow_splits: int = 0         # shared pages privatized before a write
    # fused-horizon / speculative partial commit: reserved pages whose
    # appends were rejected (draft mismatch, EOS, budget) and returned
    horizon_pages_rolled_back: int = 0
    # elastic drain (warm path): pages moved device-to-device off a
    # draining shard / onto a surviving one.  Exactly zero on a static
    # pool — the elastic suite pins that.
    migrated_out: int = 0
    migrated_in: int = 0


class PageStore:
    """Device-resident stacked KV pages.

    ``k_pages``/``v_pages``: [n_layers, hbm_pages, page, n_kv_heads,
    head_dim].  Layer ``li`` of physical page ``p`` is
    ``k_pages[li, p]`` — the per-layer slice a ``lax.scan`` over layers
    feeds to the Pallas paged_attention kernel.  All mutation from the
    serving hot path happens *inside* jit (batched scatters); the
    manager only moves whole stacked pages across the HBM/host boundary.

    **Quantized page format** (``page_dtype`` in {"int8", "fp8"}): the
    page arrays hold codes and a parallel per-slot, per-head scale
    array ``k_scale``/``v_scale`` [n_layers, hbm_pages, page,
    n_kv_heads] f32 travels with them through the entire page
    lifecycle — appends quantize on device at write time, CoW splits
    copy codes AND scales, host-tier spill/prefetch moves the
    quantized bytes, and attention dequantizes in-register (never a
    materialized fp32 page).  Scales are per slot rather than per page
    so decode appends never requantize already-written positions
    (DESIGN.md §Quantized page format).
    """

    def __init__(self, *, n_layers: int, page_size: int, hbm_pages: int,
                 n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                 page_dtype: str = "fp32"):
        if page_dtype not in PAGE_DTYPES:
            raise ValueError(f"page_dtype must be one of {PAGE_DTYPES}, "
                             f"got {page_dtype!r}")
        if page_dtype == "fp8" and _fp8_dtype() is None:
            raise ValueError("page_dtype='fp8' needs jnp.float8_e4m3fn "
                             "(unavailable on this jax build); use 'int8'")
        self.n_layers = n_layers
        self.page = page_size
        self.hbm_pages = hbm_pages
        self.hkv = n_kv_heads
        self.hd = head_dim
        self.dtype = dtype
        self.page_dtype = page_dtype
        self.quantized = page_dtype in ("int8", "fp8")
        if page_dtype == "int8":
            self.code_dtype, self.qmax = jnp.int8, 127.0
        elif page_dtype == "fp8":
            self.code_dtype, self.qmax = _fp8_dtype(), 448.0
        else:
            self.code_dtype, self.qmax = dtype, 0.0
        shape = (n_layers, hbm_pages, page_size, n_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, self.code_dtype)
        self.v_pages = jnp.zeros(shape, self.code_dtype)
        if self.quantized:
            sshape = (n_layers, hbm_pages, page_size, n_kv_heads)
            self.k_scale = jnp.zeros(sshape, jnp.float32)
            self.v_scale = jnp.zeros(sshape, jnp.float32)
        else:
            self.k_scale = self.v_scale = None

    @property
    def format_key(self) -> str:
        """Identity of the page layout: page dtype + the full-precision
        base dtype + format version.  Mixed into every prefix-cache
        digest so pages of one format can never alias another's."""
        return (f"kvpage:v{PAGE_FORMAT_VERSION}:{self.page_dtype}:"
                f"{jnp.dtype(self.dtype).name}")

    @staticmethod
    def stacked_page_bytes(*, n_layers: int, page_size: int,
                           n_kv_heads: int, head_dim: int,
                           dtype=jnp.bfloat16,
                           page_dtype: str = "fp32") -> int:
        """Bytes of one stacked page (k+v, all layers, scales included)
        without building a store — the capacity planner's constant for
        sizing a window from a byte budget."""
        if page_dtype == "int8":
            code = jnp.dtype(jnp.int8)
        elif page_dtype == "fp8":
            fp8 = _fp8_dtype()
            code = jnp.dtype(fp8 if fp8 is not None else jnp.int8)
        else:
            code = jnp.dtype(dtype)
        n = n_layers * page_size * n_kv_heads
        per = n * head_dim * code.itemsize
        if page_dtype in ("int8", "fp8"):
            per += n * 4                      # per-slot per-head f32 scale
        return int(per) * 2

    def page_bytes(self) -> int:
        """Bytes of one stacked page (k+v, all layers) — dtype-aware:
        quantized stores move code bytes plus scale bytes, so every
        tier/wire counter derived from this reflects quantization."""
        return self.stacked_page_bytes(
            n_layers=self.n_layers, page_size=self.page,
            n_kv_heads=self.hkv, head_dim=self.hd, dtype=self.dtype,
            page_dtype=self.page_dtype)

    # -- host/device transfers (management path, between jitted steps) ------

    def read_page(self, phys: int) -> Tuple[np.ndarray, ...]:
        """HBM -> host: one stacked page [n_layers, page, hkv, hd] x2
        (plus the scale slices when quantized — the spilled bytes ARE
        the quantized bytes; the host tier never inflates to fp32).
        The returned tuple is opaque to callers: pass it back to
        :meth:`write_page` unchanged."""
        out = [np.asarray(self.k_pages[:, phys]),
               np.asarray(self.v_pages[:, phys])]
        if self.quantized:
            out += [np.asarray(self.k_scale[:, phys]),
                    np.asarray(self.v_scale[:, phys])]
        return tuple(out)

    def write_page(self, phys: int, k: np.ndarray, v: np.ndarray,
                   k_scale: Optional[np.ndarray] = None,
                   v_scale: Optional[np.ndarray] = None):
        """Host -> HBM: restore one stacked page (codes + scales)."""
        self.k_pages = self.k_pages.at[:, phys].set(
            jnp.asarray(k, self.code_dtype))
        self.v_pages = self.v_pages.at[:, phys].set(
            jnp.asarray(v, self.code_dtype))
        if self.quantized:
            self.k_scale = self.k_scale.at[:, phys].set(
                jnp.asarray(k_scale, jnp.float32))
            self.v_scale = self.v_scale.at[:, phys].set(
                jnp.asarray(v_scale, jnp.float32))

    def device_state(self) -> Dict[str, jnp.ndarray]:
        """The store as the pytree the jitted serving steps consume and
        return: {"k", "v"} plus {"ks", "vs"} when quantized.  Every
        leaf's leading axis is layers, so a ``lax.scan`` over layers
        slices the whole state at once."""
        st = {"k": self.k_pages, "v": self.v_pages}
        if self.quantized:
            st["ks"] = self.k_scale
            st["vs"] = self.v_scale
        return st

    def place(self, sharding):
        """Lay the stacked pages out across a device mesh (pool serving:
        the pages axis sharded over ``model`` = one slice per DockerSSD
        node).  ``sharding`` is either one sharding for the page arrays
        or a dict keyed like :meth:`device_state` (required for
        quantized stores — the scale arrays shard along pages too).
        All later adopts inherit the layout from the jitted step's
        out_shardings."""
        if isinstance(sharding, dict):
            self.k_pages = jax.device_put(self.k_pages, sharding["k"])
            self.v_pages = jax.device_put(self.v_pages, sharding["v"])
            if self.quantized:
                self.k_scale = jax.device_put(self.k_scale, sharding["ks"])
                self.v_scale = jax.device_put(self.v_scale, sharding["vs"])
            return
        if self.quantized:
            raise ValueError("quantized stores need a dict sharding "
                             "covering the scale arrays")
        self.k_pages = jax.device_put(self.k_pages, sharding)
        self.v_pages = jax.device_put(self.v_pages, sharding)

    def adopt(self, state: Dict[str, jnp.ndarray]):
        """Install the (possibly donated-and-returned) state a jitted
        serving step produced."""
        self.k_pages = state["k"]
        self.v_pages = state["v"]
        if self.quantized:
            self.k_scale = state["ks"]
            self.v_scale = state["vs"]

    def is_deleted(self) -> bool:
        """Did a failed donated step consume the window arrays?"""
        return getattr(self.k_pages, "is_deleted", lambda: False)()

    def copy_page(self, src: int, dst: int):
        """Device-side stacked-page copy (the copy-on-write split: a
        sharer about to append privatizes the shared page without the
        KV ever crossing the host boundary).  Quantized pages split
        codes AND scales — a CoW'd page dequantizes identically to its
        original until the first divergent append."""
        self.k_pages = self.k_pages.at[:, dst].set(self.k_pages[:, src])
        self.v_pages = self.v_pages.at[:, dst].set(self.v_pages[:, src])
        if self.quantized:
            self.k_scale = self.k_scale.at[:, dst].set(self.k_scale[:, src])
            self.v_scale = self.v_scale.at[:, dst].set(self.v_scale[:, src])

    def write_token(self, li: int, phys, off, k_tok, v_tok):
        """Host-path single-position write (facade / eager reference):
        quantizes first when the store is quantized.  k_tok/v_tok:
        [hkv, hd] for one position of one layer."""
        if self.quantized:
            kq, ks = quantize_page_kv(k_tok, self.qmax, self.code_dtype)
            vq, vs = quantize_page_kv(v_tok, self.qmax, self.code_dtype)
            self.k_pages = self.k_pages.at[li, phys, off].set(kq)
            self.v_pages = self.v_pages.at[li, phys, off].set(vq)
            self.k_scale = self.k_scale.at[li, phys, off].set(ks)
            self.v_scale = self.v_scale.at[li, phys, off].set(vs)
            return
        self.k_pages = self.k_pages.at[li, phys, off].set(
            k_tok.astype(self.dtype))
        self.v_pages = self.v_pages.at[li, phys, off].set(
            v_tok.astype(self.dtype))

    def layer(self, li: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Per-layer view [hbm_pages, page, hkv, hd] (kernel convention)."""
        return self.k_pages[li], self.v_pages[li]

    def layer_state(self, li: int) -> Dict[str, jnp.ndarray]:
        """Per-layer slice of :meth:`device_state` (eager reference
        paths; the jitted path slices via ``lax.scan``)."""
        st = {"k": self.k_pages[li], "v": self.v_pages[li]}
        if self.quantized:
            st["ks"] = self.k_scale[li]
            st["vs"] = self.v_scale[li]
        return st


class PageTableManager:
    """Host-side page-table policy for a :class:`PageStore`.

    Logical pages are (seq_id, page_idx).  The manager decides *where*
    KV lives (HBM window vs host tier) and hands the jitted step a dense
    ``page_table`` of physical ids; it never touches KV values except to
    move whole stacked pages on eviction/page-in.

    **Pool sharding** (``n_shards > 1``): the physical window is split
    into equal contiguous ranges — shard ``s`` (one DockerSSD node of
    the storage pool) owns physical ids ``[s*pps, (s+1)*pps)`` plus its
    own host ("flash") tier.  ``shard_of(seq_id, page_idx)`` is the
    placement policy: the default stripes a sequence's logical pages
    round-robin across shards (the D-Cache sequence-sharded extent);
    ``runtime.pool.PoolServer`` substitutes per-sequence placement.
    Allocation, LRU eviction and page-in never cross a shard boundary —
    each node tiers against its own window — and every counter is kept
    twice: globally (``stats``) and per shard (``shard_stats``), so the
    pool's aggregate telemetry is exactly the sum of its nodes'.
    """

    def __init__(self, store: PageStore, *, n_shards: int = 1,
                 shard_of=None):
        self.store = store
        self.page = store.page
        self.hbm_pages = store.hbm_pages
        if store.hbm_pages % n_shards:
            raise ValueError(f"hbm_pages={store.hbm_pages} not divisible "
                             f"by n_shards={n_shards}")
        self.n_shards = n_shards
        self.pages_per_shard = store.hbm_pages // n_shards
        self.shard_of = shard_of or (lambda seq, pi: pi % n_shards)
        # per-shard free lists: shard s owns [s*pps, (s+1)*pps)
        self._free: List[List[int]] = [
            list(range(s * self.pages_per_shard,
                       (s + 1) * self.pages_per_shard))
            for s in range(n_shards)]
        self._dead_shards: set = set()
        # parked shards (elastic drain): the window is intact but the
        # node has left the serving set — allocation refuses it until a
        # re-join unparks it.  Distinct from dead: parked data survived
        # (it was migrated off), dead data is gone.
        self._parked_shards: set = set()
        # logical -> physical, LRU-ordered.  Several logical keys may map
        # to ONE physical page (prefix sharing); _rc counts the sharers.
        self._resident: "OrderedDict[Tuple[int,int], int]" = OrderedDict()
        self._rc: Dict[int, int] = {}
        # host tier: lkey -> the opaque tuple store.read_page returned
        # (codes + scales for quantized stores — spilled bytes stay
        # quantized)
        self._host: Dict[Tuple[int, int], Tuple[np.ndarray, ...]] = {}
        self._lengths: Dict[int, int] = {}
        self._prefetched: set = set()
        self._pinned: set = set()
        # prefix page cache: per-shard content-addressed index
        # digest(tokens[:end]) -> physical page whose KV covers exactly
        # that prefix's slice; _page_digest is the reverse map used to
        # invalidate entries when a page leaves HBM; _cached holds
        # registered pages no sequence references any more — they stay
        # resident as reclaimable cache (LRU order) so an identical
        # prompt later still hits warm.
        self._prefix_index: List[Dict[bytes, int]] = [
            {} for _ in range(n_shards)]
        # every digest is keyed by the store's page-format identity
        # (dtype + layout version): a server restarted with a different
        # page_dtype computes disjoint digests, so match_prefix can
        # never admit a share against pages of the wrong format
        # (blake2b keys cap at 64 bytes)
        self._format_key = store.format_key.encode()[:64]
        self._page_digest: Dict[int, bytes] = {}
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self.stats = KVTierStats()
        self.shard_stats: List[KVTierStats] = [KVTierStats()
                                               for _ in range(n_shards)]

    # -- shard helpers -------------------------------------------------------

    def shard_of_phys(self, phys: int) -> int:
        return phys // self.pages_per_shard

    def _bump(self, shard: int, field: str, n: int = 1):
        setattr(self.stats, field, getattr(self.stats, field) + n)
        ss = self.shard_stats[shard]
        setattr(ss, field, getattr(ss, field) + n)

    # -- sequence lifetime ---------------------------------------------------

    def add_sequence(self, seq_id: int):
        self._lengths[seq_id] = 0

    def length(self, seq_id: int) -> int:
        return self._lengths[seq_id]

    def set_length(self, seq_id: int, n: int):
        self._lengths[seq_id] = n

    def free_sequence(self, seq_id: int) -> int:
        """Release every page a sequence holds, in both tiers.  Returns
        the number of logical pages released; physical slots whose last
        sharer this was are immediately reusable by a waiting request
        (registered prefix pages stay resident as reclaimable cache)."""
        freed = 0
        for lkey in [k for k in list(self._resident) if k[0] == seq_id]:
            self._unmap(lkey)
            freed += 1
        for lkey in [k for k in list(self._host) if k[0] == seq_id]:
            self._host.pop(lkey)
            self._prefetched.discard(lkey)
            freed += 1
        self._lengths.pop(seq_id, None)
        return freed

    # -- capacity accounting (admission control) -----------------------------

    def pages_needed(self, n_tokens: int) -> int:
        """Pages a sequence of ``n_tokens`` occupies."""
        return -(-max(n_tokens, 1) // self.page)

    @property
    def free_pages(self) -> int:
        """Immediately-allocatable pages: the free lists plus the
        unreferenced prefix-cache pages (reclaimed on demand)."""
        return sum(len(f) for f in self._free) + len(self._cached)

    def shard_free_pages(self, shard: int) -> int:
        return len(self._free[shard]) + sum(
            1 for p in self._cached if self.shard_of_phys(p) == shard)

    @property
    def resident_pages(self) -> int:
        """Distinct physical pages some sequence maps (shared pages
        count once; unreferenced cache pages don't count)."""
        return len(self._rc)

    @property
    def cached_pages(self) -> int:
        """Registered prefix pages no sequence references — resident,
        reclaimable, waiting for a warm admission."""
        return len(self._cached)

    @property
    def host_pages(self) -> int:
        return len(self._host)

    def residency(self) -> float:
        return len(self._rc) / self.hbm_pages

    def sequences_on_shard(self, shard: int) -> set:
        """Every sequence with a page (either tier) homed on ``shard``."""
        seqs = {k[0] for k, phys in self._resident.items()
                if self.shard_of_phys(phys) == shard}
        seqs |= {k[0] for k in self._host
                 if self.shard_of(k[0], k[1]) == shard}
        return seqs

    def resident_on_shard(self, seq_id: int, shard: int):
        """[(page_idx, phys)] of a sequence's resident pages homed on
        ``shard`` — the warm-drain work list."""
        return [(k[1], phys) for k, phys in self._resident.items()
                if k[0] == seq_id and self.shard_of_phys(phys) == shard]

    def disable_shard(self, shard: int):
        """Take a shard's window out of service (node failure): nothing
        can be allocated there again, and its prefix index/cache is
        gone with the window.  The caller is responsible for freeing
        the sequences that lost pages (``sequences_on_shard``)."""
        self._dead_shards.add(shard)
        self._free[shard] = []
        for phys in [p for p in self._page_digest
                     if self.shard_of_phys(p) == shard]:
            self._invalidate(phys)
            self._cached.pop(phys, None)
        self._prefix_index[shard] = {}

    # -- elastic membership (drain / join) -----------------------------------

    def park_shard(self, shard: int):
        """Take a shard out of allocation WITHOUT losing its window (a
        planned drain, not a failure): ``_take_phys`` refuses it and the
        prefix walk skips it, but the free list survives so a later
        ``unpark_shard`` returns the window to service untouched."""
        self._parked_shards.add(shard)

    def unpark_shard(self, shard: int):
        """Return a parked shard's window to allocation (node re-join)."""
        if shard in self._dead_shards:
            raise RuntimeError(
                f"page shard {shard} is dead (node failed); a lost window "
                "cannot rejoin — its contents are gone")
        self._parked_shards.discard(shard)

    def migrate_page(self, src_phys: int, dst_shard: int) -> int:
        """Warm-path live migration of ONE physical page onto
        ``dst_shard`` via a device-side copy (``PageStore.copy_page`` —
        the bytes never cross the host boundary).  Every logical sharer
        follows the page: resident mappings remap in place (LRU order
        preserved), the refcount transfers whole, and a prefix-index
        entry re-homes under the destination shard so warm admissions
        keep hitting it.  The source slot returns to its shard's free
        list.  Returns the new physical id."""
        src_shard = self.shard_of_phys(src_phys)
        if src_shard == dst_shard:
            return src_phys
        if src_phys not in self._rc and src_phys not in self._cached:
            raise ValueError(f"page {src_phys} is not resident")
        new = self._take_phys(dst_shard)
        self.store.copy_page(src_phys, new)
        for lkey, phys in self._resident.items():            # LRU preserved
            if phys == src_phys:
                self._resident[lkey] = new
        if src_phys in self._rc:
            self._rc[new] = self._rc.pop(src_phys)
        d = self._page_digest.pop(src_phys, None)
        if d is not None:
            self._prefix_index[src_shard].pop(d, None)
            self._prefix_index[dst_shard][d] = new
            self._page_digest[new] = d
        if src_phys in self._cached:
            self._cached.pop(src_phys)
            self._cached[new] = None
        self._free[src_shard].append(src_phys)
        self._bump(src_shard, "migrated_out")
        self._bump(dst_shard, "migrated_in")
        return new

    def release_shard_cache(self, shard: int):
        """Drop the unreferenced prefix-cache pages a draining shard
        still holds: they are reclaimable by definition (no sequence
        references them), so a drain spends migration bandwidth only on
        live pages and lets warm prompts recompute later."""
        for phys in [p for p in self._cached
                     if self.shard_of_phys(p) == shard]:
            self._cached.pop(phys)
            self._invalidate(phys)
            self._free[shard].append(phys)

    # -- page lifecycle ------------------------------------------------------

    def _map(self, lkey, phys: int):
        """Bind a logical page to a physical one (refcounted; a cached
        page being re-referenced leaves the reclaim list)."""
        self._resident[lkey] = phys
        self._rc[phys] = self._rc.get(phys, 0) + 1
        self._cached.pop(phys, None)

    def _unmap(self, lkey):
        """Release one logical page.  The physical slot is returned when
        the last sharer leaves — to the prefix cache if the page is
        registered (still warm for identical prompts), else to the
        shard's free list."""
        phys = self._resident.pop(lkey)
        self._pinned.discard(lkey)
        self._prefetched.discard(lkey)
        rc = self._rc[phys] - 1
        if rc > 0:
            self._rc[phys] = rc
            return
        del self._rc[phys]
        if phys in self._page_digest:
            self._cached[phys] = None
        else:
            self._free[self.shard_of_phys(phys)].append(phys)

    def _invalidate(self, phys: int):
        """Drop a page's prefix-index entry (the page is leaving HBM or
        being reclaimed; the index only ever points at window pages)."""
        d = self._page_digest.pop(phys, None)
        if d is not None:
            self._prefix_index[self.shard_of_phys(phys)].pop(d, None)

    def _evict_one(self, shard: int):
        # LRU among the shard's unpinned, UNSHARED pages (pinned =
        # in-flight step; shared = prefix pages other sequences still
        # read — eviction refuses those until every sharer releases);
        # tiering never crosses a node boundary — each DockerSSD spills
        # to its own flash
        victim = None
        for lkey, phys in self._resident.items():            # LRU order
            if lkey not in self._pinned and self._rc[phys] == 1 and \
                    self.shard_of_phys(phys) == shard:
                victim = lkey
                break
        if victim is None:
            raise RuntimeError(
                "HBM window too small for the pinned working set "
                f"(shard {shard}: {len(self._pinned)} pages pinned, "
                "shared prefix pages are not evictable, "
                f"{self.pages_per_shard} per shard)")
        phys = self._resident.pop(victim)
        self._pinned.discard(victim)
        del self._rc[phys]
        self._invalidate(phys)
        self._host[victim] = self.store.read_page(phys)
        self._free[shard].append(phys)
        self._bump(shard, "page_outs")
        self._bump(shard, "bytes_out", self.store.page_bytes())

    def _take_phys(self, shard: int) -> int:
        """Claim one physical slot on ``shard``: free list first, then
        reclaim the LRU unreferenced cache page, then evict."""
        if shard in self._dead_shards:
            raise RuntimeError(f"page shard {shard} is dead (node failed)")
        if shard in self._parked_shards:
            raise RuntimeError(
                f"page shard {shard} is parked (node drained); "
                "unpark_shard re-joins it")
        if self._free[shard]:
            return self._free[shard].pop()
        for phys in self._cached:                            # LRU order
            if self.shard_of_phys(phys) == shard:
                self._cached.pop(phys)
                self._invalidate(phys)
                return phys
        self._evict_one(shard)
        return self._free[shard].pop()

    def _alloc(self, lkey) -> int:
        phys = self._take_phys(self.shard_of(lkey[0], lkey[1]))
        self._map(lkey, phys)
        return phys

    def _page_in(self, lkey) -> int:
        """Bring a host-tier page into HBM."""
        phys = self._alloc(lkey)
        self.store.write_page(phys, *self._host.pop(lkey))
        shard = self.shard_of_phys(phys)
        self._bump(shard, "page_ins")
        self._bump(shard, "bytes_in", self.store.page_bytes())
        return phys

    # -- prefix page cache (content-addressed sharing + CoW) -----------------

    def _hasher(self):
        """Fresh format-keyed hasher: the page format (dtype + layout
        version) participates in every content address, so fp32 and
        int8 pages of identical tokens never share a digest."""
        return hashlib.blake2b(digest_size=16, key=self._format_key)

    def _digest(self, toks: np.ndarray) -> bytes:
        """Content address of a token prefix: one digest identifies the
        KV of every position it covers (params/config are fixed per
        server, so token identity implies KV identity; the format key
        scopes it to this store's page layout)."""
        h = self._hasher()
        h.update(toks.tobytes())
        return h.digest()

    @staticmethod
    def _probe_page(idx: Dict[bytes, int], toks: np.ndarray,
                    lo: int, hi: int, hasher):
        """Longest indexed prefix of ``toks`` ending inside (lo, hi].
        ``hasher`` already covers ``toks[:lo]`` — each candidate end
        forks it and hashes only the page's own tokens, so a whole
        prefix walk costs O(len * page) bytes, not O(len^2)."""
        for end in range(hi, lo, -1):
            hh = hasher.copy()
            hh.update(toks[lo:end].tobytes())
            phys = idx.get(hh.digest())
            if phys is not None:
                return end, phys
        return None

    def _walk_prefix(self, toks: np.ndarray, shard_for, on_hit=None) -> int:
        """Walk the prefix chain page by page.  The returned coverage is
        capped at len-1 — admission always computes at least the final
        token's logits — but the *probe* runs to the full prompt length,
        so an identical prompt shares its tail page too (the recomputed
        final token CoWs into a copy).  A partial-page hit ends the
        chain (positions after it belong to this sequence alone)."""
        cap = int(toks.shape[0]) - 1
        n, pi = 0, 0
        hasher = self._hasher()                    # covers toks[:n]
        while n < cap:
            shard = shard_for(pi)
            if shard in self._dead_shards or shard in self._parked_shards:
                break
            got = self._probe_page(self._prefix_index[shard], toks,
                                   n, min(n + self.page,
                                          int(toks.shape[0])), hasher)
            if got is None:
                break
            end, phys = got
            if on_hit is not None:
                on_hit(pi, shard, min(end, cap) - n, phys)
            hasher.update(toks[n:end].tobytes())
            n = end
            pi += 1
            if end % self.page or end >= cap:
                break
        return min(n, cap)

    def match_prefix(self, seq_id: int, tokens) -> int:
        """Map the longest indexed prefix of a prompt into ``seq_id``'s
        page table: each hit is a refcount++ on an already-resident page
        — zero prefill compute for the covered tokens.  Sets the
        sequence length to the covered count and returns it."""
        toks = np.asarray(tokens, np.int32)

        def on_hit(pi, shard, n_toks, phys):
            self._map((seq_id, pi), phys)
            self._bump(shard, "prefix_hits")
            self._bump(shard, "prefix_tokens", n_toks)

        n = self._walk_prefix(toks, lambda pi: self.shard_of(seq_id, pi),
                              on_hit)
        self._lengths[seq_id] = n
        return n

    def probe_prefix(self, seq_id: int, tokens) -> int:
        """How many tokens :meth:`match_prefix` would cover right now,
        without mapping anything (admission telemetry / routing)."""
        return self._walk_prefix(np.asarray(tokens, np.int32),
                                 lambda pi: self.shard_of(seq_id, pi))

    def prefix_tokens_on_shard(self, tokens, shard: int) -> int:
        """Tokens of ``tokens`` shard ``shard``'s index could serve if
        the sequence were placed entirely there — the routing signal
        for placement policies (admit where the prefix already lives)."""
        return self._walk_prefix(np.asarray(tokens, np.int32),
                                 lambda pi: shard)

    def register_prefix(self, seq_id: int, tokens):
        """Index the prompt pages a finished prefill wrote, full pages
        under their chain digest plus the partial tail (later decode
        appends land at offsets past the digest's coverage, so entries
        stay valid until the page leaves HBM)."""
        toks = np.asarray(tokens, np.int32)
        s = int(toks.shape[0])
        for pi in range(self.pages_needed(s)):
            phys = self._resident.get((seq_id, pi))
            if phys is None or phys in self._page_digest:
                continue                  # spilled, or already indexed
            d = self._digest(toks[:min((pi + 1) * self.page, s)])
            shard = self.shard_of_phys(phys)
            if d in self._prefix_index[shard]:
                continue                  # identical content indexed
            self._prefix_index[shard][d] = phys
            self._page_digest[phys] = d

    def clear_prefix_cache(self):
        """Forget every registered prefix: index entries dropped,
        unreferenced cache pages returned to their free lists.  Mapped
        pages stay with their sharers — they just stop being
        discoverable (bench/test isolation knob)."""
        for phys in list(self._page_digest):
            self._invalidate(phys)
        for phys in list(self._cached):
            self._cached.pop(phys)
            self._free[self.shard_of_phys(phys)].append(phys)

    def make_writable(self, seq_id: int, page_idx: int) -> int:
        """Copy-on-write split: before any append lands in a shared
        page, this sharer gets a private device-side copy (the shared
        original keeps its index entry and remaining sharers).  No-op
        on exclusively-held pages.  Returns the writable physical id."""
        lkey = (seq_id, page_idx)
        phys = self._resident[lkey]
        if self._rc[phys] == 1:
            return phys
        shard = self.shard_of(seq_id, page_idx)
        new = self._take_phys(shard)
        self.store.copy_page(phys, new)
        self._rc[phys] -= 1
        self._rc[new] = 1
        self._resident[lkey] = new
        self._bump(shard, "cow_splits")
        return new

    def _writable_tail(self, seq_id: int):
        """Appends land mid-page when the committed length is not
        page-aligned — CoW that tail page if it is shared."""
        n = self._lengths[seq_id]
        if n % self.page:
            self.make_writable(seq_id, n // self.page)

    def row(self, seq_id: int, n_pages: int) -> List[int]:
        """The sequence's current physical page row (CoW-fresh), in
        logical order — what a jitted step's page table must carry
        after any make_writable splits remapped pages."""
        return [self._resident[(seq_id, pi)] for pi in range(n_pages)]

    def ensure_page(self, seq_id: int, page_idx: int, *, pin: bool = False,
                    count: bool = True) -> int:
        """Make one logical page resident; returns its physical id.
        ``count=False`` skips the hit/miss accounting (write-path touches
        — the facade's per-token appends — are not cache lookups; only
        view assembly and explicit residency checks are)."""
        lkey = (seq_id, page_idx)
        if lkey in self._resident:
            self._resident.move_to_end(lkey)
            if count:
                shard = self.shard_of_phys(self._resident[lkey])
                if lkey in self._prefetched:
                    self._bump(shard, "prefetch_hits")
                    self._prefetched.discard(lkey)
                self._bump(shard, "hits")
        elif lkey in self._host:
            if count:
                self._bump(self.shard_of(seq_id, page_idx), "misses")
            self._page_in(lkey)
        else:  # brand-new page
            self._alloc(lkey)
        if pin:
            self._pinned.add(lkey)
        return self._resident[lkey]

    def ensure_resident(self, seq_id: int, *, pin: bool = False,
                        n_tokens: Optional[int] = None) -> List[int]:
        """Make every page covering ``n_tokens`` (default: the current
        length) resident; returns physical ids in logical order.  With
        ``pin=True`` the pages are protected from eviction until
        :meth:`unpin_all` (used while assembling a batched step so later
        page-ins cannot invalidate earlier entries)."""
        if n_tokens is None:
            n_tokens = self._lengths[seq_id]
        return [self.ensure_page(seq_id, pi, pin=pin)
                for pi in range(self.pages_needed(n_tokens))]

    def prepare_append(self, seq_id: int) -> List[int]:
        """Pin + return the page-table row for appending one token: every
        page covering positions [0, length] resident, in logical order,
        the tail page CoW-split if shared (the append writes into it).
        Commit the append with :meth:`commit_append` after the step."""
        rows = self.ensure_resident(seq_id, pin=True,
                                    n_tokens=self._lengths[seq_id] + 1)
        self._writable_tail(seq_id)
        return self.row(seq_id, len(rows))

    def commit_append(self, seq_id: int, n: int = 1):
        self._lengths[seq_id] += n

    # -- horizon reservation (fused multi-token decode) ----------------------

    def reserve_horizon(self, seq_id: int, horizon: int) -> List[int]:
        """Pin + return the page-table row for appending up to ``horizon``
        tokens on device: every page covering positions
        [0, length + horizon) resident and pinned, in logical order.

        The fused decode loop advances page slots *on device* against
        this reservation — the host is not consulted between the
        horizon's steps.  Reserved-but-unused pages (a sequence that hit
        EOS or its budget mid-horizon) are rolled back by
        :meth:`commit_horizon`; they hold no data, so the rollback is a
        pure free-list return."""
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        rows = self.ensure_resident(seq_id, pin=True,
                                    n_tokens=self._lengths[seq_id] + horizon)
        # the horizon's first append may land mid-page in a shared
        # prefix page: split it now, on the host, before the device loop
        self._writable_tail(seq_id)
        return self.row(seq_id, len(rows))

    def commit_horizon(self, seq_id: int, n_committed: int) -> int:
        """Commit ``n_committed`` appended tokens and roll back the rest
        of the horizon reservation: reserved pages wholly past the new
        length return to their shard's free list.  Returns the number of
        pages rolled back."""
        self._lengths[seq_id] += n_committed
        used = self.pages_needed(self._lengths[seq_id])
        rolled = 0
        for lkey in [k for k in self._resident
                     if k[0] == seq_id and k[1] >= used]:
            shard = self.shard_of(lkey[0], lkey[1])
            self._unmap(lkey)
            self._bump(shard, "horizon_pages_rolled_back")
            rolled += 1
        return rolled

    def unpin_all(self):
        self._pinned.clear()

    def prefetch(self, seq_id: int):
        """Async prefetch model: pages needed by the *next* step are pulled
        in now so the transfer overlaps compute (double buffering)."""
        n_pages = self.pages_needed(self._lengths[seq_id] + 1)
        for pi in range(n_pages):
            lkey = (seq_id, pi)
            if lkey in self._host:
                self._page_in(lkey)
                self._prefetched.add(lkey)


class PagedKVCache:
    """Single-layer-group facade over PageTableManager + PageStore.

    Keeps the classic per-layer API (``append_token`` one position at a
    time, ``kernel_view`` snapshots) for tests and tools; the serving
    hot path uses the manager/store pair directly with stacked layers
    and batched in-jit scatters.
    """

    def __init__(self, *, page_size: int, hbm_pages: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16):
        # single layer group by construction — multi-layer callers use the
        # manager/store pair directly (see PagedServer)
        self.store = PageStore(n_layers=1, page_size=page_size,
                               hbm_pages=hbm_pages, n_kv_heads=n_kv_heads,
                               head_dim=head_dim, dtype=dtype)
        self.table = PageTableManager(self.store)
        self.page = page_size
        self.hbm_pages = hbm_pages
        self.dtype = dtype

    @property
    def stats(self) -> KVTierStats:
        return self.table.stats

    # -- sequence management -------------------------------------------------

    def add_sequence(self, seq_id: int):
        self.table.add_sequence(seq_id)

    def length(self, seq_id: int) -> int:
        return self.table.length(seq_id)

    def free_sequence(self, seq_id: int) -> int:
        return self.table.free_sequence(seq_id)

    # -- writes --------------------------------------------------------------

    def append_token(self, seq_id: int, k_tok: jnp.ndarray,
                     v_tok: jnp.ndarray):
        """k_tok/v_tok: [n_kv_heads, head_dim] for the new position."""
        pos = self.table.length(seq_id)
        off = pos % self.page
        self.table.ensure_page(seq_id, pos // self.page, count=False)
        # same invariant as every other write path: never write into a
        # shared physical page — split it first
        phys = self.table.make_writable(seq_id, pos // self.page)
        self.store.write_token(0, phys, off, k_tok, v_tok)
        self.table.commit_append(seq_id)

    # -- read view for the kernel --------------------------------------------

    def ensure_resident(self, seq_id: int, *, pin: bool = False) -> List[int]:
        return self.table.ensure_resident(seq_id, pin=pin)

    def prefetch(self, seq_id: int):
        self.table.prefetch(seq_id)

    def unpin_all(self):
        self.table.unpin_all()

    def kernel_view(self, seq_ids: List[int]):
        """Returns (k_pages, v_pages, page_table, lengths) ready for
        ``repro.kernels.ops.paged_attention``."""
        tables = []
        max_pages = max(self.table.pages_needed(self.table.length(s))
                        for s in seq_ids)
        try:
            for s in seq_ids:
                phys = self.table.ensure_resident(s, pin=True)
                phys = phys + [0] * (max_pages - len(phys))
                tables.append(phys)
        finally:
            self.table.unpin_all()
        page_table = jnp.asarray(tables, jnp.int32)
        lengths = jnp.asarray([self.table.length(s) for s in seq_ids],
                              jnp.int32)
        # k_pages/v_pages are immutable jnp snapshots: the returned view
        # stays valid even if later appends/evictions rewrite the window.
        k_pages, v_pages = self.store.layer(0)
        return k_pages, v_pages, page_table, lengths

    # -- occupancy -----------------------------------------------------------

    def residency(self) -> float:
        return self.table.residency()
