"""Mamba2 (SSD) blocks + the Zamba2 hybrid LM (Mamba2 backbone with a
*shared*, weight-tied attention block applied every ``attn_every``
layers — arXiv:2411.15242).

The SSD scan uses the chunked parallel form with scalar per-head decay;
every exponent is a difference of cumulative log-decays (<= 0, f32-safe).
Decode is O(1)-state recurrent, so zamba2 runs ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

D_CONV = 4


# ---------------------------------------------------------------------------
# SSD chunked scan (scalar decay per head)
# ---------------------------------------------------------------------------


def ssd_chunk(cb, x, dt, da, h0):
    """One chunk, one (batch, head).

    cb: tuple (C, B) each [Ck, ds]; x: [Ck, dh]; dt: [Ck]; da: [Ck] (<=0);
    h0: [dh, ds].  Returns (y [Ck, dh], hC).
    """
    Cm, Bm = cb
    ck = x.shape[0]
    cum = jnp.cumsum(da)                                  # [Ck]
    decay = cum[:, None] - cum[None, :]                   # t, s
    mask = jnp.tril(jnp.ones((ck, ck), bool))
    # mask BEFORE exp: exp of (positive) upper-triangle entries would
    # overflow and poison gradients via inf * 0
    dmat = jnp.exp(jnp.where(mask, decay, -jnp.inf))      # [t, s]
    scores = (Cm @ Bm.T) * dmat                           # [t, s]
    xin = x * dt[:, None]                                 # [Ck, dh]
    y = scores @ xin                                      # [Ck, dh]
    # initial state contribution
    y = y + jnp.exp(cum)[:, None] * (Cm @ h0.T)           # [Ck, dh]
    # state update
    w = jnp.exp(cum[-1] - cum)                            # [Ck]
    hC = jnp.exp(cum[-1]) * h0 + jnp.einsum("c,cd,cs->ds", w, xin, Bm)
    return y, hC


def ssd_chunked(x, dt, da, Bm, Cm, h0, chunk: int = 64,
                unroll: bool = False):
    """x: [B,S,H,dh]; dt/da: [B,S,H]; Bm/Cm: [B,S,ds]; h0: [B,H,dh,ds].
    Returns (y [B,S,H,dh], hT)."""
    b, s, h, dh = x.shape
    ds = Bm.shape[-1]
    ck = min(chunk, s)
    assert s % ck == 0
    n = s // ck

    xs_x = jnp.moveaxis(x.reshape(b, n, ck, h, dh), (1, 3), (0, 2))   # [N,B,H,Ck,dh]
    xs_dt = jnp.moveaxis(dt.reshape(b, n, ck, h), (1, 3), (0, 2))     # [N,B,H,Ck]
    xs_da = jnp.moveaxis(da.reshape(b, n, ck, h), (1, 3), (0, 2))
    xs_B = jnp.moveaxis(Bm.reshape(b, n, ck, ds), 1, 0)               # [N,B,Ck,ds]
    xs_C = jnp.moveaxis(Cm.reshape(b, n, ck, ds), 1, 0)

    # vmap over batch then head (B/C shared across heads)
    f = jax.vmap(ssd_chunk, in_axes=((None, None), 0, 0, 0, 0))       # heads
    f = jax.vmap(f, in_axes=((0, 0), 0, 0, 0, 0))                     # batch

    def body(state, xs):
        xc, dtc, dac, bc, cc = xs
        y, state = f((cc, bc), xc, dtc, dac, state)
        return state, y

    hT, ys = lax.scan(body, h0, (xs_x, xs_dt, xs_da, xs_B, xs_C),
                      unroll=unroll)
    y = jnp.moveaxis(ys, (0, 2), (1, 3)).reshape(b, s, h, dh)
    return y, hT


def ssd_step(x, dt, da, Bm, Cm, state):
    """One token.  x: [B,H,dh]; dt/da: [B,H]; Bm/Cm: [B,ds];
    state: [B,H,dh,ds]."""
    xin = x * dt[..., None]                                # [B,H,dh]
    new = jnp.exp(da)[..., None, None] * state + \
        xin[..., :, None] * Bm[:, None, None, :]
    y = jnp.einsum("bhds,bs->bhd", new, Cm)
    return y, new


def ssd_ref(x, dt, da, Bm, Cm, h0):
    """Naive per-token oracle."""
    def body(state, xs):
        xt, dtt, dat, bt, ct = xs
        y, state = ssd_step(xt, dtt, dat, bt, ct, state)
        return state, y
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (x, dt, da, Bm, Cm))
    hT, ys = lax.scan(body, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    d_proj = 2 * d_inner + 2 * cfg.ssm_state + n_heads
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], (d, d_proj), dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (D_CONV, conv_dim))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, n_heads)).astype(dtype),
        "d_skip": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "gate_norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": L.dense_init(ks[2], (d_inner, d), dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, n_heads, _ = mamba2_dims(cfg)
    ds = cfg.ssm_state
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, bias):
    """Depthwise causal conv over seq.  xBC: [B,S,C]; w: [D_CONV, C]."""
    pad = jnp.pad(xBC, ((0, 0), (D_CONV - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(D_CONV))
    return jax.nn.silu(out + bias[None, None, :])


def apply_mamba2_seq(p, x, cfg, conv_state, ssm_state, chunk=64,
                     unroll=False):
    """x: [B,S,d].  Returns (out, new_conv_state, new_ssm_state)."""
    b, s, d = x.shape
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    ds, dh = cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # prepend carried conv inputs (for prefill continuity)
    full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    xBC_conv = _causal_conv(full, p["conv_w"].astype(x.dtype),
                            p["conv_b"].astype(x.dtype))[:, D_CONV - 1:]
    new_conv_state = full[:, -(D_CONV - 1):]
    xs, Bm, Cm = jnp.split(xBC_conv, [d_inner, d_inner + ds], axis=-1)
    xs = xs.reshape(b, s, n_heads, dh)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))      # [B,S,H]
    da = -jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :] * dt
    y, hT = ssd_chunked(xs.astype(jnp.float32), dt, da,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                        ssm_state, chunk=chunk, unroll=unroll)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = L.apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"].astype(x.dtype), new_conv_state, hT


def apply_mamba2_step(p, x, cfg, conv_state, ssm_state):
    """x: [B,d] one token.  conv_state: [B, D_CONV-1, conv_dim]."""
    b, d = x.shape
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    ds, dh = cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC[:, None, :]],
                             axis=1)                             # [B,D_CONV,C]
    conv = jnp.sum(window * p["conv_w"].astype(x.dtype)[None], axis=1)
    xBC_c = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))
    new_conv_state = window[:, 1:]
    xs, Bm, Cm = jnp.split(xBC_c, [d_inner, d_inner + ds], axis=-1)
    xs = xs.reshape(b, n_heads, dh).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = -jnp.exp(p["a_log"].astype(jnp.float32))[None, :] * dt
    y, new_ssm = ssd_step(xs, dt, da, Bm.astype(jnp.float32),
                          Cm.astype(jnp.float32), ssm_state)
    y = y + xs * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = L.apply_norm(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"].astype(x.dtype), new_conv_state, new_ssm


# ---------------------------------------------------------------------------
# Zamba2 hybrid LM
# ---------------------------------------------------------------------------


class Zamba2LM:
    """Mamba2 backbone; ONE shared attention+MLP block applied before every
    ``attn_every``-th mamba layer (weight-tied across its applications,
    each application keeping its own KV cache)."""

    def __init__(self, cfg, compute_dtype=jnp.bfloat16, chunk: int = 64,
                 remat: str = "full", loss_chunk: int = 256,
                 q_chunk: int = 1024, unroll_inner: bool = False):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.chunk = chunk
        self.remat = remat
        self.q_chunk = q_chunk
        self.unroll = unroll_inner
        self.groups = []
        i = 0
        while i < cfg.n_layers:
            self.groups.append((i, min(i + cfg.attn_every, cfg.n_layers)))
            i += cfg.attn_every
        self.n_attn = len(self.groups)

    def init(self, rng, dtype=jnp.float32) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)

        def init_layer(key):
            k1, k2 = jax.random.split(key)
            return {"norm": L.init_norm(k1, cfg.d_model, "rmsnorm", dtype),
                    "mamba": init_mamba2(k2, cfg, dtype)}

        shared = {
            "attn_norm": L.init_norm(ks[1], cfg.d_model, "rmsnorm", dtype),
            "attn": L.init_attention(ks[2], cfg, dtype),
            "mlp_norm": L.init_norm(ks[1], cfg.d_model, "rmsnorm", dtype),
            "mlp": L.init_mlp(ks[3], cfg, dtype),
        }
        return {
            "embed": L.init_embed(ks[4], cfg, dtype),
            "shared_attn": shared,
            "layers": jax.vmap(init_layer)(layer_keys),
            "final_norm": L.init_norm(ks[1], cfg.d_model, "rmsnorm", dtype),
            "lm_head": {"w": L.dense_init(ks[5], (cfg.d_model, cfg.vocab_size),
                                          dtype=dtype)},
        }

    # -- shared attention block ----------------------------------------------

    def _shared_attn_seq(self, sp, h, positions, cache_dtype):
        cfg = self.cfg
        b, s, _ = h.shape
        a = L.apply_norm(sp["attn_norm"], h, "rmsnorm")
        q, k, v = L._qkv(sp["attn"], a, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.chunked_attention(q, k, v, causal=True, q_chunk=self.q_chunk,
                                positions_q=positions, positions_k=positions,
                                unroll=self.unroll)
        h = h + o.reshape(b, s, -1) @ sp["attn"]["wo"].astype(h.dtype)
        m = L.apply_norm(sp["mlp_norm"], h, "rmsnorm")
        h = h + L.apply_mlp(sp["mlp"], m, cfg.act)
        kc = jnp.swapaxes(k, 1, 2).astype(cache_dtype)
        vc = jnp.swapaxes(v, 1, 2).astype(cache_dtype)
        return h, (kc, vc)

    def _shared_attn_step(self, sp, h, kc, vc, index):
        cfg = self.cfg
        a = L.apply_norm(sp["attn_norm"], h, "rmsnorm")
        o, kc, vc = L.decode_attention(sp["attn"], a, cfg, kc, vc, index)
        h = h + o
        m = L.apply_norm(sp["mlp_norm"], h, "rmsnorm")
        h = h + L.apply_mlp(sp["mlp"], m, cfg.act)
        return h, kc, vc

    # -- full forward ----------------------------------------------------------

    def _run(self, params, h, state, cache_dtype=jnp.bfloat16):
        """Sequence forward; returns (h, new_state)."""
        cfg = self.cfg
        b, s, _ = h.shape
        start = state["index"]
        positions = (start + jnp.arange(s, dtype=jnp.int32))[None, :].repeat(b, 0)
        kcs, vcs, convs, ssms = [], [], [], []
        mamba_fn = lambda hh, lp, cs, ss: self._mamba_layer(hh, lp, cs, ss)
        if self.remat != "none":
            mamba_fn = jax.checkpoint(mamba_fn)
        for g, (lo, hi) in enumerate(self.groups):
            h, (kc, vc) = self._shared_attn_seq(params["shared_attn"], h,
                                                positions, cache_dtype)
            kcs.append(kc)
            vcs.append(vc)
            sub = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            cs0 = state["conv"][lo:hi]
            ss0 = state["ssm"][lo:hi]

            def body(hh, xs):
                lp, cs, ss = xs
                return mamba_fn(hh, lp, cs, ss)

            h, (ncs, nss) = lax.scan(body, h, (sub, cs0, ss0),
                                     unroll=self.unroll)
            convs.append(ncs)
            ssms.append(nss)
        h = L.apply_norm(params["final_norm"], h, "rmsnorm")
        d_inner, n_heads, conv_dim = mamba2_dims(cfg)
        new_state = {
            "k": (jnp.stack(kcs) if kcs else
                  jnp.zeros((0, b, cfg.n_kv_heads, s, cfg.hd), cache_dtype)),
            "v": (jnp.stack(vcs) if vcs else
                  jnp.zeros((0, b, cfg.n_kv_heads, s, cfg.hd), cache_dtype)),
            "conv": (jnp.concatenate(convs, axis=0) if convs else
                     state["conv"]),
            "ssm": (jnp.concatenate(ssms, axis=0) if ssms else state["ssm"]),
            "index": start + s,
        }
        return h, new_state

    def _mamba_layer(self, h, lp, conv_state, ssm_state):
        a = L.apply_norm(lp["norm"], h, "rmsnorm")
        o, ncs, nss = apply_mamba2_seq(lp["mamba"], a, self.cfg, conv_state,
                                       ssm_state, chunk=self.chunk,
                                       unroll=self.unroll)
        return h + o, (ncs, nss)

    def _state0(self, b, seq_hint: int = 0, cache_dtype=jnp.bfloat16):
        cfg = self.cfg
        d_inner, n_heads, conv_dim = mamba2_dims(cfg)
        s = max(seq_hint, 1)
        return {
            "k": jnp.zeros((self.n_attn, b, cfg.n_kv_heads, s, cfg.hd),
                           cache_dtype),
            "v": jnp.zeros((self.n_attn, b, cfg.n_kv_heads, s, cfg.hd),
                           cache_dtype),
            "conv": jnp.zeros((cfg.n_layers, b, D_CONV - 1, conv_dim),
                              jnp.float32),
            "ssm": jnp.zeros((cfg.n_layers, b, n_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "index": jnp.zeros((), jnp.int32),
        }

    def forward(self, params, batch):
        h = L.embed_tokens(params["embed"], batch["tokens"], self.compute_dtype)
        state = self._state0(h.shape[0], h.shape[1], self.compute_dtype)
        h, _ = self._run(params, h, state)
        logits = (h @ params["lm_head"]["w"].astype(h.dtype)).astype(jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    # -- serving ---------------------------------------------------------------

    def cache_spec(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        d_inner, n_heads, conv_dim = mamba2_dims(cfg)
        return {
            "k": jax.ShapeDtypeStruct(
                (self.n_attn, batch, cfg.n_kv_heads, seq, cfg.hd), dtype),
            "v": jax.ShapeDtypeStruct(
                (self.n_attn, batch, cfg.n_kv_heads, seq, cfg.hd), dtype),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, D_CONV - 1, conv_dim), jnp.float32),
            "ssm": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, n_heads, cfg.ssm_head_dim,
                 cfg.ssm_state), jnp.float32),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        spec = self.cache_spec(batch, seq, dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def prefill(self, params, batch, cache_dtype=jnp.bfloat16):
        h = L.embed_tokens(params["embed"], batch["tokens"], self.compute_dtype)
        state = self._state0(h.shape[0], h.shape[1], cache_dtype)
        h, state = self._run(params, h, state, cache_dtype)
        logits = (h[:, -1] @ params["lm_head"]["w"].astype(h.dtype)).astype(
            jnp.float32)
        return logits, state

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        index = cache["index"]
        h = L.embed_tokens(params["embed"], tokens[:, None],
                           self.compute_dtype)                    # [B,1,d]
        kcs, vcs, convs, ssms = [], [], [], []
        for g, (lo, hi) in enumerate(self.groups):
            h, kc, vc = self._shared_attn_step(
                params["shared_attn"], h, cache["k"][g], cache["v"][g], index)
            kcs.append(kc)
            vcs.append(vc)
            sub = jax.tree.map(lambda a: a[lo:hi], params["layers"])

            def body(hh, xs):
                lp, cs, ss = xs
                a = L.apply_norm(lp["norm"], hh, "rmsnorm")
                o, ncs, nss = apply_mamba2_step(lp["mamba"], a[:, 0], cfg,
                                                cs, ss)
                return hh + o[:, None, :], (ncs, nss)

            h, (ncs, nss) = lax.scan(
                body, h, (sub, cache["conv"][lo:hi], cache["ssm"][lo:hi]),
                unroll=self.unroll)
            convs.append(ncs)
            ssms.append(nss)
        h = L.apply_norm(params["final_norm"], h, "rmsnorm")
        logits = (h[:, 0] @ params["lm_head"]["w"].astype(h.dtype)).astype(
            jnp.float32)
        new_cache = {
            "k": jnp.stack(kcs) if kcs else cache["k"],
            "v": jnp.stack(vcs) if vcs else cache["v"],
            "conv": jnp.concatenate(convs, axis=0) if convs else cache["conv"],
            "ssm": jnp.concatenate(ssms, axis=0) if ssms else cache["ssm"],
            "index": index + 1,
        }
        return logits, new_cache
