"""Uniform Model interface over the architecture zoo.

``get_model(cfg)`` returns a ``Model`` exposing:
  init / loss / forward / prefill / decode_step / cache_spec / init_cache /
  input_specs(shape) — the ShapeDtypeStruct stand-ins used by the multi-pod
  dry-run (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import frontends
from repro.models.mamba2 import Zamba2LM
from repro.models.rwkv6 import RWKV6LM
from repro.models.transformer import TransformerLM


class Model:
    """Thin uniform facade; ``impl`` is the family-specific module."""

    def __init__(self, cfg: ArchConfig, impl):
        self.cfg = cfg
        self.impl = impl

    # delegate the functional API
    def init(self, rng, dtype=jnp.float32):
        return self.impl.init(rng, dtype)

    def loss(self, params, batch):
        return self.impl.loss(params, batch)

    def forward(self, params, batch):
        return self.impl.forward(params, batch)

    def prefill(self, params, batch, cache_dtype=jnp.bfloat16):
        return self.impl.prefill(params, batch, cache_dtype)

    def decode_step(self, params, cache, tokens):
        return self.impl.decode_step(params, cache, tokens)

    def cache_spec(self, batch, seq, dtype=jnp.bfloat16):
        return self.impl.cache_spec(batch, seq, dtype)

    def init_cache(self, batch, seq, dtype=jnp.bfloat16):
        return self.impl.init_cache(batch, seq, dtype)

    # ------------------------------------------------------------------
    def uses_embeds(self) -> bool:
        """Frontend archs feed precomputed embeddings for train/prefill."""
        return self.cfg.frontend in ("vision", "audio")

    def input_specs(self, shape: ShapeConfig,
                    embed_dtype=jnp.bfloat16) -> Dict[str, Any]:
        """Dry-run input ShapeDtypeStructs for one assigned shape."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            if self.uses_embeds():
                return {"embeds": frontends.frontend_embed_spec(cfg, b, s,
                                                                embed_dtype),
                        "labels": tok}
            return {"tokens": tok, "labels": tok}
        if shape.kind == "prefill":
            if self.uses_embeds():
                return {"embeds": frontends.frontend_embed_spec(cfg, b, s,
                                                                embed_dtype)}
            return {"tokens": tok}
        # decode: one new token against a seq_len cache
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
                "cache": self.cache_spec(b, s)}

    def synth_batch(self, shape: ShapeConfig, rng=None,
                    dtype=jnp.float32) -> Dict[str, Any]:
        """Concrete synthetic batch matching input_specs (smoke tests)."""
        cfg = self.cfg
        if rng is None:
            rng = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(rng)
        b, s = shape.global_batch, shape.seq_len
        toks = jax.random.randint(k1, (b, s), 0, cfg.vocab_size, jnp.int32)
        if shape.kind == "train":
            if self.uses_embeds():
                return {"embeds": frontends.synth_embeddings(cfg, b, s, k2,
                                                             dtype),
                        "labels": toks}
            return {"tokens": toks, "labels": toks}
        if shape.kind == "prefill":
            if self.uses_embeds():
                return {"embeds": frontends.synth_embeddings(cfg, b, s, k2,
                                                             dtype)}
            return {"tokens": toks}
        return {"tokens": toks[:, 0], "cache": self.init_cache(b, s)}

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """MoE-aware: only top_k/n_experts of expert weights are active."""
        cfg = self.cfg
        if not cfg.is_moe:
            return self.param_count(params)
        total = 0
        flat = jax.tree.flatten_with_path(params)[0] if hasattr(jax.tree, "flatten_with_path") else None
        # expert tensors have leading dim n_experts inside "mlp"
        def visit(path, leaf):
            nonlocal total
            keys = [getattr(p, "key", str(p)) for p in path]
            if "mlp" in keys and leaf.ndim >= 3 and leaf.shape[-3] == cfg.n_experts:
                total += int(leaf.size * cfg.top_k / cfg.n_experts)
            elif "mlp" in keys and leaf.ndim >= 4 and leaf.shape[1] == cfg.n_experts:
                total += int(leaf.size * cfg.top_k / cfg.n_experts)
            else:
                total += leaf.size
        jax.tree_util.tree_map_with_path(visit, params)
        return total


def _filter_kwargs(cls, kw):
    import inspect
    sig = inspect.signature(cls.__init__)
    return {k: v for k, v in kw.items() if k in sig.parameters}


def get_model(cfg: ArchConfig, compute_dtype=jnp.bfloat16, **kw) -> Model:
    cls = {"rwkv6": RWKV6LM, "mamba2_hybrid": Zamba2LM}.get(
        cfg.block_type, TransformerLM)
    impl = cls(cfg, compute_dtype=compute_dtype, **_filter_kwargs(cls, kw))
    return Model(cfg, impl)
