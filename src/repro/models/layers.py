"""Shared neural building blocks (pure-functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; leaves of per-layer blocks are
    stacked along a leading layer dim and consumed via ``lax.scan``.
  * all matmuls run in ``compute_dtype`` (bf16 on TPU); softmax/norms in f32.
  * key names are stable: the sharding rules in ``runtime/sharding.py``
    match on them.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(key, d, kind, dtype=jnp.float32):
    del key
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def group_norm_heads(x, scale, eps=1e-5):
    """Per-head group norm used by RWKV6 wkv output.  x: [..., H, D]."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (or [S]) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[..., None, :]                   # [B, S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, chunked-query "flash" schedule)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype=jnp.float32):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), in_axis=0, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _qkv(p, x, cfg):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(b, s, h, hd), k.reshape(b, s, hkv, hd), v.reshape(b, s, hkv, hd))


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 1024,
                      positions_q=None, positions_k=None,
                      unroll: bool = False):
    """Memory-bounded attention: scan over query chunks, full softmax per
    chunk.  q: [B,Sq,H,D], k/v: [B,Sk,Hkv,D].  GQA via head grouping.
    Never materializes the [Sq,Sk] score matrix for more than one chunk.
    """
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, sq)
    n_chunks = sq // qc
    assert sq % qc == 0, (sq, qc)

    qg = q.reshape(b, sq, hkv, g, hd)
    if positions_q is None:
        positions_q = jnp.arange(sq, dtype=jnp.int32)[None, :]
    if positions_k is None:
        positions_k = jnp.arange(sk, dtype=jnp.int32)[None, :]

    def one_chunk(carry, idx):
        qi = lax.dynamic_slice_in_dim(qg, idx * qc, qc, axis=1)      # [B,qc,Hkv,G,D]
        pq = lax.dynamic_slice_in_dim(positions_q, idx * qc, qc, axis=1)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qi, k,
                            preferred_element_type=jnp.float32) * scale
        if causal:
            mask = pq[:, None, None, :, None] >= positions_k[:, None, None, None, :]
            logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        oi = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return carry, oi.reshape(b, qc, h, hd)

    if n_chunks == 1:
        _, out = one_chunk(None, 0)
    else:
        _, chunks = lax.scan(one_chunk, None, jnp.arange(n_chunks),
                             unroll=unroll)
        out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, h, hd)
    return out


def attention_block(p, x, cfg, *, positions=None, q_chunk: int = 1024,
                    unroll: bool = False):
    """Full (training / prefill) attention incl. projections."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=cfg.causal, q_chunk=q_chunk,
                            positions_q=positions, positions_k=positions,
                            unroll=unroll)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def quantize_kv(x, axis=-1):
    """Symmetric per-token int8 quantization.  x: [..., D] float ->
    (int8 values, f32 scale[...])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention_q8(p, x, cfg, k_cache, v_cache, k_scale, v_scale,
                        index):
    """int8-KV variant of decode_attention (beyond-paper optimization:
    halves the dominant memory-term traffic of the D-Cache schedule).

    k_cache/v_cache: int8 [B, Hkv, S, D]; k_scale/v_scale: f32 [B, Hkv, S].
    """
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    s = k_cache.shape[2]
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.full((b, 1), index, dtype=jnp.int32)
    if cfg.rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    kq, ks = quantize_kv(jnp.swapaxes(k, 1, 2))          # [B,Hkv,1,D],[B,Hkv,1]
    vq, vs = quantize_kv(jnp.swapaxes(v, 1, 2))
    k_cache = lax.dynamic_update_slice(k_cache, kq, (0, 0, index, 0))
    v_cache = lax.dynamic_update_slice(v_cache, vq, (0, 0, index, 0))
    k_scale = lax.dynamic_update_slice(k_scale, ks, (0, 0, index))
    v_scale = lax.dynamic_update_slice(v_scale, vs, (0, 0, index))

    qg = q.reshape(b, hkv, g, hd)
    # dequantize to bf16 (f32 accumulate via preferred_element_type):
    # halves the materialized dequant traffic vs f32 copies (§Perf iter 3)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.bfloat16),
                        k_cache.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    logits = logits * k_scale[:, :, None, :] / math.sqrt(hd)
    valid = jnp.arange(s, dtype=jnp.int32)[None, None, None, :] <= index
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    pw = probs * v_scale[:, :, None, :]                   # fold dequant scale
    out = jnp.einsum("bhgs,bhsd->bhgd", pw.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(b, 1, h * hd) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache, k_scale, v_scale


def decode_attention(p, x, cfg, k_cache, v_cache, index):
    """One-token decode against a (possibly seq-sharded) KV cache.

    This is the paper-faithful "D-Cache" schedule: the KV cache stays put
    (sharded over the ``model`` axis = the storage pool), the query is
    broadcast, each shard computes a partial softmax and XLA emits only
    the tiny reduction collectives (log-sum-exp merge) — compute moves to
    the data, exactly the DockerSSD near-data principle.

    x: [B, 1, d]; k_cache/v_cache: [B, Hkv, S, D]; index: scalar int32.
    Returns (attn_out [B,1,d], new_k, new_v).
    """
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    s = k_cache.shape[2]
    q, k, v = _qkv(p, x, cfg)                                   # [B,1,*,D]
    pos = jnp.full((b, 1), index, dtype=jnp.int32)
    if cfg.rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # insert new kv at `index` (dynamic-update-slice: touches one page)
    k_new = jnp.swapaxes(k, 1, 2).astype(k_cache.dtype)         # [B,Hkv,1,D]
    v_new = jnp.swapaxes(v, 1, 2).astype(v_cache.dtype)
    k_cache = lax.dynamic_update_slice(k_cache, k_new, (0, 0, index, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v_new, (0, 0, index, 0))

    qg = q.reshape(b, hkv, g, hd)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(hd)
    valid = jnp.arange(s, dtype=jnp.int32)[None, None, None, :] <= index
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v_cache)
    out = out.reshape(b, 1, h * hd) @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP (gated / plain) and MoE
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {"w_up": dense_init(ks[0], (d, f), dtype=dtype),
                "b_up": jnp.zeros((f,), dtype),
                "w_down": dense_init(ks[1], (f, d), dtype=dtype),
                "b_down": jnp.zeros((d,), dtype)}
    return {"w_gate": dense_init(ks[0], (d, f), dtype=dtype),
            "w_up": dense_init(ks[1], (d, f), dtype=dtype),
            "w_down": dense_init(ks[2], (f, d), dtype=dtype)}


def _gate_act(x, act):
    if act in ("swiglu",):
        return jax.nn.silu(x)
    return jax.nn.gelu(x)  # geglu


def apply_mlp(p, x, act):
    if "w_gate" not in p:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
    h = _gate_act(x @ p["w_gate"].astype(x.dtype), act) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=dtype),
        "w_gate": dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
    }


def apply_moe(p, x, cfg, capacity: Optional[int] = None,
              no_drop: bool = False):
    """Token-choice top-k MoE with capacity-bounded scatter dispatch.

    x: [B, S, d].  FLOPs scale with *active* params (top_k experts/token),
    not total — dispatch is a scatter into per-expert buffers, not a dense
    all-experts einsum.  ``no_drop`` sizes capacity to the worst case
    (exact routing; used for decode and correctness tests).
    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    if no_drop:
        capacity = t
    elif capacity is None:
        capacity = int(cfg.capacity_factor * t * k / e)
        capacity = max(capacity, 1)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)                                  # [T,k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * (e ** 2) / e

    out = jnp.zeros((t, d), x.dtype)
    for j in range(k):
        eid = topi[:, j]                                              # [T]
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
        pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
        keep = pos < capacity
        slot = jnp.where(keep, pos, capacity)                         # overflow slot
        buf = jnp.zeros((e, capacity + 1, d), x.dtype)
        buf = buf.at[eid, slot].add(jnp.where(keep[:, None], xt, 0))
        buf = buf[:, :capacity]                                       # [E,C,d]
        # NOTE: we tried with_sharding_constraint hints (E->model,
        # C->data) here — measured WORSE (29 -> 107 GB/dev/layer of
        # collectives; GSPMD reshards the scatter).  The real fix is
        # apply_moe_shardmap below.  Kept dense dispatch as the
        # GSPMD-native baseline.
        h = _gate_act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)),
                      cfg.act)
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
        y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
        y = jnp.concatenate([y, jnp.zeros((e, 1, d), y.dtype)], axis=1)
        gathered = y[eid, slot]                                       # [T,d]
        out = out + gathered * topv[:, j:j + 1].astype(x.dtype)
    return out.reshape(b, s, d), aux


def apply_moe_shardmap(p, x, cfg, no_drop: bool = False):
    """Explicit-schedule MoE (beyond-paper hillclimb, EXPERIMENTS.md §Perf).

    GSPMD's dense-dispatch partitioning all-gathers the [E, C, d] expert
    buffers over the data axis (~8.4 GB/dev/layer measured on
    phi3.5-moe).  This shard_map version never materializes a global
    capacity buffer:

      * routing + dispatch run on each data shard's LOCAL tokens with
        LOCAL capacity (no communication);
      * each model shard slices out ITS experts (weights arrive via one
        bf16 FSDP all-gather) and runs the FFN on every data shard's
        local buffer;
      * the combine is a single psum over `model` of the [T_local, d]
        outputs — the only activation collective in the layer.

    Requires n_experts % model-axis == 0; falls back to ``apply_moe``
    outside a mesh context.
    """
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty or "model" not in m.axis_names:
        return apply_moe(p, x, cfg, no_drop=no_drop)
    from jax.sharding import PartitionSpec as P
    from repro.jax_compat import shard_map_unchecked
    e, k = cfg.n_experts, cfg.top_k
    d, f = cfg.d_model, cfg.d_ff
    tp = m.shape["model"]
    if e % tp != 0:
        return apply_moe(p, x, cfg, no_drop=no_drop)
    e_loc = e // tp
    fsdp = tuple(a for a in ("pod", "data") if a in m.axis_names)
    fa = fsdp if len(fsdp) > 1 else fsdp[0]
    b, s, _ = x.shape

    pspecs = {"router": P(None, None),
              "w_gate": P("model", fa, None),
              "w_up": P("model", fa, None),
              "w_down": P("model", fa, None)}
    xspec = P(fa, None, None)

    def local_fn(pp, xx):
        t = xx.shape[0] * s
        xt = xx.reshape(t, d)
        # one bf16 FSDP gather per weight (the standard ZeRO cost)
        wg = lax.all_gather(pp["w_gate"].astype(xt.dtype), fsdp, axis=1,
                            tiled=True)
        wu = lax.all_gather(pp["w_up"].astype(xt.dtype), fsdp, axis=1,
                            tiled=True)
        wd = lax.all_gather(pp["w_down"].astype(xt.dtype), fsdp, axis=1,
                            tiled=True)
        logits = (xt @ pp["router"].astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
        capacity = t if no_drop else max(
            int(cfg.capacity_factor * t * k / e), 1)
        my0 = lax.axis_index("model") * e_loc

        out = jnp.zeros((t, d), xt.dtype)
        for j in range(k):
            eid = topi[:, j]
            onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
            pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
            keep = pos < capacity
            slot = jnp.where(keep, pos, capacity)
            buf = jnp.zeros((e, capacity + 1, d), xt.dtype)
            buf = buf.at[eid, slot].add(jnp.where(keep[:, None], xt, 0))
            mine = lax.dynamic_slice_in_dim(buf[:, :capacity], my0, e_loc, 0)
            h = _gate_act(jnp.einsum("ecd,edf->ecf", mine, wg), cfg.act)
            h = h * jnp.einsum("ecd,edf->ecf", mine, wu)
            y = jnp.einsum("ecf,efd->ecd", h, wd)          # [e_loc, C, d]
            y = jnp.concatenate([y, jnp.zeros((e_loc, 1, d), y.dtype)], 1)
            sel = keep & (eid >= my0) & (eid < my0 + e_loc)
            gathered = y[jnp.clip(eid - my0, 0, e_loc - 1), slot]
            gathered = jnp.where(sel[:, None], gathered, 0)
            out = out + gathered * topv[:, j:j + 1].astype(xt.dtype)
        out = lax.psum(out, "model")
        density = jnp.mean(jax.nn.one_hot(topi[:, 0], e,
                                          dtype=jnp.float32), 0)
        density_proxy = jnp.mean(probs, axis=0)
        aux = jnp.sum(density * density_proxy) * e
        aux = lax.pmean(aux, fsdp)
        return out.reshape(xx.shape), aux

    fn = shard_map_unchecked(local_fn, mesh=m, in_specs=(pspecs, xspec),
                             out_specs=(xspec, P()))
    return fn({kk: p[kk] for kk in ("router", "w_gate", "w_up", "w_down")},
              x)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, cfg, dtype=jnp.float32):
    return {"table": embed_init(key, (cfg.vocab_size, cfg.d_model), dtype)}


def embed_tokens(p, tokens, compute_dtype):
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def unembed(p_embed, p_head, x, tie: bool):
    if tie:
        w = p_embed["table"].astype(x.dtype).T
    else:
        w = p_head["w"].astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean next-token CE in f32.  logits: [..., V] f32; labels int32."""
    mask = (labels != ignore_id).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
