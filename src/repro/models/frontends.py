"""Modality frontends — STUBS per task spec.

``[audio]``/``[vlm]`` archs specify the transformer BACKBONE only; the
modality frontend supplies *precomputed* frame/patch embeddings.  These
helpers generate deterministic synthetic embeddings with the right
shapes/dtypes for smoke tests, and the ShapeDtypeStructs for dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def frontend_embed_spec(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for precomputed patch/frame embeddings."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)


def synth_embeddings(cfg, batch: int, seq: int, rng=None, dtype=jnp.float32):
    """Deterministic synthetic patch/frame embeddings (stub frontend)."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    return (0.02 * jax.random.normal(rng, (batch, seq, cfg.d_model))).astype(dtype)
