"""Decoder-only (and encoder-only) transformer LM.

Structure is scan-over-layers with stacked per-layer params (compact HLO,
fast multi-pod compiles) and optional remat.  Supports:
  * dense / MoE FFN, GQA / MQA / MHA, RoPE, tied embeddings, QKV bias
  * ``forward`` for training (tokens or precomputed frontend embeddings)
  * ``prefill`` returning last-token logits + KV cache
  * ``decode_step`` against a seq-sharded KV cache (the DockerSSD
    "compute-at-the-KV-shard" schedule; see layers.decode_attention)
Cross-entropy is computed seq-chunked so the [B,S,V] logits tensor is
never materialized (vocab up to 257k).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

AUX_LOSS_COEF = 0.01


class TransformerLM:
    def __init__(self, cfg, compute_dtype=jnp.bfloat16, q_chunk: int = 1024,
                 remat: str = "full", loss_chunk: int = 256,
                 moe_no_drop: bool = False, unroll_inner: bool = False,
                 kv_quant: str = "none", moe_impl: str = "dense"):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.q_chunk = q_chunk
        self.remat = remat
        self.loss_chunk = loss_chunk
        self.moe_no_drop = moe_no_drop
        self.unroll = unroll_inner
        self.kv_quant = kv_quant
        self.moe_impl = moe_impl

    # -- init ---------------------------------------------------------------

    def init(self, rng, dtype=jnp.float32) -> Dict[str, Any]:
        cfg = self.cfg
        k_embed, k_head, k_norm, k_layers = jax.random.split(rng, 4)

        def init_layer(key):
            ks = jax.random.split(key, 4)
            p = {
                "attn_norm": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
                "attn": L.init_attention(ks[1], cfg, dtype),
                "mlp_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
            }
            p["mlp"] = (L.init_moe(ks[3], cfg, dtype) if cfg.is_moe
                        else L.init_mlp(ks[3], cfg, dtype))
            return p

        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        params = {
            "embed": L.init_embed(k_embed, cfg, dtype),
            "final_norm": L.init_norm(k_norm, cfg.d_model, cfg.norm, dtype),
            "layers": jax.vmap(init_layer)(layer_keys),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)}
        return params

    # -- blocks -------------------------------------------------------------

    def _layer(self, h, lp, positions):
        cfg = self.cfg
        a = L.apply_norm(lp["attn_norm"], h, cfg.norm)
        h = h + L.attention_block(lp["attn"], a, cfg, positions=positions,
                                  q_chunk=self.q_chunk, unroll=self.unroll)
        m = L.apply_norm(lp["mlp_norm"], h, cfg.norm)
        if cfg.is_moe:
            if self.moe_impl == "shardmap":
                mo, aux = L.apply_moe_shardmap(lp["mlp"], m, cfg,
                                               no_drop=self.moe_no_drop)
            else:
                mo, aux = L.apply_moe(lp["mlp"], m, cfg,
                                      no_drop=self.moe_no_drop)
        else:
            mo, aux = L.apply_mlp(lp["mlp"], m, cfg.act), jnp.zeros((), jnp.float32)
        return h + mo, aux

    def _maybe_remat(self, fn):
        if self.remat == "none":
            return fn
        policy = None
        if self.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)

    def backbone(self, params, h, positions):
        """Run the layer stack.  h: [B,S,d] compute_dtype."""
        layer_fn = self._maybe_remat(
            lambda hh, lp: self._layer(hh, lp, positions))

        def body(hh, lp):
            hh, aux = layer_fn(hh, lp)
            return hh, aux

        h, auxs = lax.scan(body, h, params["layers"], unroll=self.unroll)
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm)
        return h, jnp.sum(auxs)

    def _inputs_to_h(self, params, batch):
        if "embeds" in batch:
            return batch["embeds"].astype(self.compute_dtype)
        return L.embed_tokens(params["embed"], batch["tokens"], self.compute_dtype)

    # -- training forward / loss --------------------------------------------

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full logits (small vocab / tests).  Returns (logits_f32, aux)."""
        h = self._inputs_to_h(params, batch)
        b, s, _ = h.shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
        h, aux = self.backbone(params, h, positions)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           self.cfg.tie_embeddings)
        return logits, aux

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        h = self._inputs_to_h(params, batch)
        b, s, _ = h.shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
        h, aux = self.backbone(params, h, positions)
        labels = batch["labels"]
        ce = self._chunked_ce(params, h, labels)
        total = ce + AUX_LOSS_COEF * aux
        return total, {"ce": ce, "aux": aux}

    def _chunked_ce(self, params, h, labels):
        """Seq-chunked CE: logits materialized one chunk at a time."""
        cfg = self.cfg
        b, s, d = h.shape
        ck = min(self.loss_chunk, s)
        n = s // ck
        if s % ck:
            n, ck = 1, s

        def chunk(carry, idx):
            hh = lax.dynamic_slice_in_dim(h, idx * ck, ck, axis=1)
            ll = lax.dynamic_slice_in_dim(labels, idx * ck, ck, axis=1)
            logits = L.unembed(params["embed"], params.get("lm_head"), hh,
                               cfg.tie_embeddings)
            mask = (ll != -1).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ll[..., None].clip(0),
                                       axis=-1)[..., 0]
            nll = jnp.sum((lse - gold) * mask)
            return (carry[0] + nll, carry[1] + jnp.sum(mask)), None

        chunk = self._maybe_remat(chunk) if self.remat != "none" else chunk
        (nll, cnt), _ = lax.scan(chunk, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(n), unroll=self.unroll)
        return nll / jnp.maximum(cnt, 1.0)

    # -- serving ------------------------------------------------------------

    def cache_spec(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, seq, cfg.hd)
        if self.kv_quant == "int8":
            sshape = shape[:-1]
            return {"k": jax.ShapeDtypeStruct(shape, jnp.int8),
                    "v": jax.ShapeDtypeStruct(shape, jnp.int8),
                    "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                    "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
                    "index": jax.ShapeDtypeStruct((), jnp.int32)}
        return {"k": jax.ShapeDtypeStruct(shape, dtype),
                "v": jax.ShapeDtypeStruct(shape, dtype),
                "index": jax.ShapeDtypeStruct((), jnp.int32)}

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        spec = self.cache_spec(batch, seq, dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def prefill(self, params, batch, cache_dtype=jnp.bfloat16):
        """Returns (last-token logits [B,V] f32, cache)."""
        cfg = self.cfg
        h = self._inputs_to_h(params, batch)
        b, s, _ = h.shape
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)

        def body(hh, lp):
            a = L.apply_norm(lp["attn_norm"], hh, cfg.norm)
            q, k, v = L._qkv(lp["attn"], a, cfg)
            if cfg.rope:
                q = L.apply_rope(q, positions, cfg.rope_theta)
                k = L.apply_rope(k, positions, cfg.rope_theta)
            o = L.chunked_attention(q, k, v, causal=cfg.causal,
                                    q_chunk=self.q_chunk,
                                    positions_q=positions,
                                    positions_k=positions, unroll=self.unroll)
            hh = hh + o.reshape(b, s, -1) @ lp["attn"]["wo"].astype(hh.dtype)
            m = L.apply_norm(lp["mlp_norm"], hh, cfg.norm)
            if cfg.is_moe:
                mo, _ = L.apply_moe(lp["mlp"], m, cfg,
                                    no_drop=self.moe_no_drop)
            else:
                mo = L.apply_mlp(lp["mlp"], m, cfg.act)
            kc = jnp.swapaxes(k, 1, 2).astype(cache_dtype)   # [B,Hkv,S,D]
            vc = jnp.swapaxes(v, 1, 2).astype(cache_dtype)
            return hh + mo, (kc, vc)

        body = self._maybe_remat(body)
        h, (kc, vc) = lax.scan(body, h, params["layers"], unroll=self.unroll)
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        logits = L.unembed(params["embed"], params.get("lm_head"), h[:, -1:],
                           cfg.tie_embeddings)[:, 0]
        cache = {"k": kc, "v": vc,
                 "index": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """One token for every sequence in the batch.

        tokens: [B] int32.  cache: {"k": [L,B,Hkv,S,D], "v": ..., "index"}.
        Returns (logits [B,V] f32, new cache).
        """
        cfg = self.cfg
        index = cache["index"]
        h = L.embed_tokens(params["embed"], tokens[:, None], self.compute_dtype)
        q8 = self.kv_quant == "int8"

        def body(hh, xs):
            if q8:
                lp, kc, vc, ksc, vsc = xs
                a = L.apply_norm(lp["attn_norm"], hh, cfg.norm)
                o, kc, vc, ksc, vsc = L.decode_attention_q8(
                    lp["attn"], a, cfg, kc, vc, ksc, vsc, index)
            else:
                lp, kc, vc = xs
                a = L.apply_norm(lp["attn_norm"], hh, cfg.norm)
                o, kc, vc = L.decode_attention(lp["attn"], a, cfg, kc, vc,
                                               index)
            hh = hh + o
            m = L.apply_norm(lp["mlp_norm"], hh, cfg.norm)
            if cfg.is_moe:
                mo, _ = L.apply_moe(lp["mlp"], m, cfg, no_drop=True)
            else:
                mo = L.apply_mlp(lp["mlp"], m, cfg.act)
            if q8:
                return hh + mo, (kc, vc, ksc, vsc)
            return hh + mo, (kc, vc)

        if q8:
            h, (kc, vc, ksc, vsc) = lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]),
                unroll=self.unroll)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc,
                         "index": index + 1}
        else:
            h, (kc, vc) = lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]),
                unroll=self.unroll)
            new_cache = {"k": kc, "v": vc, "index": index + 1}
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           cfg.tie_embeddings)[:, 0]
        return logits, new_cache
