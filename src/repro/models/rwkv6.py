"""RWKV-6 "Finch": attention-free RNN with data-dependent decay
(arXiv:2404.05892), JAX implementation.

Training/prefill use a *chunked* parallel form of the wkv recurrence in
which every exponent is a difference of cumulative log-decays and hence
<= 0 — numerically safe in f32 without renormalization tricks.  Decode
is the O(1) recurrent step (this is why rwkv6 runs the ``long_500k``
shape).  A Pallas TPU kernel of the chunk kernel lives in
``repro.kernels.rwkv_scan``; this module is its algorithmic reference.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

LORA_R = 32
DECAY_LORA_R = 64


# ---------------------------------------------------------------------------
# wkv recurrence — chunked parallel form and step form
# ---------------------------------------------------------------------------


def wkv_chunk(r, k, v, logw, u, s0):
    """One chunk of the wkv recurrence for a single (batch, head).

    r/k/logw: [C, dk]; v: [C, dv]; u: [dk]; s0: [dk, dv].
    Returns (o: [C, dv], sC: [dk, dv]).  All exponents are <= 0.
    """
    cum = jnp.cumsum(logw, axis=0)                      # [C, dk] incl. t
    cum_excl = cum - logw                               # prod over 1..t-1
    # intra-chunk scores: t > s strictly
    diff = cum_excl[:, None, :] - cum[None, :, :]       # [t, s, dk]
    c = r.shape[0]
    tri = jnp.tril(jnp.ones((c, c), bool), -1)
    # mask BEFORE exp (exp of masked positive entries would overflow and
    # poison gradients via inf * 0)
    dmat = jnp.exp(jnp.where(tri[:, :, None], diff, -jnp.inf))
    scores = jnp.einsum("ti,si,tsi->ts", r, k, dmat)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)         # [C]
    o = scores @ v + diag[:, None] * v
    # inter-chunk (initial state)
    o = o + (r * jnp.exp(cum_excl)) @ s0
    # state update
    k2 = k * jnp.exp(cum[-1][None, :] - cum)            # [C, dk]
    sC = jnp.exp(cum[-1])[:, None] * s0 + k2.T @ v
    return o, sC


def wkv_chunked(r, k, v, logw, u, s0, chunk: int = 32,
                unroll: bool = False):
    """Full-sequence wkv via scan over chunks.

    r/k/logw: [B, S, H, dk]; v: [B, S, H, dv]; u: [H, dk];
    s0: [B, H, dk, dv].  Returns (o: [B, S, H, dv], sT).
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    ck = min(chunk, s)
    assert s % ck == 0, (s, ck)
    n = s // ck

    def resh(x):  # [B,S,H,*] -> [N, B, H, C, *]
        return jnp.moveaxis(x.reshape(b, n, ck, h, -1), (1, 3), (0, 2))

    rs, ks, vs, ws = resh(r), resh(k), resh(v), resh(logw)

    chunk_fn = jax.vmap(jax.vmap(wkv_chunk, in_axes=(0, 0, 0, 0, 0, 0)),
                        in_axes=(0, 0, 0, 0, None, 0))

    def body(state, xs):
        rc, kc, vc, wc = xs
        o, state = chunk_fn(rc, kc, vc, wc, u, state)
        return state, o

    sT, os = lax.scan(body, s0, (rs, ks, vs, ws),
                      unroll=unroll)                    # os: [N,B,H,C,dv]
    o = jnp.moveaxis(os, (0, 2), (1, 3)).reshape(b, s, h, dv)
    return o, sT


def wkv_step(r, k, v, logw, u, state):
    """One-token recurrence.  r/k/logw: [B,H,dk]; v: [B,H,dv];
    state: [B,H,dk,dv].  Returns (o [B,H,dv], new state)."""
    kv = k[..., :, None] * v[..., None, :]              # [B,H,dk,dv]
    o = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    new = jnp.exp(logw)[..., None] * state + kv
    return o, new


def wkv_ref(r, k, v, logw, u, s0):
    """Naive per-token scan — oracle for the chunked form and the kernel."""
    def body(state, xs):
        rt, kt, vt, wt = xs
        o, state = wkv_step(rt, kt, vt, wt, u, state)
        return state, o
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, logw))
    sT, os = lax.scan(body, s0, xs)
    return jnp.moveaxis(os, 0, 1), sT


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


class RWKV6LM:
    def __init__(self, cfg, compute_dtype=jnp.bfloat16, chunk: int = 32,
                 remat: str = "full", loss_chunk: int = 256,
                 unroll_inner: bool = False):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.chunk = chunk
        self.remat = remat
        self.loss_chunk = loss_chunk
        self.unroll = unroll_inner
        self.n_heads = cfg.d_model // cfg.ssm_head_dim
        self.dk = cfg.ssm_head_dim

    # -- init ----------------------------------------------------------------

    def init(self, rng, dtype=jnp.float32) -> Dict[str, Any]:
        cfg, d, h, dk = self.cfg, self.cfg.d_model, self.n_heads, self.dk
        keys = jax.random.split(rng, 4)

        def init_layer(key):
            ks = jax.random.split(key, 12)
            tm = {
                "mu_x": jnp.zeros((d,), dtype), "mu_w": jnp.zeros((d,), dtype),
                "mu_k": jnp.zeros((d,), dtype), "mu_v": jnp.zeros((d,), dtype),
                "mu_r": jnp.zeros((d,), dtype), "mu_g": jnp.zeros((d,), dtype),
                "lora_a": L.dense_init(ks[0], (d, 5 * LORA_R), dtype=dtype),
                "lora_b": (jnp.zeros((5, LORA_R, d), dtype)),
                "w0": jnp.full((d,), -0.6, dtype),  # w ~ exp(-exp(-0.6)) ~ 0.58
                "wa": L.dense_init(ks[1], (d, DECAY_LORA_R), dtype=dtype),
                "wb": jnp.zeros((DECAY_LORA_R, d), dtype),
                "u": (0.5 * jax.random.normal(ks[2], (h, dk))).astype(dtype),
                "wr": L.dense_init(ks[3], (d, d), dtype=dtype),
                "wk": L.dense_init(ks[4], (d, d), dtype=dtype),
                "wv": L.dense_init(ks[5], (d, d), dtype=dtype),
                "wg": L.dense_init(ks[6], (d, d), dtype=dtype),
                "wo": L.dense_init(ks[7], (d, d), dtype=dtype),
                "ln_x": jnp.ones((h, dk), dtype),
            }
            cm = {
                "mu_k": jnp.zeros((d,), dtype), "mu_r": jnp.zeros((d,), dtype),
                "wk": L.dense_init(ks[8], (d, cfg.d_ff), dtype=dtype),
                "wv": L.dense_init(ks[9], (cfg.d_ff, d), dtype=dtype),
                "wr": L.dense_init(ks[10], (d, d), dtype=dtype),
            }
            return {
                "ln1": L.init_norm(ks[11], d, "layernorm", dtype),
                "time_mix": tm,
                "ln2": L.init_norm(ks[11], d, "layernorm", dtype),
                "channel_mix": cm,
            }

        layer_keys = jax.random.split(keys[0], cfg.n_layers)
        return {
            "embed": L.init_embed(keys[1], cfg, dtype),
            "ln0": L.init_norm(keys[2], d, "layernorm", dtype),
            "final_norm": L.init_norm(keys[2], d, "layernorm", dtype),
            "layers": jax.vmap(init_layer)(layer_keys),
            "lm_head": {"w": L.dense_init(keys[3], (d, cfg.vocab_size),
                                          dtype=dtype)},
        }

    # -- time mix ------------------------------------------------------------

    def _ddlerp(self, tm, x, sx):
        """Data-dependent token-shift interpolation -> (xw,xk,xv,xr,xg)."""
        dx = sx - x
        xxx = x + dx * tm["mu_x"].astype(x.dtype)
        lo = jnp.tanh(xxx @ tm["lora_a"].astype(x.dtype))
        lo = lo.reshape(*x.shape[:-1], 5, LORA_R)
        mix = jnp.einsum("...ck,ckd->...cd", lo, tm["lora_b"].astype(x.dtype))
        mus = jnp.stack([tm["mu_w"], tm["mu_k"], tm["mu_v"], tm["mu_r"],
                         tm["mu_g"]]).astype(x.dtype)
        outs = x[..., None, :] + dx[..., None, :] * (mus + mix)
        return [outs[..., i, :] for i in range(5)]

    def _tm_proj(self, tm, x, sx):
        xw, xk, xv, xr, xg = self._ddlerp(tm, x, sx)
        b = x.shape[0]
        lead = x.shape[:-1]
        h, dk = self.n_heads, self.dk
        w_dec = tm["w0"].astype(jnp.float32) + (
            jnp.tanh(xw @ tm["wa"].astype(x.dtype)) @ tm["wb"].astype(x.dtype)
        ).astype(jnp.float32)
        logw = -jnp.exp(w_dec)                                # [..., d] <= 0
        r = (xr @ tm["wr"].astype(x.dtype)).reshape(*lead, h, dk)
        k = (xk @ tm["wk"].astype(x.dtype)).reshape(*lead, h, dk)
        v = (xv @ tm["wv"].astype(x.dtype)).reshape(*lead, h, dk)
        g = jax.nn.silu(xg @ tm["wg"].astype(x.dtype))
        logw = logw.reshape(*lead, h, dk)
        return r, k, v, g, logw

    def _time_mix_seq(self, tm, x, shift_state, wkv_state):
        """x: [B,S,d].  Returns (out, last_x, new_wkv_state)."""
        b, s, d = x.shape
        sx = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
        r, k, v, g, logw = self._tm_proj(tm, x, sx)
        o, sT = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw,
                            tm["u"].astype(jnp.float32),
                            wkv_state, chunk=self.chunk, unroll=self.unroll)
        o = L.group_norm_heads(o.astype(x.dtype), tm["ln_x"])
        out = (o.reshape(b, s, d) * g) @ tm["wo"].astype(x.dtype)
        return out, x[:, -1], sT

    def _channel_mix_seq(self, cm, x, shift_state):
        sx = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
        dx = sx - x
        xk = x + dx * cm["mu_k"].astype(x.dtype)
        xr = x + dx * cm["mu_r"].astype(x.dtype)
        kk = jnp.square(jax.nn.relu(xk @ cm["wk"].astype(x.dtype)))
        out = jax.nn.sigmoid(xr @ cm["wr"].astype(x.dtype)) * (
            kk @ cm["wv"].astype(x.dtype))
        return out, x[:, -1]

    # -- forward -------------------------------------------------------------

    def _state0(self, b, dtype=jnp.float32):
        cfg = self.cfg
        return {
            "shift_tm": jnp.zeros((cfg.n_layers, b, cfg.d_model), dtype),
            "shift_cm": jnp.zeros((cfg.n_layers, b, cfg.d_model), dtype),
            "wkv": jnp.zeros((cfg.n_layers, b, self.n_heads, self.dk, self.dk),
                             jnp.float32),
            "index": jnp.zeros((), jnp.int32),
        }

    def backbone(self, params, h, state):
        cfg = self.cfg

        def layer_fn(carry, xs):
            hh = carry
            lp, st_tm, st_cm, wkv = xs
            a = L.apply_norm(lp["ln1"], hh, "layernorm")
            o, n_tm, n_wkv = self._time_mix_seq(lp["time_mix"], a, st_tm, wkv)
            hh = hh + o
            c = L.apply_norm(lp["ln2"], hh, "layernorm")
            o2, n_cm = self._channel_mix_seq(lp["channel_mix"], c, st_cm)
            return hh + o2, (n_tm, n_cm, n_wkv)

        if self.remat != "none":
            layer_fn = jax.checkpoint(layer_fn)
        h, (tm, cm, wkv) = lax.scan(
            layer_fn, h,
            (params["layers"], state["shift_tm"].astype(h.dtype),
             state["shift_cm"].astype(h.dtype), state["wkv"]),
            unroll=self.unroll)
        new_state = {"shift_tm": tm.astype(state["shift_tm"].dtype),
                     "shift_cm": cm.astype(state["shift_cm"].dtype),
                     "wkv": wkv,
                     "index": state["index"] + h.shape[1]}
        return L.apply_norm(params["final_norm"], h, "layernorm"), new_state

    def _embed(self, params, batch):
        if "embeds" in batch:
            h = batch["embeds"].astype(self.compute_dtype)
        else:
            h = L.embed_tokens(params["embed"], batch["tokens"],
                               self.compute_dtype)
        return L.apply_norm(params["ln0"], h, "layernorm")

    def forward(self, params, batch):
        h = self._embed(params, batch)
        state = self._state0(h.shape[0], self.compute_dtype)
        h, _ = self.backbone(params, h, state)
        logits = (h @ params["lm_head"]["w"].astype(h.dtype)).astype(jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    # -- serving -------------------------------------------------------------

    def cache_spec(self, batch: int, seq: int, dtype=jnp.bfloat16):
        del seq  # O(1) state — the whole point of the SSM family
        cfg = self.cfg
        return {
            "shift_tm": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.d_model), dtype),
            "shift_cm": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.d_model), dtype),
            "wkv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, self.n_heads, self.dk, self.dk),
                jnp.float32),
            "index": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        spec = self.cache_spec(batch, seq, dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def prefill(self, params, batch, cache_dtype=jnp.bfloat16):
        h = self._embed(params, batch)
        state = self._state0(h.shape[0], self.compute_dtype)
        h, state = self.backbone(params, h, state)
        logits = (h[:, -1] @ params["lm_head"]["w"].astype(h.dtype)).astype(
            jnp.float32)
        state = {**state,
                 "shift_tm": state["shift_tm"].astype(cache_dtype),
                 "shift_cm": state["shift_cm"].astype(cache_dtype)}
        return logits, state

    def decode_step(self, params, cache, tokens):
        """tokens: [B].  O(1) per token — no KV growth."""
        h = L.embed_tokens(params["embed"], tokens[:, None],
                           self.compute_dtype)[:, 0]          # [B, d]
        h = L.apply_norm(params["ln0"], h, "layernorm")

        def layer_fn(hh, xs):
            lp, st_tm, st_cm, wkv = xs
            a = L.apply_norm(lp["ln1"], hh, "layernorm")
            r, k, v, g, logw = self._tm_proj(lp["time_mix"], a,
                                             st_tm.astype(a.dtype))
            o, n_wkv = wkv_step(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), logw,
                                lp["time_mix"]["u"].astype(jnp.float32), wkv)
            o = L.group_norm_heads(o.astype(a.dtype), lp["time_mix"]["ln_x"])
            o = (o.reshape(*hh.shape[:-1], -1) * g) @ lp["time_mix"]["wo"].astype(a.dtype)
            hh = hh + o
            c = L.apply_norm(lp["ln2"], hh, "layernorm")
            dx = st_cm.astype(c.dtype) - c
            xk = c + dx * lp["channel_mix"]["mu_k"].astype(c.dtype)
            xr = c + dx * lp["channel_mix"]["mu_r"].astype(c.dtype)
            kk = jnp.square(jax.nn.relu(xk @ lp["channel_mix"]["wk"].astype(c.dtype)))
            o2 = jax.nn.sigmoid(xr @ lp["channel_mix"]["wr"].astype(c.dtype)) * (
                kk @ lp["channel_mix"]["wv"].astype(c.dtype))
            return hh + o2, (a.astype(st_tm.dtype), c.astype(st_cm.dtype), n_wkv)

        h, (tm, cm, wkv) = lax.scan(
            layer_fn, h, (params["layers"], cache["shift_tm"],
                          cache["shift_cm"], cache["wkv"]),
            unroll=self.unroll)
        h = L.apply_norm(params["final_norm"], h, "layernorm")
        logits = (h @ params["lm_head"]["w"].astype(h.dtype)).astype(jnp.float32)
        return logits, {"shift_tm": tm, "shift_cm": cm, "wkv": wkv,
                        "index": cache["index"] + 1}
