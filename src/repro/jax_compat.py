"""Cross-version jax API shims.

The repo targets current jax, but the kernels and the sharded runtime
must also lower on the LTS-ish versions CI pins (see also
``kernels/pltpu_compat.py`` for the Pallas side):

  * ``jax.shard_map`` lived in ``jax.experimental.shard_map`` before it
    was promoted;
  * its replication-check kwarg was renamed ``check_rep`` ->
    ``check_vma``.
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map_unchecked(fn, *, mesh, in_specs, out_specs):
    """``shard_map`` that autodiffs on every supported jax version.

    New jax: the varying-manual-axes check rejects our collectives-only
    schedules, so pass ``check_vma=False``.  Old jax (pre-rename): keep
    ``check_rep=True`` — its transpose rule mis-specs scalar cotangents
    when the rep check is off, and our bodies psum their outputs over
    every mesh axis anyway, so the static rep check passes."""
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=True)
