"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real (single) device.

``auto_axis_kwargs`` smooths a jax API gap: ``AxisType`` /
``axis_types=`` only exist in newer releases; on older jax every mesh
axis is implicitly Auto, which is what we ask for anyway.
"""
from __future__ import annotations

import jax


def auto_axis_kwargs(axes) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:          # older jax: all axes are Auto already
        return {}
    return {"axis_types": (axis_type.Auto,) * len(axes)}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_kwargs(axes))


def make_elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                      axis_names=("data", "model")):
    """Degraded-capacity mesh after node failures: keeps the model axis
    intact (shard layout of the checkpoint) and shrinks the data axis."""
    while model_parallel > 1 and n_devices % model_parallel != 0:
        model_parallel //= 2
    data = n_devices // model_parallel
    return jax.make_mesh((data, model_parallel), axis_names,
                         **auto_axis_kwargs(axis_names))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires host-device flag)."""
    return jax.make_mesh(shape, axes, **auto_axis_kwargs(axes))
