"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the full production stack at the requested scale: sharded params
(when >1 device), grad-accum AdamW train step, deterministic sharded
data pipeline, async atomic checkpointing with restart, gradient
compression option.  On this CPU container use ``--reduced`` configs;
on a pod the same entry point drives the full mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_arch
from repro.data import ShardedLoader
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.optim import compression as comp
from repro.runtime import sharding as shd
from repro.runtime.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg, compute_dtype=jnp.dtype(args.dtype),
                      remat="none" if args.reduced else "full")
    sched = warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps)
    init_fn, upd_fn = adamw(lr=sched)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_fn(params)
    step0 = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume and mgr.latest_step() is not None:
            state = mgr.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            step0 = mgr.latest_step()
            print(f"resumed from step {step0}")

    tstep = make_train_step(model, upd_fn, grad_accum=args.grad_accum,
                            compression=args.compression)
    tstep = jax.jit(tstep, donate_argnums=(0, 1))
    residuals = (comp.init_residuals(params)
                 if args.compression != "none" else None)

    loader = ShardedLoader(global_batch=args.batch, seq_len=args.seq,
                           vocab=cfg.vocab_size, n_shards=1, shard=0)
    losses = []
    t0 = time.time()
    for step in range(step0, args.steps):
        batch = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if model.uses_embeds():
            from repro.models.frontends import synth_embeddings
            batch = {"embeds": synth_embeddings(
                cfg, args.batch, args.seq,
                jax.random.PRNGKey(step)), "labels": batch["labels"]}
        if args.compression != "none":
            params, opt_state, residuals, metrics = tstep(
                params, opt_state, residuals, batch)
        else:
            params, opt_state, metrics = tstep(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt / max(len(losses), 1):.2f}s/step)", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     blocking=False)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    loader.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
