import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without real hardware:
``.lower().compile()`` must succeed on the single-pod (16,16) mesh and
the multi-pod (2,16,16) mesh for every runnable cell; the compiled
artifact supplies memory_analysis / cost_analysis / the collective
schedule for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, cells, cell_status, get_arch
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.runtime import roofline, sharding as shd
from repro.runtime.train import make_train_step


def grad_accum_for(cfg) -> int:
    if cfg.d_model >= 8192:
        return 4
    if cfg.d_model >= 4096:
        return 2
    return 1


def count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_params(cfg, tree) -> int:
    total = count_params(tree)
    if not cfg.is_moe:
        return total
    inactive = 0

    def visit(path, leaf):
        nonlocal inactive
        keys = [getattr(p, "key", str(p)) for p in path]
        if "mlp" in keys and len(leaf.shape) >= 3 and cfg.n_experts in leaf.shape:
            inactive += int(np.prod(leaf.shape) *
                            (1 - cfg.top_k / cfg.n_experts))
    jax.tree_util.tree_map_with_path(visit, tree)
    return total - inactive


def build_lowered(arch_id: str, shape_name: str, mesh, mesh_name: str,
                  opt_level: int = 0):
    """Build and lower one cell.  Returns (lowered, meta)."""
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    # --- optimization ladder (EXPERIMENTS.md §Perf) ---
    #  serve opt>=1: bf16 weights, TP-only sharding (no per-token FSDP
    #                all-gather);  opt>=2: int8 KV cache
    #  train opt>=1: bf16 FSDP gathers (fp32 master weights)
    kv_quant = ("int8" if (opt_level >= 2 and shape.kind == "decode"
                           and cfg.block_type == "transformer") else "none")
    moe_impl = ("shardmap" if (opt_level >= 2 and cfg.is_moe
                               and shape.kind == "train") else "dense")
    model = get_model(cfg, compute_dtype=jnp.bfloat16, remat="full",
                      **({"kv_quant": kv_quant, "moe_impl": moe_impl}
                         if cfg.block_type == "transformer" else {}))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if opt_level >= 1 and shape.kind != "train":
        params_shape = shd.cast_float_specs(params_shape, jnp.bfloat16)
        pspecs = shd.serve_param_specs(mesh, params_shape)
    else:
        pspecs = shd.param_specs(mesh, params_shape)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    n_params = count_params(params_shape)
    n_active = active_params(cfg, params_shape)
    in_specs = model.input_specs(shape)

    if shape.kind == "train":
        ga = grad_accum_for(cfg)
        sched = warmup_cosine(3e-4, 100, 10_000)
        init_fn, upd_fn = adamw(lr=sched)
        opt_shape = jax.eval_shape(init_fn, params_shape)
        oshard = type(opt_shape)(
            step=NamedSharding(mesh, P()),
            m=pshard, v=pshard)
        tstep = make_train_step(
            model, upd_fn, grad_accum=ga,
            gather_dtype=jnp.bfloat16 if opt_level >= 1 else None)
        bshard = shd.to_shardings(mesh, shd.batch_spec(mesh, in_specs))
        rep = NamedSharding(mesh, P())
        lowered = jax.jit(
            tstep,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard,
                           {"loss": rep, "grad_norm": rep}),
            donate_argnums=(0, 1),
        ).lower(params_shape, opt_shape, in_specs)
        tokens = shape.global_batch * shape.seq_len
        mf = roofline.model_flops_estimate(n_active, tokens, "train")
        return lowered, dict(n_params=n_params, n_active=n_active,
                             model_flops=mf, grad_accum=ga)

    if shape.kind == "prefill":
        bshard = shd.to_shardings(mesh, shd.batch_spec(mesh, in_specs))
        if cfg.encoder_only:
            fn = lambda p, b: model.forward(p, b)[0]
            lowered = jax.jit(fn, in_shardings=(pshard, bshard)).lower(
                params_shape, in_specs)
        else:
            cache_shape = jax.eval_shape(
                lambda: model.cache_spec(shape.global_batch, shape.seq_len))
            cshard = shd.to_shardings(
                mesh, shd.cache_spec_shardings(mesh, cache_shape))
            lowered = jax.jit(
                model.prefill,
                in_shardings=(pshard, bshard),
                out_shardings=(NamedSharding(mesh, P()), cshard),
            ).lower(params_shape, in_specs)
        tokens = shape.global_batch * shape.seq_len
        mf = roofline.model_flops_estimate(n_active, tokens, "prefill")
        return lowered, dict(n_params=n_params, n_active=n_active,
                             model_flops=mf)

    # decode: one new token against a seq_len cache
    cache_spec = in_specs["cache"]
    cshard = shd.to_shardings(mesh, shd.cache_spec_shardings(mesh, cache_spec))
    tshard = NamedSharding(mesh, shd.decode_token_spec(mesh,
                                                       shape.global_batch))
    lowered = jax.jit(
        model.decode_step,
        in_shardings=(pshard, cshard, tshard),
        out_shardings=(NamedSharding(mesh, P()), cshard),
        donate_argnums=(1,),
    ).lower(params_shape, cache_spec, in_specs["tokens"])
    mf = roofline.model_flops_estimate(n_active, shape.global_batch,
                                       "decode")
    return lowered, dict(n_params=n_params, n_active=n_active,
                         model_flops=mf)


def run_cell(arch_id: str, shape_name: str, mesh_name: str,
             opt_level: int = 0, lower_only: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    rec = dict(arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
               opt_level=opt_level, status="ok")
    t0 = time.time()
    try:
        with mesh:
            lowered, meta = build_lowered(arch_id, shape_name, mesh,
                                          mesh_name, opt_level)
            rec.update(meta)
            rec["lower_s"] = round(time.time() - t0, 1)
            if lower_only:
                rec["status"] = "lowered"
                return rec
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            mem = compiled.memory_analysis()
            print(mem)
            cost = compiled.cost_analysis()
            print({k: v for k, v in (cost[0] if isinstance(cost, list)
                                     else cost).items()
                   if k in ("flops", "bytes accessed")})
            terms = roofline.analyze(compiled, None, arch_id, shape_name,
                                     mesh_name, chips, meta["model_flops"])
            rec["roofline"] = terms.to_dict()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                # per-device working set (args are sharded over chips)
                "temp_bytes_per_device": getattr(
                    mem, "temp_size_in_bytes", 0),
            }
    except Exception as e:  # record the failure — these are bugs to fix
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun.jsonl")
    ap.add_argument("--opt", type=int, default=0,
                    help="optimization level (hillclimb variants)")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--lower-only", action="store_true",
                    help="stop after .lower() (fast structural check)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, _ in cells(runnable_only=True):
            for m in meshes:
                todo.append((arch.name, shape.name, m))
    else:
        assert args.arch and args.shape
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok" and r.get("opt_level", 0) == args.opt:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    n_fail = 0
    for arch_id, shape_name, mesh_name in todo:
        key = (arch_id.replace("_", "-"), shape_name, mesh_name)
        norm = (get_arch(arch_id).name, shape_name, mesh_name)
        if norm in done:
            print(f"SKIP (done) {norm}")
            continue
        print(f"=== {arch_id} x {shape_name} x {mesh_name} ===", flush=True)
        rec = run_cell(arch_id, shape_name, mesh_name, args.opt,
                       lower_only=args.lower_only)
        rec["arch"] = get_arch(arch_id).name
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        if rec["status"] in ("ok", "lowered"):
            if rec["status"] == "lowered":
                print(f"  LOWERED in {rec['lower_s']}s", flush=True)
            else:
                rf = rec["roofline"]
                print(f"  OK  compile={rec['compile_s']}s "
                      f"flops={rf['hlo_flops']:.3e} bytes={rf['hlo_bytes']:.3e} "
                      f"coll={rf['coll_bytes']:.3e} "
                      f"bottleneck={rf['bottleneck']}", flush=True)
        else:
            n_fail += 1
            print(f"  FAIL {rec['error']}", flush=True)
    print(f"done: {len(todo)} cells, {n_fail} failures")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
