import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline probe runner: per-cell extrapolated FLOPs/bytes/collective
bytes from compiled 1-/2-layer probes (see runtime/costprobe.py).

  PYTHONPATH=src python -m repro.launch.probe --all --mesh single \
      --out results/probe.jsonl
"""

import argparse
import json
import time
import traceback

import numpy as np

from repro.configs.base import SHAPES, cells, get_arch
from repro.launch.mesh import make_production_mesh
from repro.runtime import roofline
from repro.runtime.costprobe import probe_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/probe.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--opt", type=int, default=0)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for arch, shape, _ in cells(runnable_only=True):
            for m in meshes:
                todo.append((arch.name, shape.name, m))
    else:
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok" and                             r.get("opt_level", 0) == args.opt:
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    from repro.launch.dryrun import active_params, count_params
    import jax

    n_fail = 0
    for arch_id, shape_name, mesh_name in todo:
        if (get_arch(arch_id).name, shape_name, mesh_name) in done:
            print(f"SKIP (done) {arch_id} {shape_name} {mesh_name}")
            continue
        print(f"=== probe {arch_id} x {shape_name} x {mesh_name} ===",
              flush=True)
        t0 = time.time()
        rec = dict(arch=get_arch(arch_id).name, shape=shape_name,
                   mesh=mesh_name, status="ok", opt_level=args.opt)
        try:
            mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
            chips = int(np.prod(list(mesh.shape.values())))
            with mesh:
                total = probe_cell(arch_id, shape_name, mesh, mesh_name,
                                   opt_level=args.opt)
            # cost_analysis is PER-DEVICE for SPMD modules -> globalize
            for k in ("flops", "bytes", "coll"):
                total[k] *= chips
            cfg = get_arch(arch_id)
            from repro.models.api import get_model
            import jax.numpy as jnp
            model = get_model(cfg)
            pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            n_active = active_params(cfg, pshape)
            shape = SHAPES[shape_name]
            tokens = (shape.global_batch * shape.seq_len
                      if shape.kind != "decode" else shape.global_batch)
            mf = roofline.model_flops_estimate(n_active, tokens, shape.kind)
            terms = roofline.RooflineTerms(
                arch=rec["arch"], shape=shape_name, mesh=mesh_name,
                chips=chips, hlo_flops=total["flops"],
                hlo_bytes=total["bytes"], coll_bytes=total["coll"],
                coll_breakdown={}, model_flops=mf)
            rec["roofline"] = terms.to_dict()
            rec["n_active"] = int(n_active)
            print(f"  flops={total['flops']:.3e} bytes={total['bytes']:.3e} "
                  f"coll={total['coll']:.3e} "
                  f"useful={terms.useful_flops_ratio:.2f} "
                  f"bottleneck={terms.bottleneck} "
                  f"step={terms.step_time_s*1e3:.1f}ms "
                  f"roofline_frac={terms.roofline_fraction:.3f}", flush=True)
        except Exception as e:
            rec["status"] = "fail"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            n_fail += 1
            print(f"  FAIL {rec['error']}", flush=True)
        rec["total_s"] = round(time.time() - t0, 1)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(f"probe done: {len(todo)} cells, {n_fail} failures")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
