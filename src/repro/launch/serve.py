"""Serving launcher — batched-request decode with the D-Cache runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --requests 4 --prompt-len 16 --gen 32 [--paged | --pool]

Three paths:

  * default — dense jitted decode (what the dry-run lowers at
    production scale).
  * ``--paged`` — the tiered PagedKVCache + Pallas paged_attention path
    on one device (the paper's mechanism made concrete).
  * ``--pool`` — distributed pool serving: a ``PoolServer`` shard-maps
    the tiered decode over ``--nodes`` devices (one DockerSSD node per
    ``model``-axis shard), fronted by a ``StoragePool`` whose
    admission/placement/free control messages ride Ether-oN frames and
    a ``PoolRouter`` doing least-loaded placement, per-node admission
    and failover requeue.  To simulate N nodes on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    launching (default ``--nodes 0`` uses every visible device).

Timing uses ``time.monotonic()`` so reported throughput/latency cannot
be skewed (or go negative) by wall-clock adjustment mid-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.serve import PagedServer, make_serving_fns


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--pool", action="store_true",
                    help="distributed pool serving (PoolServer across "
                         "--nodes devices; see module docstring)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="pool size; 0 = all visible devices")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-pages", type=int, default=32,
                    help="HBM window pages (per node with --pool)")
    ap.add_argument("--page-dtype", choices=["fp32", "int8", "fp8"],
                    default="fp32",
                    help="KV page storage format (--paged / --pool): "
                         "int8/fp8 store quantized codes + per-slot f32 "
                         "scales (~3x smaller pages) and decode through "
                         "the fused-dequant attention kernel")
    ap.add_argument("--horizon", type=int, default=1,
                    help="fused decode-horizon length: tokens generated "
                         "per host interaction (--paged / --pool; 1 = "
                         "classic per-token scheduling)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill size: admissions run at most "
                         "this many prompt tokens per scheduler "
                         "iteration, interleaved with decode horizons "
                         "(--paged / --pool; 0 = blocking one-shot "
                         "admission).  Prompts sharing a cached prefix "
                         "skip the covered pages entirely")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len), dtype=np.int32)

    t0 = time.monotonic()
    if args.pool:
        if cfg.block_type != "transformer":
            raise SystemExit("--pool demo path supports transformer archs")
        from repro.core import analytical as A
        from repro.core.storage_pool import StoragePool
        from repro.runtime.pool import PoolServer
        from repro.runtime.scheduler import PoolRouter, Request
        n = args.nodes or len(jax.devices())
        server = PoolServer(model, params, n_nodes=n,
                            page_size=args.page_size,
                            hbm_pages_per_node=args.hbm_pages,
                            page_dtype=args.page_dtype)
        pool = StoragePool(n)
        pool.attach_server(server)
        router = PoolRouter(server, pool, max_active=args.requests,
                            horizon=args.horizon,
                            prefill_chunk=args.prefill_chunk or None)
        for i in range(args.requests):
            router.submit(Request(rid=i, prompt=prompts[i],
                                  max_tokens=args.gen))
        stats = router.run_to_completion()
        toks = sum(len(r.output) for r in router.finished)
        print(f"pool of {n} nodes | per-node tier stats: "
              f"{server.node_tier_stats()}")
        print("aggregate tier stats:", stats["tier"])
        print("control plane:", A.control_plane_terms(pool.driver.stats,
                                                      toks))
    elif args.paged:
        if cfg.block_type != "transformer":
            raise SystemExit("--paged demo path supports transformer archs")
        server = PagedServer(model, params, page_size=args.page_size,
                             hbm_pages=args.hbm_pages,
                             page_dtype=args.page_dtype)
        for i in range(args.requests):
            server.add_request(i, prompts[i],
                               chunk=args.prefill_chunk or None)
        out = server.decode(args.gen,
                            horizon=args.horizon if args.horizon > 1
                            else None)
        toks = sum(len(v) for v in out.values())
        print("tier stats:", server.tier_stats())
        print(f"prefix hit rate: {server.prefix_hit_rate():.2f} "
              f"(prompt tokens served from the shared-prefix cache)")
    else:
        prefill, decode = make_serving_fns(model)
        total = args.prompt_len + args.gen
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)})
        # grow cache to generation capacity
        if "k" in cache:
            pad = total - cache["k"].shape[-2]
            cache["k"] = jnp.pad(cache["k"],
                                 [(0, 0)] * 3 + [(0, pad), (0, 0)])
            cache["v"] = jnp.pad(cache["v"],
                                 [(0, 0)] * 3 + [(0, pad), (0, 0)])
        toks = 0
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(args.gen):
            logits, cache = decode(params, cache, cur)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            toks += args.requests
    dt = time.monotonic() - t0
    print(f"served {args.requests} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
