"""Serving launcher — batched-request decode with the D-Cache runtime.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --requests 4 --prompt-len 16 --gen 32 [--paged | --pool] \
      [--horizon 8 --speculative] [--temperature 0.8 --top-p 0.9]

Three paths:

  * default — dense jitted decode (what the dry-run lowers at
    production scale).
  * ``--paged`` — the tiered PagedKVCache + Pallas paged_attention path
    on one device (the paper's mechanism made concrete).
  * ``--pool`` — distributed pool serving: a ``PoolServer`` shard-maps
    the tiered decode over ``--nodes`` devices (one DockerSSD node per
    ``model``-axis shard), fronted by a ``StoragePool`` whose
    admission/placement/free control messages ride Ether-oN frames and
    a ``PoolRouter`` doing least-loaded placement, per-node admission
    and failover requeue.  To simulate N nodes on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    launching (default ``--nodes 0`` uses every visible device).

Timing uses ``time.monotonic()`` so reported throughput/latency cannot
be skewed (or go negative) by wall-clock adjustment mid-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.models.api import get_model
from repro.runtime.serve import PagedServer, make_serving_fns


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--pool", action="store_true",
                    help="distributed pool serving (PoolServer across "
                         "--nodes devices; see module docstring)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="pool size; 0 = all visible devices")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-pages", type=int, default=32,
                    help="HBM window pages (per node with --pool)")
    ap.add_argument("--page-dtype", choices=["fp32", "int8", "fp8"],
                    default="fp32",
                    help="KV page storage format (--paged / --pool): "
                         "int8/fp8 store quantized codes + per-slot f32 "
                         "scales (~3x smaller pages) and decode through "
                         "the fused-dequant attention kernel")
    ap.add_argument("--horizon", type=int, default=1,
                    help="fused decode-horizon length: tokens generated "
                         "per host interaction (--paged / --pool; 1 = "
                         "classic per-token scheduling)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify decoding on the fused-horizon "
                         "scaffold (--paged / --pool, needs --horizon "
                         ">= 2): a device-side prompt-lookup drafter "
                         "proposes up to horizon-1 tokens, one "
                         "chunk-shaped pass verifies them, and the "
                         "accepted prefix + bonus token commit; "
                         "outputs are token-identical to the plain "
                         "horizon (greedy) or distribution-correct "
                         "(rejection sampling, temperature > 0)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax); "
                         "sampling runs on-device, seeded, so reruns "
                         "and pool nodes reproduce the same tokens")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (only with "
                         "--temperature > 0)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill size: admissions run at most "
                         "this many prompt tokens per scheduler "
                         "iteration, interleaved with decode horizons "
                         "(--paged / --pool; 0 = blocking one-shot "
                         "admission).  Prompts sharing a cached prefix "
                         "skip the covered pages entirely")
    args = ap.parse_args(argv)

    if args.speculative and not (args.paged or args.pool):
        raise SystemExit("--speculative needs --paged or --pool")
    if args.speculative and args.horizon < 2:
        raise SystemExit("--speculative needs --horizon >= 2 (the "
                         "draft rides the fused-horizon scaffold)")
    sampling = None
    if args.temperature > 0:
        from repro.runtime.serve import SamplingConfig
        sampling = SamplingConfig(temperature=args.temperature,
                                  top_p=args.top_p)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg, compute_dtype=jnp.float32, moe_no_drop=True)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len), dtype=np.int32)

    t0 = time.monotonic()
    if args.pool:
        if cfg.block_type != "transformer":
            raise SystemExit("--pool demo path supports transformer archs")
        from repro.core import analytical as A
        from repro.core.storage_pool import StoragePool
        from repro.runtime.pool import PoolServer
        from repro.runtime.scheduler import PoolRouter, Request
        n = args.nodes or len(jax.devices())
        server = PoolServer(model, params, n_nodes=n,
                            page_size=args.page_size,
                            hbm_pages_per_node=args.hbm_pages,
                            page_dtype=args.page_dtype)
        pool = StoragePool(n)
        pool.attach_server(server)
        router = PoolRouter(server, pool, max_active=args.requests,
                            horizon=args.horizon,
                            speculative=args.speculative,
                            sampling=sampling,
                            prefill_chunk=args.prefill_chunk or None)
        for i in range(args.requests):
            router.submit(Request(rid=i, prompt=prompts[i],
                                  max_tokens=args.gen))
        stats = router.run_to_completion()
        toks = sum(len(r.output) for r in router.finished)
        print(f"pool of {n} nodes | per-node tier stats: "
              f"{server.node_tier_stats()}")
        print("aggregate tier stats:", stats["tier"])
        print("control plane:", A.control_plane_terms(pool.driver.stats,
                                                      toks))
    elif args.paged:
        if cfg.block_type != "transformer":
            raise SystemExit("--paged demo path supports transformer archs")
        server = PagedServer(model, params, page_size=args.page_size,
                             hbm_pages=args.hbm_pages,
                             page_dtype=args.page_dtype)
        for i in range(args.requests):
            server.add_request(i, prompts[i],
                               chunk=args.prefill_chunk or None)
        out = server.decode(args.gen,
                            horizon=args.horizon if args.horizon > 1
                            else None,
                            sampling=sampling,
                            speculative=args.speculative)
        toks = sum(len(v) for v in out.values())
        if args.speculative:
            st = server.speculation_stats()
            print(f"speculation: alpha={st['alpha']:.2f} "
                  f"passes={st['passes']} "
                  f"(fallback {st['fallback_passes']}) "
                  f"accepted-length hist {st['accepted_len_hist']}")
        print("tier stats:", server.tier_stats())
        print(f"prefix hit rate: {server.prefix_hit_rate():.2f} "
              f"(prompt tokens served from the shared-prefix cache)")
    else:
        prefill, decode = make_serving_fns(model)
        total = args.prompt_len + args.gen
        logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompts)})
        # grow cache to generation capacity
        if "k" in cache:
            pad = total - cache["k"].shape[-2]
            cache["k"] = jnp.pad(cache["k"],
                                 [(0, 0)] * 3 + [(0, pad), (0, 0)])
            cache["v"] = jnp.pad(cache["v"],
                                 [(0, 0)] * 3 + [(0, pad), (0, 0)])
        if sampling is not None:
            from repro.runtime.serve import sampling_log_probs
            key = jax.random.PRNGKey(sampling.seed)

        def pick(lg, step):
            if sampling is None:
                return jnp.argmax(lg, -1).astype(jnp.int32)
            lp = sampling_log_probs(lg, jnp.float32(sampling.temperature),
                                    jnp.float32(sampling.top_p))
            g = jax.random.gumbel(jax.random.fold_in(key, step),
                                  lp.shape, jnp.float32)
            return jnp.argmax(lp + g, -1).astype(jnp.int32)

        toks = 0
        cur = pick(logits, 0)
        for step in range(args.gen):
            logits, cache = decode(params, cache, cur)
            cur = pick(logits, step + 1)
            toks += args.requests
    dt = time.monotonic() - t0
    print(f"served {args.requests} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
