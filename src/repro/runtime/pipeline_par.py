"""Pipeline parallelism over the ``pod`` axis (GPipe schedule).

The paper's storage pool runs pipeline-parallel inference across
DockerSSDs (Fig 8b); at training scale the analogous structure maps the
layer stack onto the ``pod`` mesh axis: stage *i* holds layers
[i*L/S, (i+1)*L/S), microbatches stream through stages via
``lax.ppermute``, and autodiff through the permutes yields the reverse
pipeline for the backward pass.

Implementation: ``shard_map`` over the full mesh; within it the layer
stack's leading dim is sharded over ``pod`` (each stage owns its slice),
batch over ``data``, weights additionally sharded over ``model`` exactly
as in the non-pipelined path (GSPMD handles the intra-stage TP because
we re-enter jit-style tracing via the collectives-only schedule below).

This is the scale path for models whose per-layer weights exceed what
FSDP alone can hold per chip; demonstrated at test scale in
``tests/test_pipeline_par.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.jax_compat import shard_map_unchecked


def make_pipeline_loss(model, mesh, *, n_microbatches: int,
                       stage_axis: str = "pod"):
    """Returns loss_fn(params, batch) running the transformer backbone as
    a GPipe pipeline over ``stage_axis``.

    Constraints: transformer-family model; n_layers % n_stages == 0;
    global batch % (n_microbatches * data_axis) == 0.  Embedding + loss
    tail execute on every stage (they are cheap and replicated over the
    stage axis), which keeps the schedule simple: only hidden states
    travel between stages.
    """
    cfg = model.cfg
    impl = model.impl
    n_stages = mesh.shape[stage_axis]
    assert cfg.n_layers % n_stages == 0
    per_stage = cfg.n_layers // n_stages
    data_axes = tuple(a for a in ("data",) if a in mesh.axis_names)

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]

        # specs: layer stack sharded over the stage axis; the rest of the
        # params replicated over it (embed/head participate everywhere)
        def stage_spec(path, leaf):
            keys = [getattr(p_, "key", str(p_)) for p_ in path]
            if keys and keys[0] == "layers":
                return P(stage_axis)
            return P()

        pspecs = jax.tree_util.tree_map_with_path(stage_spec, params)
        bspec = P(data_axes[0] if data_axes else None, None)

        def staged(params_local, tok_local, lab_local):
            stage = lax.axis_index(stage_axis)
            layers_local = params_local["layers"]      # [per_stage, ...]

            def run_stage(h):
                def body(hh, lp):
                    hh, _ = impl._layer(hh, lp, None if False else
                                        jnp.arange(hh.shape[1],
                                                   dtype=jnp.int32)[None, :]
                                        .repeat(hh.shape[0], 0))
                    return hh, None
                h, _ = lax.scan(body, h, layers_local)
                return h

            def embed(tok_mb):
                return impl._inputs_to_h(params_local, {"tokens": tok_mb})

            def tail_loss(h, lab_mb):
                from repro.models import layers as L
                hh = L.apply_norm(params_local["final_norm"], h, cfg.norm)
                logits = L.unembed(params_local["embed"],
                                   params_local.get("lm_head"), hh,
                                   cfg.tie_embeddings)
                mask = (lab_mb != -1).astype(jnp.float32)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, lab_mb[..., None].clip(0), axis=-1)[..., 0]
                # (1,)-shaped, not scalar: old-jax shard_map partial
                # eval mis-specs scalar device-varying residuals
                return (jnp.sum((lse - gold) * mask)[None],
                        jnp.sum(mask)[None])

            # GPipe: n_microbatches + n_stages - 1 ticks.  At each tick a
            # stage processes one microbatch-slot and passes it downstream.
            # shapes are LOCAL here (inside shard_map)
            b_loc, seq = tok_local.shape
            assert b_loc % n_microbatches == 0, (
                f"local batch {b_loc} must divide into "
                f"{n_microbatches} microbatches")
            mb = b_loc // n_microbatches
            toks = tok_local.reshape(n_microbatches, mb, seq)
            labs = lab_local.reshape(n_microbatches, mb, seq)
            buf = jnp.zeros((mb, seq, cfg.d_model), jnp.float32)
            nll = jnp.zeros((1,))
            cnt = jnp.zeros((1,))
            n_ticks = n_microbatches + n_stages - 1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(carry, t):
                buf, nll, cnt = carry
                # stage 0 injects microbatch t (if valid)
                mb_idx = jnp.clip(t, 0, n_microbatches - 1)
                fresh = embed(toks[mb_idx]).astype(jnp.float32)
                h_in = jnp.where(jnp.equal(stage, 0)[None, None, None],
                                 fresh, buf)
                h_out = run_stage(h_in.astype(impl.compute_dtype)).astype(
                    jnp.float32)
                # last stage computes the loss for the microbatch that
                # entered n_stages-1 ticks ago
                out_idx = jnp.clip(t - (n_stages - 1), 0,
                                   n_microbatches - 1)
                l, c = tail_loss(h_out.astype(impl.compute_dtype),
                                 labs[out_idx])
                valid = ((t - (n_stages - 1) >= 0) &
                         (t - (n_stages - 1) < n_microbatches) &
                         (stage == n_stages - 1))
                nll = nll + jnp.where(valid, l, 0.0)
                cnt = cnt + jnp.where(valid, c, 0.0)
                # hand the activation to the next stage
                buf = lax.ppermute(h_out, stage_axis, perm)
                return (buf, nll, cnt), None

            (buf, nll, cnt), _ = lax.scan(tick, (buf, nll, cnt),
                                          jnp.arange(n_ticks))
            # total loss lives on the last stage; share it with everyone
            nll = lax.psum(nll, stage_axis)
            cnt = lax.psum(cnt, stage_axis)
            if data_axes:
                nll = lax.psum(nll, data_axes)
                cnt = lax.psum(cnt, data_axes)
            return (nll / jnp.maximum(cnt, 1.0))[0]

        fn = shard_map_unchecked(staged, mesh=mesh,
                                 in_specs=(pspecs, bspec, bspec),
                                 out_specs=P())
        return fn(params, tokens, labels)

    return loss_fn
