"""Serving runtime.

Two paths:

  * ``make_serving_fns`` — production path: jitted prefill/decode with
    the D-Cache sharding rules (KV sequence-sharded over the ``model``
    axis = the storage pool; see runtime/sharding.py).  Used by
    ``launch/serve.py`` and the dry-run.
  * ``PagedServer`` — the paper's tiered mechanism made concrete on one
    device: a host-side **PageTableManager** (policy: LRU tiering,
    pinning, prefetch, admission accounting) over a device-resident
    **PageStore** with *stacked* per-layer pages, consumed by the Pallas
    ``paged_attention`` kernel.  One jitted ``decode_step`` advances
    every layer and every active sequence per token: a single batched
    scatter appends the new K/V for all layers/sequences, then a
    ``lax.scan`` over layers runs the paged-attention kernel against
    each layer's page slice.  Prefill is one jitted shot that writes
    whole prompt pages.  Host-side page management (eviction, page-in,
    table assembly) runs *between* jitted steps — the ISP-container
    split of the case study: policy at the host, data-path on the
    device.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kv_tier import PageStore, PageTableManager
from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention as _paged_inner
from repro.models import layers as L
from repro.runtime import sharding as shd


def make_serving_fns(model, mesh=None):
    """Returns (prefill_fn, decode_fn), jitted; sharded when mesh given."""
    if mesh is None:
        return (jax.jit(model.prefill), jax.jit(model.decode_step,
                                                donate_argnums=(1,)))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.param_specs(mesh, params_shape))

    prefill = jax.jit(model.prefill, in_shardings=(pshard, None))

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    decode_j = jax.jit(decode, donate_argnums=(1,),
                       in_shardings=(pshard, None, None))
    return prefill, decode_j


def _pow2(n: int) -> int:
    """Smallest power of two >= n (shape bucketing to bound retraces)."""
    return 1 << max(0, n - 1).bit_length()


class PagedServer:
    """Tiered-KV serving for a TransformerLM on one device.

    All layers share one page table: a physical page id addresses the
    stacked KV ``[n_layers, page, Hkv, D]`` of that extent, so host<->HBM
    tiering moves whole stacked pages and the jitted step needs exactly
    one table per batch.  Batch size and table width are bucketed to
    powers of two, so the decode step compiles O(log) times, not per
    shape.
    """

    def __init__(self, model, params, *, page_size: int = 16,
                 hbm_pages: Optional[int] = None, dtype=jnp.float32,
                 hbm_pages_per_layer: Optional[int] = None):
        if hbm_pages is None:
            hbm_pages = (hbm_pages_per_layer
                         if hbm_pages_per_layer is not None else 64)
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.dtype = dtype
        self.page = page_size
        self.hbm_pages = hbm_pages
        self.store = self._new_store()
        self.table = self._new_table()
        self._seqs: List[int] = []
        self._pending: Dict[int, int] = {}
        self._interpret = jax.default_backend() != "tpu"
        # donating the page arrays lets XLA update the store in place;
        # CPU jit ignores donation (with a warning), so only opt in on
        # accelerators.
        donate = (1, 2) if not self._interpret else ()
        self._decode_jit = jax.jit(self.decode_step, donate_argnums=donate)
        self._prefill_jit = jax.jit(self.prefill_step, donate_argnums=donate)

    def _new_store(self) -> PageStore:
        """The store the config prescribes (used at init and when a failed
        donated step voids the window)."""
        cfg = self.cfg
        return PageStore(n_layers=cfg.n_layers, page_size=self.page,
                         hbm_pages=self.hbm_pages,
                         n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                         dtype=self.dtype)

    def _new_table(self) -> PageTableManager:
        """Table-manager factory (PoolServer overrides with a sharded
        manager bound to its placement policy)."""
        return PageTableManager(self.store)

    # -- public capacity API (admission control lives on these) --------------

    def pages_needed(self, n_tokens: int) -> int:
        return self.table.pages_needed(n_tokens)

    def sequence_ids(self) -> List[int]:
        return list(self._seqs)

    def pending_tokens(self) -> Dict[int, int]:
        """Next-token (greedy) continuation for each live sequence — the
        argmax produced by its last prefill/decode step."""
        return dict(self._pending)

    def free_sequence(self, seq_id: int) -> int:
        """Retire a sequence: all its HBM + host-tier pages are released
        and immediately reusable.  Returns the number of pages freed."""
        freed = self.table.free_sequence(seq_id)
        if seq_id in self._seqs:
            self._seqs.remove(seq_id)
        self._pending.pop(seq_id, None)
        return freed

    def _recover_store(self):
        """Failure cleanup for donated jitted calls.  On accelerators the
        step's inputs are donated, so a call that fails *during execution*
        has already consumed the store arrays; the resident page data is
        unrecoverable.  Drop every sequence and reopen an empty window so
        the server stays usable (callers resubmit) instead of poisoning
        all later steps with deleted buffers."""
        if not getattr(self.store.k_pages, "is_deleted", lambda: False)():
            return
        stats, shard_stats = self.table.stats, self.table.shard_stats
        self.store = self._new_store()
        self.table = self._new_table()
        self.table.stats = stats           # telemetry continuity
        self.table.shard_stats = shard_stats
        self._seqs.clear()
        self._pending.clear()

    # -- shared transformer-block halves (used by the jitted decode /
    #    prefill bodies and the eager reference; only the attention
    #    middle differs between them) ----------------------------------------

    def _attn_inputs(self, lp, h, positions):
        """Pre-norm -> q/k/v projections -> RoPE at ``positions``."""
        cfg = self.cfg
        a = L.apply_norm(lp["attn_norm"], h, cfg.norm)
        q, k, v = L._qkv(lp["attn"], a, cfg)
        if cfg.rope:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_out_ffn(self, lp, h, o_flat):
        """Attention output-projection residual + FFN residual.
        o_flat: [B, S, H*D]."""
        cfg = self.cfg
        h = h + o_flat @ lp["attn"]["wo"].astype(h.dtype)
        m = L.apply_norm(lp["mlp_norm"], h, cfg.norm)
        if cfg.is_moe:
            mo, _ = L.apply_moe(lp["mlp"], m, cfg, no_drop=True)
        else:
            mo = L.apply_mlp(lp["mlp"], m, cfg.act)
        return h + mo

    # -- jitted device programs ----------------------------------------------

    def decode_step(self, params, k_pages, v_pages, page_table, lengths,
                    tokens):
        """One fused decode step for the whole active batch.

        k_pages/v_pages: [L, P, page, Hkv, D] stacked store; page_table:
        [B, pps] int32 physical ids; lengths: [B] int32 committed length
        per sequence (0 marks a padding slot); tokens: [B] int32.

        Appends each sequence's new K/V into its current page for every
        layer (one batched scatter per layer inside the scan — no
        per-sequence host loop) and runs the Pallas paged_attention
        kernel per layer via ``lax.scan``.  Returns (logits [B, V] f32,
        k_pages, v_pages).
        """
        cfg = self.cfg
        b = tokens.shape[0]
        n_phys = k_pages.shape[1]
        valid = lengths > 0                      # padding slots carry 0
        pos = lengths[:, None]                   # new token's position
        pidx = lengths // self.page
        offs = lengths % self.page
        phys = jnp.take_along_axis(page_table, pidx[:, None], axis=1)[:, 0]
        # out-of-bounds sentinel => scatter drops padding slots
        phys = jnp.where(valid, phys, n_phys)
        new_lengths = lengths + valid.astype(jnp.int32)

        h = L.embed_tokens(params["embed"], tokens[:, None], self.dtype)

        def body(hh, xs):
            lp, kp, vp = xs
            q, k, v = self._attn_inputs(lp, hh, pos)
            # batched append: all sequences' new K/V in one scatter
            kp = kp.at[phys, offs].set(k[:, 0].astype(kp.dtype),
                                       mode="drop")
            vp = vp.at[phys, offs].set(v[:, 0].astype(vp.dtype),
                                       mode="drop")
            o = _paged_inner(q[:, 0].astype(self.dtype), kp, vp,
                             page_table, new_lengths,
                             interpret=self._interpret)
            return self._attn_out_ffn(lp, hh, o.reshape(b, 1, -1)), (kp, vp)

        h, (k_pages, v_pages) = lax.scan(
            body, h, (params["layers"], k_pages, v_pages))
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           cfg.tie_embeddings)[:, 0]
        return logits, k_pages, v_pages

    def prefill_step(self, params, k_pages, v_pages, tokens, phys, length):
        """One-shot prefill: run the whole (page-padded) prompt through
        the layer stack and write full prompt pages into the store.

        tokens: [1, S_pad] int32 with S_pad a page multiple; phys:
        [S_pad // page] int32 physical destinations; length: scalar int32
        true prompt length.  Returns (last-real-token logits [V] f32,
        k_pages, v_pages).
        """
        cfg = self.cfg
        s_pad = tokens.shape[1]
        n_pages = s_pad // self.page
        positions = jnp.arange(s_pad, dtype=jnp.int32)[None, :]
        h = L.embed_tokens(params["embed"], tokens, self.dtype)

        def body(hh, xs):
            lp, kp, vp = xs
            q, k, v = self._attn_inputs(lp, hh, positions)
            o = L.chunked_attention(q, k, v, causal=True,
                                    positions_q=positions,
                                    positions_k=positions)
            # whole prompt pages in one scatter (positions past `length`
            # are garbage the kernel masks by sequence length; padding
            # pages carry an out-of-bounds id and are dropped)
            kpg = k[0].reshape(n_pages, self.page, cfg.n_kv_heads, cfg.hd)
            vpg = v[0].reshape(n_pages, self.page, cfg.n_kv_heads, cfg.hd)
            kp = kp.at[phys].set(kpg.astype(kp.dtype), mode="drop")
            vp = vp.at[phys].set(vpg.astype(vp.dtype), mode="drop")
            return self._attn_out_ffn(lp, hh, o.reshape(1, s_pad, -1)), \
                (kp, vp)

        h, (k_pages, v_pages) = lax.scan(
            body, h, (params["layers"], k_pages, v_pages))
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        last = lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
        logits = L.unembed(params["embed"], params.get("lm_head"), last,
                           cfg.tie_embeddings)[0, 0]
        return logits, k_pages, v_pages

    # -- request handling -----------------------------------------------------

    def add_request(self, seq_id: int, prompt: np.ndarray):
        """Admit a sequence: one jitted prefill writes the whole prompt's
        pages (no token-by-token teacher forcing).  Returns the last
        prompt position's logits [V].

        Like the kernel view it feeds, the active working set must fit
        the HBM window (admission control's ``pages_needed`` contract);
        a prompt needing more pages than the window raises the same
        pinned-working-set error the per-token path raised.
        """
        prompt = np.asarray(prompt, np.int32)
        s = int(prompt.shape[0])
        assert s >= 1, "empty prompt"
        self.table.add_sequence(seq_id)
        self._seqs.append(seq_id)
        try:
            try:
                phys = self.table.ensure_resident(seq_id, pin=True,
                                                  n_tokens=s)
            finally:
                self.table.unpin_all()
            # bucket the padded prompt to a power-of-two page count;
            # padding pages get an out-of-bounds destination (dropped by
            # the scatter)
            n_pages_pad = _pow2(len(phys))
            phys = list(phys) + [self.hbm_pages] * (n_pages_pad - len(phys))
            s_pad = n_pages_pad * self.page
            tokens = np.zeros((1, s_pad), np.int32)
            tokens[0, :s] = prompt
            logits, k_pages, v_pages = self._prefill_jit(
                self.params, self.store.k_pages, self.store.v_pages,
                jnp.asarray(tokens), jnp.asarray(phys, jnp.int32),
                jnp.asarray(s, jnp.int32))
        except Exception:
            # rejected admissions must not leak window pages or leave a
            # zero-length ghost in the live set; a failure inside the
            # donated jit call additionally voids the store
            self.free_sequence(seq_id)
            self._recover_store()
            raise
        self.store.adopt(k_pages, v_pages)
        self.table.set_length(seq_id, s)
        self._pending[seq_id] = int(jnp.argmax(logits))
        return logits

    # -- one committed batched step -------------------------------------------

    def _plan_step(self, seqs: List[int]):
        """Host-side page management for one decode step: make every
        active page resident + pinned, then build the padded device
        inputs.  Shapes are bucketed to powers of two."""
        try:
            rows = [self.table.prepare_append(s) for s in seqs]
        except Exception:
            self.table.unpin_all()
            raise
        lengths = [self.table.length(s) for s in seqs]
        pps = _pow2(max(len(r) for r in rows))
        b2 = _pow2(len(seqs))
        table = np.zeros((b2, pps), np.int32)
        for i, r in enumerate(rows):
            table[i, :len(r)] = r
        lens = np.zeros((b2,), np.int32)
        lens[:len(seqs)] = lengths
        return jnp.asarray(table), jnp.asarray(lens)

    def step_batch(self, tokens: Dict[int, int]):
        """Feed one token per sequence through a single jitted step and
        commit the appends.  Returns (seq_ids, logits [B, V]) — one
        device array, so callers sample with one transfer."""
        seqs = list(tokens)
        page_table, lengths = self._plan_step(seqs)
        try:
            toks = np.zeros((lengths.shape[0],), np.int32)
            toks[:len(seqs)] = [tokens[s] for s in seqs]
            logits, k_pages, v_pages = self._decode_jit(
                self.params, self.store.k_pages, self.store.v_pages,
                page_table, lengths, jnp.asarray(toks))
            self.store.adopt(k_pages, v_pages)
            for s in seqs:
                self.table.commit_append(s)
        except Exception:
            self._recover_store()
            raise
        finally:
            self.table.unpin_all()
        return seqs, logits[:len(seqs)]

    def step(self, tokens: Dict[int, int]) -> Dict[int, jnp.ndarray]:
        """Dict-shaped wrapper of :meth:`step_batch`:
        returns {seq_id: logits [V]}."""
        seqs, logits = self.step_batch(tokens)
        return {s: logits[i] for i, s in enumerate(seqs)}

    def step_reference(self, tokens: Dict[int, int]) -> jnp.ndarray:
        """Unjitted reference of one decode step on the *seed* schedule:
        Python loop over layers, per-layer param slicing, one eager
        scalar append per sequence, per-layer page-table rebuild.  Does
        NOT commit — used for equivalence tests and as the benchmark
        baseline.  Returns logits [B, V] in ``tokens`` order."""
        cfg = self.cfg
        seqs = list(tokens)
        try:
            rows = [self.table.prepare_append(s) for s in seqs]
            lengths = [self.table.length(s) for s in seqs]
            pos = jnp.asarray([[l] for l in lengths], jnp.int32)
            b = len(seqs)
            toks = jnp.asarray([tokens[s] for s in seqs], jnp.int32)
            new_lengths = jnp.asarray([l + 1 for l in lengths], jnp.int32)
            h = L.embed_tokens(self.params["embed"], toks[:, None],
                               self.dtype)
            for li in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[li], self.params["layers"])
                kp, vp = self.store.layer(li)
                q, k, v = self._attn_inputs(lp, h, pos)
                # seed schedule: one scalar append per sequence
                for bi, (l, row) in enumerate(zip(lengths, rows)):
                    kp = kp.at[row[l // self.page], l % self.page].set(
                        k[bi, 0].astype(kp.dtype))
                    vp = vp.at[row[l // self.page], l % self.page].set(
                        v[bi, 0].astype(vp.dtype))
                # seed schedule: page table rebuilt per layer
                max_pages = max(len(r) for r in rows)
                page_table = jnp.asarray(
                    [r + [0] * (max_pages - len(r)) for r in rows],
                    jnp.int32)
                o = ops.paged_attention(q[:, 0].astype(self.dtype), kp, vp,
                                        page_table, new_lengths)
                h = self._attn_out_ffn(lp, h, o.reshape(b, 1, -1))
            h = L.apply_norm(self.params["final_norm"], h, cfg.norm)
            logits = L.unembed(self.params["embed"],
                               self.params.get("lm_head"), h,
                               cfg.tie_embeddings)[:, 0]
        finally:
            self.table.unpin_all()
        return logits

    # -- decode loop ----------------------------------------------------------

    def decode(self, n_tokens: int, greedy: bool = True,
               seqs: Optional[List[int]] = None) -> Dict[int, list]:
        """Batched greedy decode across live sequences (or a subset — the
        HBM window only needs to hold the *active* batch's working set;
        idle sequences spill to the flash tier)."""
        active = self._seqs if seqs is None else seqs
        out = {s: [] for s in active}
        # page-in overlap model: pull any spilled pages of the activating
        # batch before the token loop starts
        for s in active:
            self.table.prefetch(s)
        # continue from the tokens pending after prefill
        cur = {s: self._pending.get(s, 0) for s in active}
        for _ in range(n_tokens):
            seqs, logits = self.step_batch(cur)
            # one batched argmax + one device->host transfer per token,
            # not one per sequence
            nxt_arr = np.asarray(jnp.argmax(logits, axis=-1))
            cur = {s: int(nxt_arr[i]) for i, s in enumerate(seqs)}
            for s in active:
                out[s].append(cur[s])
        self._pending.update(cur)
        return out

    # -- telemetry -----------------------------------------------------------

    def tier_stats(self) -> Dict[str, int]:
        agg = dict(vars(self.table.stats))
        agg["residency"] = self.table.residency()
        return agg
