"""Serving runtime.

Two paths:

  * ``make_serving_fns`` — production path: jitted prefill/decode with
    the D-Cache sharding rules (KV sequence-sharded over the ``model``
    axis = the storage pool; see runtime/sharding.py).  Used by
    ``launch/serve.py`` and the dry-run.
  * ``PagedServer`` — the paper's tiered mechanism made concrete on one
    device: a host-side **PageTableManager** (policy: LRU tiering,
    pinning, prefetch, admission accounting) over a device-resident
    **PageStore** with *stacked* per-layer pages, consumed by the Pallas
    ``paged_attention`` kernel.  One jitted ``decode_step`` advances
    every layer and every active sequence per token: a single batched
    scatter appends the new K/V for all layers/sequences, then a
    ``lax.scan`` over layers runs the paged-attention kernel against
    each layer's page slice.  Prefill is **chunked**: each jitted
    ``prefill_chunk_step`` writes one pow2-bucketed chunk of prompt
    pages and attends over the paged context, and prompts whose prefix
    is already resident skip the covered pages entirely (the
    content-addressed **prefix page cache** in
    ``core.kv_tier.PageTableManager``: refcount shares + copy-on-write;
    DESIGN.md §Prefix page cache).  Host-side page management
    (eviction, page-in, CoW splits, table assembly) runs *between*
    jitted steps — the ISP-container split of the case study: policy
    at the host, data-path on the device.

The **fused decode horizon** (``decode(horizon=H)``) extends the same
split H tokens at a time: one jitted ``lax.scan`` over H decode steps
where the on-device argmax feeds the next step, page slots advance
against a horizon's worth of pre-reserved pages
(``PageTableManager.reserve_horizon``), per-sequence EOS/budget masks
stop finished sequences mid-horizon, and exactly one [H, B] token
transfer crosses the boundary per horizon — greedy outputs are
token-for-token identical to the per-token path (DESIGN.md §Decode
horizon).

**Speculative decoding** (``decode(speculative=True)``) turns the same
scaffold into a draft-verify loop: an n-gram / prompt-lookup drafter
(``draft_ngram`` — suffix-match over the sequence's own
prompt+generated history, a device-side table so drafting adds no host
round-trip) proposes up to H-1 candidate tokens per sequence, ONE
chunk-shaped pass verifies every candidate (per-step query positions
against the pre-reserved pages), the on-device acceptance mask keeps
the longest matched prefix plus the bonus token from the first
mismatch, and ``commit_horizon`` rolls the rest of the reservation
back.  Token selection is on-device throughout — greedy argmax or
temperature/top-p Gumbel sampling on a per-step PRNG key
(``SamplingConfig``), with rejection-sampling acceptance so
speculative sampling stays distribution-correct (DESIGN.md
§Speculative decoding).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kv_tier import (PAGE_DTYPES, PageStore, PageTableManager,
                                quantize_page_kv)
from repro.kernels import ops, ref as kref
from repro.kernels.paged_attention import (
    paged_attention as _paged_inner,
    paged_attention_q8 as _paged_q8_inner)
from repro.models import layers as L
from repro.runtime import sharding as shd

NEG_INF = -1e30


def paged_attention_partial(q, k_pages, v_pages, local_table, col_owned,
                            lengths, k_scale=None, v_scale=None):
    """Paged decode attention returning online-softmax partials.

    The device contract of distributed paged attention (the pool hot
    path): score only the pages this node owns, fold them with an
    online softmax, and hand back the un-normalized state ``(acc, m,
    l)`` so the caller can merge nodes exactly (``combine_partials``)
    — or, on one node, normalize locally (the partial form *is* the
    full softmax when every page is owned).  On TPU the Pallas
    ``paged_attention`` kernel computes this piece per layer slice; the
    partial form is the distributed contract either way.

    q: [B, H, D]; k_pages/v_pages: *local* [P_node, page, Hkv, D];
    local_table: [B, pps] local physical ids (garbage where not owned);
    col_owned: [B, pps] bool — does this node own that logical page;
    lengths: [B] post-append sequence lengths.
    ``k_scale``/``v_scale`` ([P_node, page, Hkv] f32, quantized stores
    only) dequantize in-register with the exact same multiply on every
    node, so the LSE merge stays device-invariant across pool shards.
    Returns (acc [B, H, D] f32, m [B, H] f32, l [B, H] f32).
    """
    b, h, d = q.shape
    _, page, hkv, _ = k_pages.shape
    pps = local_table.shape[1]
    g = h // hkv
    sm_scale = 1.0 / math.sqrt(d)

    safe = jnp.where(col_owned, local_table, 0)
    k = k_pages[safe].astype(jnp.float32)        # [B, pps, page, Hkv, D]
    v = v_pages[safe].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[safe][..., None]         # fused dequant, no fp32
        v = v * v_scale[safe][..., None]         # page materialization
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bptkd->bkgpt", qg, k) * sm_scale
    pos = (jnp.arange(pps, dtype=jnp.int32)[:, None] * page +
           jnp.arange(page, dtype=jnp.int32)[None, :])     # [pps, page]
    mask = (pos[None] < lengths[:, None, None]) & col_owned[:, :, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    sf = s.reshape(b, hkv, g, pps * page)
    mf = mask.reshape(b, 1, 1, pps * page)
    m = jnp.max(sf, axis=-1)                               # [b, hkv, g]
    # all-masked rows have m == NEG_INF; exp(NEG_INF - NEG_INF) == 1, so
    # the mask (not the score) must zero those probabilities
    p = jnp.where(mf, jnp.exp(sf - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p,
                     v.reshape(b, pps * page, hkv, d))
    return acc.reshape(b, h, d), m.reshape(b, h), l.reshape(b, h)


def combine_partials(acc, m, l, axis_name: str):
    """Exact cross-node merge of online-softmax partials: rebase every
    node's accumulator to the global max and sum.  Nodes owning nothing
    contribute (0, NEG_INF, 0) and vanish; a fully-masked (padding) slot
    ends with l == 0 and yields 0, matching the Pallas kernel's
    ``acc / max(l, 1e-30)`` convention."""
    m_glob = lax.pmax(m, axis_name)
    scale = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * scale, axis_name)
    acc_glob = lax.psum(acc * scale[..., None], axis_name)
    return acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]


def normalize_partials(acc, m, l):
    """Single-node closure of the partial contract: with every page
    owned locally, normalizing the accumulator *is* the full softmax
    (same ``acc / max(l, 1e-30)`` convention as the Pallas kernel)."""
    del m  # the local max cancels in acc / l
    return acc / jnp.maximum(l, 1e-30)[..., None]


def make_serving_fns(model, mesh=None):
    """Returns (prefill_fn, decode_fn), jitted; sharded when mesh given."""
    if mesh is None:
        return (jax.jit(model.prefill), jax.jit(model.decode_step,
                                                donate_argnums=(1,)))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.param_specs(mesh, params_shape))

    prefill = jax.jit(model.prefill, in_shardings=(pshard, None))

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    decode_j = jax.jit(decode, donate_argnums=(1,),
                       in_shardings=(pshard, None, None))
    return prefill, decode_j


def _pow2(n: int) -> int:
    """Smallest power of two >= n (shape bucketing to bound retraces)."""
    return 1 << max(0, n - 1).bit_length()


def _pow2_floor(n: int) -> int:
    """Largest power of two <= n (horizon bucketing: a tail horizon
    runs as pow2 chunks — e.g. 5 -> 4 then 1 — so the compiled-program
    set stays O(log) *without* masked surplus steps burning full model
    forwards)."""
    return 1 << (max(n, 1).bit_length() - 1)


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """On-device token selection, threaded through ``decode`` /
    ``horizon_batch`` / ``spec_horizon_batch``.

    ``temperature <= 0`` is greedy argmax — the default, bit-identical
    to the historical ``greedy=True`` path.  ``temperature > 0``
    samples on device via Gumbel-max over the temperature-scaled,
    top-p-filtered distribution; the PRNG key derives from ``seed``
    (folded with the pass index host-side, the step index on device),
    so every pool node draws the identical sample from the merged
    logits and tokens stay device-invariant across shards."""
    temperature: float = 0.0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingConfig()


def sampling_log_probs(logits, temperature, top_p):
    """Log-probs of the temperature/top-p target distribution.

    ``logits`` [..., V]; ``temperature``/``top_p`` [] f32 arrays
    (traced, so toggling sampling never retraces).  Tokens outside the
    nucleus — the smallest probability-sorted set with mass >=
    ``top_p`` (cutoff ties all kept) — go to NEG_INF and the rest
    renormalize.  This IS the distribution speculative acceptance must
    be correct against, so the verify pass scores drafted tokens with
    exactly these probabilities."""
    t = jnp.maximum(temperature.astype(jnp.float32), 1e-6)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32) / t, axis=-1)
    p = jnp.exp(lp)
    srt = jnp.sort(p, axis=-1)[..., ::-1]
    mass_before = jnp.cumsum(srt, axis=-1) - srt
    cut = jnp.min(jnp.where(mass_before < top_p, srt, jnp.inf),
                  axis=-1, keepdims=True)
    lp = jnp.where(p >= cut, lp, NEG_INF)
    return lp - jax.nn.logsumexp(lp, axis=-1, keepdims=True)


def sampled_token(logits, sampling, stream: int, position: int) -> int:
    """Host-side mirror of the device sampler for ONE token: the token
    at absolute ``position`` of sequence ``stream``, drawn from
    ``logits`` [V] under ``sampling`` with the same
    per-(sequence, position) Gumbel-max key the fused scaffold uses.
    Greedy configs reduce to plain argmax.

    This is the admission-time selection a scheduler needs: the token
    after a (re-)prefill is chosen from host-visible logits, and it
    must equal the draw the device would have made at that position —
    otherwise a failover-requeued sequence resuming at temperature > 0
    would diverge from the uninterrupted run."""
    row = jnp.asarray(logits).reshape(-1)
    if sampling is None or sampling.greedy:
        return int(jnp.argmax(row))
    lp = sampling_log_probs(row, jnp.float32(sampling.temperature),
                            jnp.float32(sampling.top_p))
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(sampling.seed),
                           int(stream) & 0x7FFFFFFF), int(position))
    g = jax.random.gumbel(key, lp.shape, jnp.float32)
    return int(jnp.argmax(lp + g))


# n-gram drafter tuning: a candidate site must match at least
# SPEC_MIN_MATCH trailing history tokens (a bigram minimum drowns in
# spurious matches on non-repetitive text — every false draft burns a
# verify position), and match quality is scored up to SPEC_MAX_MATCH
# trailing tokens (longer suffix agreement disambiguates cycles whose
# bigrams recur with different successors)
SPEC_MIN_MATCH = 3
SPEC_MAX_MATCH = 8


def draft_ngram(hist, hist_len, n_draft: int):
    """Device-side n-gram / prompt-lookup drafter.

    Suffix-match over the sequence's own prompt+generated token
    history: find the earlier site whose trailing tokens agree with the
    history's suffix on the longest run (scored up to
    ``SPEC_MAX_MATCH``, required >= ``SPEC_MIN_MATCH``; ties prefer a
    site with a full ``n_draft`` of successor tokens, then the latest
    one) and propose the tokens that followed it.  The history rides in
    as a replicated device table, so drafting costs zero host
    round-trips and every pool shard derives the identical candidates.

    hist: [B, T] int32 (prompt + generated incl. the pending token,
    garbage past ``hist_len``); hist_len: [B] int32.  Returns
    [B, n_draft] int32 candidates, -1 where nothing matched (a -1
    candidate can never equal a real token, so the verify pass rejects
    it for free)."""
    b, t = hist.shape
    ar = jnp.arange(t, dtype=jnp.int32)
    k = int(min(SPEC_MAX_MATCH, t))
    # suffix tokens newest-first: last_js[:, j] = hist[hl - 1 - j]
    idx = jnp.clip(hist_len[:, None] - 1 - jnp.arange(k)[None, :],
                   0, t - 1)
    last_js = jnp.take_along_axis(hist, idx, axis=1)         # [B, K]
    run = jnp.ones((b, t), bool)
    mlen = jnp.zeros((b, t), jnp.int32)
    for j in range(k):
        # hj[:, i] = hist[:, i - j] (the token j back from site i)
        hj = (jnp.pad(hist, ((0, 0), (j, 0)),
                      constant_values=-1)[:, :t] if j else hist)
        e = ((hj == last_js[:, j:j + 1]) & (ar[None, :] >= j) &
             ((hist_len[:, None] - 1 - j) >= 0))
        run = run & e
        mlen = mlen + run.astype(jnp.int32)
    valid = ((mlen >= SPEC_MIN_MATCH) & (ar[None, :] >= 1) &
             (ar[None, :] < (hist_len - 1)[:, None]))
    # successor tokens actually available after site i — the draft
    # length this site can fill.  Ranked FIRST: on a repeating stream
    # the deepest matches crowd the history tail where there is nothing
    # left to copy, so runway (how much we can draft) outranks match
    # depth (how sure we are), with depth and recency as tiebreaks
    runway = jnp.clip((hist_len[:, None] - 1) - ar[None, :], 0, n_draft)
    score = jnp.where(
        valid,
        (runway * (SPEC_MAX_MATCH + 1) + mlen) * t + ar[None, :], -1)
    best = jnp.max(score, axis=1)                            # [B]
    match = jnp.where(best >= 0, best % t, -1)
    di = match[:, None] + 1 + jnp.arange(n_draft, dtype=jnp.int32)[None]
    ok = (match >= 1)[:, None] & (di < hist_len[:, None])
    cand = jnp.take_along_axis(hist, jnp.clip(di, 0, t - 1), axis=1)
    return jnp.where(ok, cand, -1).astype(jnp.int32)


class PagedServer:
    """Tiered-KV serving for a TransformerLM on one device.

    All layers share one page table: a physical page id addresses the
    stacked KV ``[n_layers, page, Hkv, D]`` of that extent, so host<->HBM
    tiering moves whole stacked pages and the jitted step needs exactly
    one table per batch.  Batch size and table width are bucketed to
    powers of two, so the decode step compiles O(log) times, not per
    shape.
    """

    def __init__(self, model, params, *, page_size: int = 16,
                 hbm_pages: Optional[int] = None, dtype=jnp.float32,
                 hbm_pages_per_layer: Optional[int] = None,
                 prefix_cache: bool = True, page_dtype: str = "fp32",
                 hbm_bytes: Optional[int] = None):
        if page_dtype not in PAGE_DTYPES:
            raise ValueError(f"page_dtype must be one of {PAGE_DTYPES}, "
                             f"got {page_dtype!r}")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.dtype = dtype
        self.page = page_size
        self.page_dtype = page_dtype
        self.quantized = page_dtype in ("int8", "fp8")
        if hbm_bytes is not None:
            # capacity is a byte budget, not a page count: the window
            # holds however many (dtype-aware) stacked pages fit — the
            # quantized format's 2-4x page-count payoff at equal HBM
            pb = PageStore.stacked_page_bytes(
                n_layers=self.cfg.n_layers, page_size=page_size,
                n_kv_heads=self.cfg.n_kv_heads, head_dim=self.cfg.hd,
                dtype=dtype, page_dtype=page_dtype)
            hbm_pages = max(1, int(hbm_bytes) // pb)
        elif hbm_pages is None:
            hbm_pages = (hbm_pages_per_layer
                         if hbm_pages_per_layer is not None else 64)
        self.hbm_pages = hbm_pages
        # prefix_cache=False ablates the shared-prefix page cache (every
        # admission computes every prompt token — the cold baseline the
        # benchmark's warm-speedup floor is measured against)
        self.prefix_cache = prefix_cache
        self.store = self._new_store()
        self.table = self._new_table()
        self._seqs: List[int] = []
        self._pending: Dict[int, int] = {}
        # prompt tokens of admissions whose chunked prefill is still
        # in flight (progress = the table's committed length);
        # _prefill_unmatched marks the ones whose lazy prefix match has
        # not run yet
        self._prefill_state: Dict[int, np.ndarray] = {}
        self._prefill_unmatched: set = set()
        self.prefill_tokens_computed = 0
        self._interpret = jax.default_backend() != "tpu"
        # donating the page state lets XLA update the store in place;
        # CPU jit ignores donation (with a warning), so only opt in on
        # accelerators.
        donate = (1,) if not self._interpret else ()
        self._decode_jit = jax.jit(self.decode_step, donate_argnums=donate)
        self._chunk_jit = jax.jit(self.prefill_chunk_step,
                                  donate_argnums=donate)
        self._horizon_jit = jax.jit(self.decode_horizon_step,
                                    static_argnames=("horizon",),
                                    donate_argnums=donate)
        self._spec_jit = jax.jit(self.decode_spec_step,
                                 static_argnames=("horizon",),
                                 donate_argnums=donate)
        # prompt + generated (incl. pending) tokens per live sequence —
        # the drafter's lookup corpus; uploaded per spec pass like the
        # page table, never read back
        self._history: Dict[int, List[int]] = {}
        self.spec_lookup_window = 256
        # adaptive gate: speculation pays only while drafts land, so a
        # rolling acceptance-rate EMA below the floor routes passes to
        # the plain horizon, with periodic probe passes to reopen
        # the break-even acceptance rate rises with the draft depth (a
        # mostly-rejected H=16 verify costs the same device time as a
        # fallback pass that commits all 16), so the gate closes early
        self.spec_alpha_floor = 0.7
        self.spec_probe_every = 16
        self.spec_stats: Dict[str, object] = {}
        self.reset_speculation_stats()

    def _new_store(self) -> PageStore:
        """The store the config prescribes (used at init and when a failed
        donated step voids the window)."""
        cfg = self.cfg
        return PageStore(n_layers=cfg.n_layers, page_size=self.page,
                         hbm_pages=self.hbm_pages,
                         n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                         dtype=self.dtype, page_dtype=self.page_dtype)

    def _new_table(self) -> PageTableManager:
        """Table-manager factory (PoolServer overrides with a sharded
        manager bound to its placement policy)."""
        return PageTableManager(self.store)

    # -- public capacity API (admission control lives on these) --------------

    def pages_needed(self, n_tokens: int) -> int:
        return self.table.pages_needed(n_tokens)

    def sequence_ids(self) -> List[int]:
        return list(self._seqs)

    def pending_tokens(self) -> Dict[int, int]:
        """Next-token (greedy) continuation for each live sequence — the
        argmax produced by its last prefill/decode step."""
        return dict(self._pending)

    def set_pending(self, seq_id: int, token: int):
        """Override the pending next token for ``seq_id`` — the token
        the next decode call will feed first.  Schedulers doing sampled
        selection host-side (``sampled_token``) use this so the device
        continues from the token they actually reported; the drafter
        history entry mirroring the old pending token is rewritten to
        match (the fed token is what the drafter will see)."""
        tok = int(token)
        hist = self._history.get(seq_id)
        if hist and hist[-1] == self._pending.get(seq_id):
            hist[-1] = tok
        self._pending[seq_id] = tok

    def free_sequence(self, seq_id: int) -> int:
        """Retire a sequence: all its HBM + host-tier pages are released
        and immediately reusable.  Returns the number of pages freed."""
        freed = self.table.free_sequence(seq_id)
        if seq_id in self._seqs:
            self._seqs.remove(seq_id)
        self._pending.pop(seq_id, None)
        self._prefill_state.pop(seq_id, None)
        self._prefill_unmatched.discard(seq_id)
        self._history.pop(seq_id, None)
        return freed

    def _recover_store(self):
        """Failure cleanup for donated jitted calls.  On accelerators the
        step's inputs are donated, so a call that fails *during execution*
        has already consumed the store arrays; the resident page data is
        unrecoverable.  Drop every sequence and reopen an empty window so
        the server stays usable (callers resubmit) instead of poisoning
        all later steps with deleted buffers."""
        if not self.store.is_deleted():
            return
        stats, shard_stats = self.table.stats, self.table.shard_stats
        self.store = self._new_store()
        self.table = self._new_table()
        self.table.stats = stats           # telemetry continuity
        self.table.shard_stats = shard_stats
        self._seqs.clear()
        self._pending.clear()
        self._prefill_state.clear()
        self._prefill_unmatched.clear()
        self._history.clear()

    # -- shared transformer-block halves (used by the jitted decode /
    #    prefill bodies and the eager reference; only the attention
    #    middle differs between them) ----------------------------------------

    def _attn_inputs(self, lp, h, positions):
        """Pre-norm -> q/k/v projections -> RoPE at ``positions``."""
        cfg = self.cfg
        a = L.apply_norm(lp["attn_norm"], h, cfg.norm)
        q, k, v = L._qkv(lp["attn"], a, cfg)
        if cfg.rope:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_out_ffn(self, lp, h, o_flat):
        """Attention output-projection residual + FFN residual.
        o_flat: [B, S, H*D]."""
        cfg = self.cfg
        h = h + o_flat @ lp["attn"]["wo"].astype(h.dtype)
        m = L.apply_norm(lp["mlp_norm"], h, cfg.norm)
        if cfg.is_moe:
            mo, _ = L.apply_moe(lp["mlp"], m, cfg, no_drop=True)
        else:
            mo = L.apply_mlp(lp["mlp"], m, cfg.act)
        return h + mo

    # -- jitted device programs ----------------------------------------------

    def _append_state(self, st, tgt, offs, k_new, v_new):
        """Scatter one new KV position per row into a per-layer page
        state dict (``tgt`` rows at the out-of-bounds sentinel are
        dropped).  Quantized stores quantize **on device at write
        time**: codes and their per-slot scales land in one step, so
        the page arrays never hold full-precision data.
        k_new/v_new: [N, Hkv, D]; tgt/offs: [N]."""
        st = dict(st)
        if self.quantized:
            kq, ks = quantize_page_kv(k_new, self.store.qmax,
                                      self.store.code_dtype)
            vq, vs = quantize_page_kv(v_new, self.store.qmax,
                                      self.store.code_dtype)
            st["k"] = st["k"].at[tgt, offs].set(kq, mode="drop")
            st["v"] = st["v"].at[tgt, offs].set(vq, mode="drop")
            st["ks"] = st["ks"].at[tgt, offs].set(ks, mode="drop")
            st["vs"] = st["vs"].at[tgt, offs].set(vs, mode="drop")
            return st
        st["k"] = st["k"].at[tgt, offs].set(k_new.astype(st["k"].dtype),
                                            mode="drop")
        st["v"] = st["v"].at[tgt, offs].set(v_new.astype(st["v"].dtype),
                                            mode="drop")
        return st

    def _kernel_attention(self, q, st, page_table, lengths):
        """The Pallas paged-attention kernel over one layer's page
        state: the fp kernel for full-precision stores, the fused-
        dequant ``paged_attention_q8`` for quantized ones (codes stream
        HBM->VMEM, scales ride the scalar-prefetch page table, dequant
        happens in-register — HBM traffic is the quantized bytes)."""
        if self.quantized:
            return _paged_q8_inner(q, st["k"], st["v"], st["ks"], st["vs"],
                                   page_table, lengths,
                                   interpret=self._interpret)
        return _paged_inner(q, st["k"], st["v"], page_table, lengths,
                            interpret=self._interpret)

    def decode_step(self, params, state, page_table, lengths, tokens):
        """One fused decode step for the whole active batch — the
        horizon scaffold run at H=1, so per-token/horizon token identity
        holds by construction rather than by test-enforced parallel
        bodies.  The attention is the Pallas ``paged_attention`` kernel
        (it stays the benchmark baseline); longer horizons swap in the
        LSE-partial form via their own hook.

        state: the :meth:`PageStore.device_state` pytree ({"k","v"}
        [L, P, page, Hkv, D] plus {"ks","vs"} [L, P, page, Hkv] when
        quantized); page_table: [B, pps] int32 physical ids; lengths:
        [B] int32 committed length per sequence (0 marks a padding
        slot); tokens: [B] int32.  Returns (logits [B, V] f32, state).
        """
        n_phys = state["k"].shape[1]
        _, logits, state = self._fused_horizon_scan(
            params, state, page_table, lengths, tokens,
            (lengths > 0).astype(jnp.int32), jnp.int32(-1), horizon=1,
            # out-of-bounds sentinel => scatter drops padding slots
            append_target=lambda phys, valid:
                jnp.where(valid, phys, n_phys),
            attention=lambda q, st, new_lengths:
                self._kernel_attention(q, st, page_table, new_lengths))
        return logits, state

    # -- fused decode horizon -------------------------------------------------

    def _horizon_attention(self, q, st, page_table, lengths):
        """Per-step decode attention inside the fused horizon loop.

        Uses the LSE-partial formulation — the same device contract the
        pool hot path runs — normalized locally (exactly the full
        softmax when every page is owned).  On TPU the Pallas
        ``paged_attention`` kernel takes this seam per layer slice; in
        CPU interpret mode the jnp partial path is the realistic fast
        path (the Pallas emulation's per-call cost would otherwise
        dominate the very overhead the horizon amortizes).  Both close
        the same fused-dequant contract on quantized states.
        q: [B, H, D] f32; returns [B, H, D]."""
        if not self._interpret:
            return self._kernel_attention(q, st, page_table, lengths)
        owned = jnp.ones(page_table.shape, bool)
        acc, m, l = paged_attention_partial(
            q, st["k"], st["v"], page_table, owned, lengths,
            k_scale=st.get("ks"), v_scale=st.get("vs"))
        return normalize_partials(acc, m, l).astype(q.dtype)

    def _fused_horizon_scan(self, params, state, page_table, lengths,
                            tokens, budget, eos_id, key=None,
                            temperature=None, top_p=None, streams=None,
                            *, horizon: int,
                            append_target, attention):
        """The fused-step scaffold shared by the single-node and pool
        horizon bodies: one ``lax.scan`` over ``horizon`` decode steps
        where the on-device argmax feeds the next step, page slots
        advance against the reservation, and EOS/budget masks stop
        finished sequences.  The two hooks are the only places the
        paths differ:

        ``append_target(phys, valid) -> [B]`` maps each sequence's tail
        physical page to the scatter row (out-of-bounds sentinel drops
        finished/padding/non-owned appends); ``attention(q, st,
        new_lengths) -> [B, H, D]`` closes the paged-attention contract
        over the per-layer state slice (locally normalized, or
        ownership-masked + pool-merged).

        Returns (emitted [H, B], last step's logits [B, V] f32, state)
        — the logits make H=1 *be* the per-token decode step (one
        scaffold, token identity by construction).

        ``key``/``temperature``/``top_p`` enable on-device sampling:
        each row's draw folds ``(streams[b], absolute position)`` into
        the key — ``streams`` is the [B] stable per-sequence id, the
        position is the emitted token's 1-based index in its sequence —
        so a sampled token is a pure function of (seed, sequence,
        position).  That is what makes sampling reproducible across
        failover re-prefill (same sequence, same positions => same
        draws, regardless of batch slot, pass boundaries or which node
        runs the step) and identical between the plain and speculative
        paths.  ``temperature <= 0`` falls through to the greedy argmax
        *inside* the traced switch, so toggling sampling never retraces
        and greedy outputs stay bit-identical to the key-free program.
        """
        cfg = self.cfg
        b = tokens.shape[0]

        def step(carry, i):
            state, lengths, tokens, budget = carry
            valid = (budget > 0) & (lengths > 0)
            pos = lengths[:, None]
            pidx = lengths // self.page
            offs = lengths % self.page
            phys = jnp.take_along_axis(page_table, pidx[:, None],
                                       axis=1)[:, 0]
            tgt = append_target(phys, valid)
            new_lengths = lengths + valid.astype(jnp.int32)

            h = L.embed_tokens(params["embed"], tokens[:, None], self.dtype)

            def body(hh, xs):
                # the scan slices every state leaf's leading layer axis,
                # so st is this layer's {"k","v"[,"ks","vs"]} pages
                lp, st = xs
                q, k, v = self._attn_inputs(lp, hh, pos)
                st = self._append_state(st, tgt, offs, k[:, 0], v[:, 0])
                o = attention(q[:, 0].astype(self.dtype), st, new_lengths)
                return (self._attn_out_ffn(lp, hh, o.reshape(b, 1, -1)),
                        st)

            h, state = lax.scan(body, h, (params["layers"], state))
            h = L.apply_norm(params["final_norm"], h, cfg.norm)
            logits = L.unembed(params["embed"], params.get("lm_head"), h,
                               cfg.tie_embeddings)[:, 0]
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if key is not None:
                # lax.cond (not where): greedy passes must not pay the
                # top-p sort + Gumbel draw at runtime
                def _sample(lg):
                    lp = sampling_log_probs(lg, temperature, top_p)

                    def draw(s, p, row_lp):
                        k = jax.random.fold_in(
                            jax.random.fold_in(key, s), p)
                        g = jax.random.gumbel(k, row_lp.shape,
                                              jnp.float32)
                        return jnp.argmax(row_lp + g).astype(jnp.int32)
                    # new_lengths is the emitted token's 1-based
                    # position — the coordinate the spec verify path
                    # folds too
                    return jax.vmap(draw)(streams, new_lengths, lp)
                nxt = lax.cond(temperature > 0, _sample,
                               lambda lg: jnp.argmax(
                                   lg, axis=-1).astype(jnp.int32),
                               logits)
            emitted = jnp.where(valid, nxt, -1)
            # the token just emitted consumed one budget slot; EOS zeroes
            # what's left so the next step goes inactive
            budget = jnp.where(valid & (nxt == eos_id), 0,
                               budget - valid.astype(jnp.int32))
            tokens = jnp.where(valid, nxt, tokens)
            return (state, new_lengths, tokens, budget), \
                (emitted, logits.astype(jnp.float32))

        (state, lengths, tokens, budget), (emitted, logits) = \
            lax.scan(step, (state, lengths, tokens, budget),
                     jnp.arange(horizon, dtype=jnp.int32))
        return emitted, logits[-1], state

    def decode_horizon_step(self, params, state, page_table, lengths,
                            tokens, budget, eos_id, key=None,
                            temperature=None, top_p=None, streams=None,
                            *, horizon: int):
        """``horizon`` fused decode steps in ONE device program.

        A single ``lax.scan`` over the horizon: each step appends the
        fed token's K/V against the pre-reserved page table (page-slot
        advance on device — ``lengths // page`` indexes into the
        horizon reservation), runs the layer stack, takes the greedy
        argmax **on device**, and feeds it to the next step.  Per-
        sequence EOS and token budgets are masked on device too, so a
        finished sequence stops appending mid-horizon without a host
        round-trip.  Exactly one token transfer happens per horizon:
        the stacked [horizon, B] emissions (-1 marks "no token").

        page_table: [B, pps] physical ids covering the *reservation*
        (``PageTableManager.reserve_horizon``); lengths: [B] committed
        lengths (0 marks padding slots); tokens: [B] the pending token
        per sequence; budget: [B] int32 tokens this sequence may still
        produce (device-side min of max_tokens and the caller's ask);
        eos_id: [] int32, -1 disables EOS stopping.

        Returns (emitted [horizon, B] int32, last step's logits [B, V],
        state).
        """
        n_phys = state["k"].shape[1]
        return self._fused_horizon_scan(
            params, state, page_table, lengths, tokens,
            budget, eos_id, key, temperature, top_p, streams,
            horizon=horizon,
            # out-of-bounds sentinel => scatter drops finished/padding
            append_target=lambda phys, valid:
                jnp.where(valid, phys, n_phys),
            attention=lambda q, st, new_lengths:
                self._horizon_attention(q, st, page_table, new_lengths))

    # -- speculative decoding (draft-verify on the horizon scaffold) ----------

    def _spec_verify_scan(self, params, state, page_table, lengths,
                          tokens, budget, eos_id, hist, hist_len, key,
                          temperature, top_p, streams=None, *,
                          horizon: int,
                          append_target, attention):
        """The draft-verify scaffold shared by the single-node and pool
        speculative bodies (the hooks mirror
        :meth:`_prefill_chunk_scan`'s — speculation verifies a
        *chunk-shaped* batch of candidate positions, not a sequential
        horizon).

        One pass: ``draft_ngram`` proposes ``horizon-1`` candidates per
        sequence from the device-resident history table; the fed block
        ``[pending, d_1 .. d_{H-1}]`` runs the layer stack as ``horizon``
        decode-shaped queries with per-position causal lengths (one
        ``lax.scan`` over layers — the H-position forward costs one
        model pass, which is the entire speedup); position ``j``'s
        logits then judge candidate ``d_{j+1}``.  Acceptance on device:
        greedy mode accepts while ``argmax == candidate``; sampling
        mode uses *Gumbel coupling* — pre-draw the target token from
        the same per-(stream, position) key the plain fused horizon
        folds, accept a candidate iff it equals that target, and emit
        the target either way.  For a point-mass draft this IS
        rejection sampling (a candidate ``d`` is accepted with
        probability exactly ``p(d)``, and the emitted marginal is the
        sampling target), with the stronger property that the sampled
        stream is token-identical to the non-speculative path — the
        invariant failover requeue and the chaos suite check.  The
        longest ok-prefix plus the bonus token from the first mismatch
        is emitted; everything downstream of the first break is masked
        to -1 so ``commit_horizon`` rolls its pages back.

        Returns (packed [horizon+1, B] int32 — emitted rows then the
        per-sequence drafted-count row, ONE device->host transfer —
        and the page state).
        """
        cfg = self.cfg
        b = tokens.shape[0]
        pps = page_table.shape[1]
        hzn = horizon
        hkv, hd, nh = cfg.n_kv_heads, cfg.hd, cfg.n_heads

        draft = draft_ngram(hist, hist_len, hzn - 1)          # [B, H-1]
        n_drafted = jnp.sum((draft >= 0).astype(jnp.int32), axis=1)
        fed = jnp.concatenate([tokens[:, None], jnp.maximum(draft, 0)],
                              axis=1)                          # [B, H]
        steps = jnp.arange(hzn, dtype=jnp.int32)[None, :]      # [1, H]
        pos = lengths[:, None] + steps                         # [B, H]
        # appends stay inside the reservation: a position past the
        # budget was never reserved a page, so it must not scatter
        append_ok = (steps < budget[:, None]) & (lengths[:, None] > 0)
        pidx = jnp.clip(pos // self.page, 0, pps - 1)
        offs = (pos % self.page).reshape(-1)
        phys = jnp.take_along_axis(page_table, pidx, axis=1)
        tgt = append_target(phys.reshape(-1), append_ok.reshape(-1))
        # per-position causal extent; 0 fully masks dead positions
        row_lengths = jnp.where(append_ok, pos + 1, 0).reshape(-1)

        h = L.embed_tokens(params["embed"], fed, self.dtype)

        def body(hh, xs):
            lp, st = xs
            q, k, v = self._attn_inputs(lp, hh, pos)
            st = self._append_state(st, tgt, offs,
                                    k.reshape(b * hzn, hkv, hd),
                                    v.reshape(b * hzn, hkv, hd))
            o = attention(q.reshape(b * hzn, nh, hd).astype(self.dtype),
                          st, row_lengths)
            return self._attn_out_ffn(lp, hh, o.reshape(b, hzn, -1)), st

        h, state = lax.scan(body, h, (params["layers"], state))
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           cfg.tie_embeddings).astype(jnp.float32)

        greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # candidate that position j's logits verify: d_{j+1}; the last
        # position has none (its emission is the bonus token)
        d_next = jnp.concatenate(
            [draft, jnp.full((b, 1), -1, jnp.int32)], axis=1)  # [B, H]

        has_draft = d_next >= 0

        def _greedy_sel(lg):
            return greedy_tok == d_next, greedy_tok

        def _sample_sel(lg):
            # Gumbel coupling: one pre-drawn target per (stream,
            # absolute position) — position j's emission lands at
            # 1-based position pos[:, j] + 1, the coordinate the plain
            # fused horizon folds — every pool node draws the same
            lp = sampling_log_probs(lg, temperature, top_p)

            def draw_row(s, row_pos, row_lp):
                def one(p, l):
                    k = jax.random.fold_in(jax.random.fold_in(key, s),
                                           p)
                    g = jax.random.gumbel(k, l.shape, jnp.float32)
                    return jnp.argmax(l + g).astype(jnp.int32)
                return jax.vmap(one)(row_pos + 1, row_lp)
            target = jax.vmap(draw_row)(streams, pos, lp)      # [B, H]
            return target == d_next, target

        # lax.cond (not where): a greedy pass must not pay the top-p
        # sort + H Gumbel draws at runtime
        accept_raw, out_tok = lax.cond(temperature > 0, _sample_sel,
                                       _greedy_sel, logits)
        accept = accept_raw & has_draft                        # [B, H]

        # longest ok-prefix: position j emits iff every earlier position
        # accepted its candidate, stayed under budget, and did not EOS
        live0 = (budget > 0) & (lengths > 0)
        cont = accept & (out_tok != eos_id) & (steps + 1 < budget[:, None])
        chain = jnp.cumprod(cont.astype(jnp.int32), axis=1)
        ok = live0[:, None] & jnp.concatenate(
            [jnp.ones((b, 1), bool), chain[:, :-1].astype(bool)], axis=1)
        emitted = jnp.where(ok, out_tok, -1).astype(jnp.int32)
        packed = jnp.concatenate([emitted.T, n_drafted[None, :]], axis=0)
        return packed, state

    def decode_spec_step(self, params, state, page_table, lengths,
                         tokens, budget, eos_id, hist, hist_len, key,
                         temperature, top_p, streams=None, *,
                         horizon: int):
        """One jitted speculative draft-verify pass on one device.

        Arguments as :meth:`decode_horizon_step` plus ``hist``
        [B, T] int32 / ``hist_len`` [B] (the drafter's history table),
        ``key`` (the pass PRNG key) and ``temperature``/``top_p`` []
        f32.  Returns (packed [horizon+1, B] int32, state) — see
        :meth:`_spec_verify_scan`.
        """
        n_phys = state["k"].shape[1]
        # every flattened query row attends over its sequence's table
        rows_table = jnp.repeat(page_table, horizon, axis=0)
        return self._spec_verify_scan(
            params, state, page_table, lengths, tokens, budget, eos_id,
            hist, hist_len, key, temperature, top_p, streams,
            horizon=horizon,
            append_target=lambda phys, valid:
                jnp.where(valid, phys, n_phys),
            attention=lambda q, st, row_lengths:
                self._horizon_attention(q, st, rows_table, row_lengths))

    def _prefill_chunk_scan(self, params, state, page_row, tokens, start,
                            n_valid, *, append_target, attention):
        """The prefill-chunk scaffold shared by the single-node and pool
        chunk bodies (the chunk-shaped sibling of
        :meth:`_fused_horizon_scan`, with the same two hooks): append
        the chunk's K/V into the sequence's pages, then attend every
        chunk position over the *paged* context — the cached/committed
        prefix plus the chunk itself, causally — as decode-shaped
        queries with per-position length ``pos+1``.

        ``append_target(phys, valid) -> [C]`` maps each position's
        destination page to the scatter row (sentinel drops padding /
        non-owned writes); ``attention(q, st, table, lengths) ->
        [C, H, D]`` closes the paged-attention contract over the
        per-layer state slice.
        """
        cfg = self.cfg
        c = tokens.shape[1]
        pps = page_row.shape[0]
        pos_i = jnp.arange(c, dtype=jnp.int32)
        wpos = start + pos_i                      # absolute positions
        positions = wpos[None, :]
        valid_w = pos_i < n_valid
        pidx = jnp.clip(wpos // self.page, 0, pps - 1)
        offs = wpos % self.page
        phys_w = append_target(page_row[pidx], valid_w)
        # per-position causal extent; 0 fully masks padding queries
        lengths_q = jnp.where(valid_w, wpos + 1, 0)
        table = jnp.broadcast_to(page_row[None, :], (c, pps))

        h = L.embed_tokens(params["embed"], tokens, self.dtype)

        def body(hh, xs):
            lp, st = xs
            q, k, v = self._attn_inputs(lp, hh, positions)
            st = self._append_state(st, phys_w, offs, k[0], v[0])
            o = attention(q[0].astype(self.dtype), st, table, lengths_q)
            return self._attn_out_ffn(lp, hh, o.reshape(1, c, -1)), st

        h, state = lax.scan(body, h, (params["layers"], state))
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        last = lax.dynamic_slice_in_dim(h, n_valid - 1, 1, axis=1)
        logits = L.unembed(params["embed"], params.get("lm_head"), last,
                           cfg.tie_embeddings)[0, 0]
        return logits.astype(jnp.float32), state

    def prefill_chunk_step(self, params, state, page_row, tokens, start,
                           n_valid):
        """One jitted prefill chunk on one device.

        page_row: [pps] int32 physical ids covering positions
        [0, start + n_valid); tokens: [1, C] int32 (C a pow2 bucket,
        garbage past n_valid); start: [] int32 committed tokens before
        this chunk; n_valid: [] int32 true chunk length.  Returns
        (last-valid-position logits [V] f32, state).
        """
        n_phys = state["k"].shape[1]
        return self._prefill_chunk_scan(
            params, state, page_row, tokens, start, n_valid,
            # out-of-bounds sentinel => the scatter drops chunk padding
            append_target=lambda phys, valid:
                jnp.where(valid, phys, n_phys),
            attention=self._horizon_attention)

    # -- request handling -----------------------------------------------------

    def begin_request(self, seq_id: int, prompt: np.ndarray) -> int:
        """Open an admission: queue the prompt for :meth:`prefill_chunk`
        calls.  The cached-prefix match itself runs lazily at the first
        chunk — a queued admission neither holds shared pages (they
        would be unevictable) nor misses pages an admission ahead of it
        in the queue is still about to register.  Returns the number of
        prompt tokens the cache covers *right now* (telemetry/routing;
        the lazy match can only cover more)."""
        prompt = np.asarray(prompt, np.int32)
        assert int(prompt.shape[0]) >= 1, "empty prompt"
        self.table.add_sequence(seq_id)
        self._seqs.append(seq_id)
        self._prefill_state[seq_id] = prompt
        self._history[seq_id] = [int(t) for t in prompt]
        if not self.prefix_cache:
            return 0
        self._prefill_unmatched.add(seq_id)
        return self.table.probe_prefix(seq_id, prompt)

    def prefill_pending(self, seq_id: int) -> int:
        """Prompt tokens still to prefill (0 = admission complete)."""
        prompt = self._prefill_state.get(seq_id)
        if prompt is None:
            return 0
        return int(prompt.shape[0]) - self.table.length(seq_id)

    def prefill_chunk(self, seq_id: int, chunk: Optional[int] = None):
        """Run ONE jitted prefill chunk of at most ``chunk`` tokens
        (default: the whole remaining suffix).  The chunk length is
        bucketed UP to a power of two and the page row to a pow2 width,
        so admissions of any prompt length compile O(log) programs.
        Returns the last prompt position's logits [V] when this chunk
        completes the prompt, else None.

        Like the kernel view it feeds, the active working set must fit
        the HBM window (admission control's ``pages_needed`` contract);
        a prompt needing more pages than the window raises the same
        pinned-working-set error the per-token path raises.
        """
        prompt = self._prefill_state[seq_id]
        s = int(prompt.shape[0])
        if seq_id in self._prefill_unmatched:
            # lazy cached-prefix match (see begin_request): map shares,
            # skip their prefill compute entirely
            self._prefill_unmatched.discard(seq_id)
            try:
                self.table.match_prefix(seq_id, prompt)
            except Exception:
                self.free_sequence(seq_id)
                raise
        start = self.table.length(seq_id)
        c = s - start if chunk is None else min(int(chunk), s - start)
        try:
            try:
                rows = self.table.ensure_resident(seq_id, pin=True,
                                                  n_tokens=start + c)
                if start % self.page:
                    # the chunk's first write lands mid-page: CoW-split
                    # a shared prefix tail before the device touches it
                    self.table.make_writable(seq_id, start // self.page)
                    rows = self.table.row(seq_id, len(rows))
            finally:
                self.table.unpin_all()
            row = np.zeros((_pow2(len(rows)),), np.int32)
            row[:len(rows)] = rows
            tokens = np.zeros((1, _pow2(c)), np.int32)
            tokens[0, :c] = prompt[start:start + c]
            logits, state = self._chunk_jit(
                self.params, self.store.device_state(),
                jnp.asarray(row), jnp.asarray(tokens),
                jnp.asarray(start, jnp.int32), jnp.asarray(c, jnp.int32))
        except Exception:
            # rejected admissions must not leak window pages or leave a
            # zero-length ghost in the live set; a failure inside the
            # donated jit call additionally voids the store
            self.free_sequence(seq_id)
            self._recover_store()
            raise
        self.store.adopt(state)
        self.table.set_length(seq_id, start + c)
        self.prefill_tokens_computed += c
        if start + c < s:
            return None
        # admission complete: index the prompt's pages for later sharers
        del self._prefill_state[seq_id]
        if self.prefix_cache:
            self.table.register_prefix(seq_id, prompt)
        self._pending[seq_id] = int(jnp.argmax(logits))
        if seq_id in self._history:
            # the pending token is the first generated one: it will be
            # fed (and is thus drafter-visible) before it is re-emitted
            self._history[seq_id].append(self._pending[seq_id])
        return logits

    def add_request(self, seq_id: int, prompt: np.ndarray, *,
                    chunk: Optional[int] = None):
        """Admit a sequence: cached-prefix match, then chunked jitted
        prefill of only the uncached suffix (``chunk=None`` runs the
        suffix as a single chunk — the blocking admission of the
        pre-chunking servers; schedulers that interleave admission with
        decode drive :meth:`begin_request`/:meth:`prefill_chunk`
        directly).  Returns the last prompt position's logits [V]."""
        self.begin_request(seq_id, prompt)
        logits = None
        while logits is None:
            logits = self.prefill_chunk(seq_id, chunk)
        return logits

    def prefix_hit_rate(self) -> float:
        """Fraction of all admitted prompt tokens served from the
        prefix cache instead of computed."""
        saved = self.table.stats.prefix_tokens
        total = saved + self.prefill_tokens_computed
        return saved / total if total else 0.0

    # -- one committed batched step -------------------------------------------

    def _plan_step(self, seqs: List[int]):
        """Host-side page management for one decode step: make every
        active page resident + pinned, then build the padded device
        inputs.  Shapes are bucketed to powers of two."""
        try:
            rows = [self.table.prepare_append(s) for s in seqs]
        except Exception:
            self.table.unpin_all()
            raise
        lengths = [self.table.length(s) for s in seqs]
        pps = _pow2(max(len(r) for r in rows))
        b2 = _pow2(len(seqs))
        table = np.zeros((b2, pps), np.int32)
        for i, r in enumerate(rows):
            table[i, :len(r)] = r
        lens = np.zeros((b2,), np.int32)
        lens[:len(seqs)] = lengths
        return jnp.asarray(table), jnp.asarray(lens)

    def step_batch(self, tokens: Dict[int, int]):
        """Feed one token per sequence through a single jitted step and
        commit the appends.  Returns (seq_ids, logits [B, V]) — one
        device array, so callers sample with one transfer."""
        seqs = list(tokens)
        page_table, lengths = self._plan_step(seqs)
        try:
            toks = np.zeros((lengths.shape[0],), np.int32)
            toks[:len(seqs)] = [tokens[s] for s in seqs]
            logits, state = self._decode_jit(
                self.params, self.store.device_state(),
                page_table, lengths, jnp.asarray(toks))
            self.store.adopt(state)
            for s in seqs:
                self.table.commit_append(s)
        except Exception:
            self._recover_store()
            raise
        finally:
            self.table.unpin_all()
        return seqs, logits[:len(seqs)]

    def step(self, tokens: Dict[int, int]) -> Dict[int, jnp.ndarray]:
        """Dict-shaped wrapper of :meth:`step_batch`:
        returns {seq_id: logits [V]}."""
        seqs, logits = self.step_batch(tokens)
        return {s: logits[i] for i, s in enumerate(seqs)}

    def step_reference(self, tokens: Dict[int, int]) -> jnp.ndarray:
        """Unjitted reference of one decode step on the *seed* schedule:
        Python loop over layers, per-layer param slicing, one eager
        scalar append per sequence, per-layer page-table rebuild.  Does
        NOT commit — used for equivalence tests and as the benchmark
        baseline.  Returns logits [B, V] in ``tokens`` order."""
        cfg = self.cfg
        seqs = list(tokens)
        try:
            rows = [self.table.prepare_append(s) for s in seqs]
            lengths = [self.table.length(s) for s in seqs]
            pos = jnp.asarray([[l] for l in lengths], jnp.int32)
            b = len(seqs)
            toks = jnp.asarray([tokens[s] for s in seqs], jnp.int32)
            new_lengths = jnp.asarray([l + 1 for l in lengths], jnp.int32)
            h = L.embed_tokens(self.params["embed"], toks[:, None],
                               self.dtype)
            for li in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[li], self.params["layers"])
                st = self.store.layer_state(li)
                q, k, v = self._attn_inputs(lp, h, pos)
                # seed schedule: one scalar append per sequence
                for bi, (l, row) in enumerate(zip(lengths, rows)):
                    st = self._append_state(
                        st, jnp.asarray([row[l // self.page]], jnp.int32),
                        jnp.asarray([l % self.page], jnp.int32),
                        k[bi:bi + 1, 0], v[bi:bi + 1, 0])
                # seed schedule: page table rebuilt per layer
                max_pages = max(len(r) for r in rows)
                page_table = jnp.asarray(
                    [r + [0] * (max_pages - len(r)) for r in rows],
                    jnp.int32)
                if self.quantized:
                    # pure-jnp dequantizing oracle — the reference the
                    # fused-dequant Pallas kernel is held to (<=1e-4)
                    o = kref.paged_attention_q8_ref(
                        q[:, 0].astype(self.dtype), st["k"], st["v"],
                        st["ks"], st["vs"], page_table, new_lengths)
                else:
                    o = ops.paged_attention(q[:, 0].astype(self.dtype),
                                            st["k"], st["v"], page_table,
                                            new_lengths)
                h = self._attn_out_ffn(lp, h, o.reshape(b, 1, -1))
            h = L.apply_norm(self.params["final_norm"], h, cfg.norm)
            logits = L.unembed(self.params["embed"],
                               self.params.get("lm_head"), h,
                               cfg.tie_embeddings)[:, 0]
        finally:
            self.table.unpin_all()
        return logits

    # -- one committed horizon batch ------------------------------------------

    def _plan_horizon(self, seqs: List[int], budgets: Dict[int, int]):
        """Host-side page management for one fused horizon: reserve + pin
        every page the horizon can touch (``reserve_horizon``), then
        build the padded device inputs.  Shapes are bucketed to powers
        of two, so horizons over 3 and 4 active sequences share one
        compiled program."""
        try:
            rows = [self.table.reserve_horizon(s, budgets[s]) for s in seqs]
        except Exception:
            # a failed reservation (e.g. pinned working set overflow on a
            # later sequence) must not leave earlier sequences' data-less
            # reserved pages resident: roll every reservation back to the
            # committed lengths before re-raising
            for s in seqs:
                self.table.commit_horizon(s, 0)
            self.table.unpin_all()
            raise
        lengths = [self.table.length(s) for s in seqs]
        pps = _pow2(max(len(r) for r in rows))
        b2 = _pow2(len(seqs))
        table = np.zeros((b2, pps), np.int32)
        for i, r in enumerate(rows):
            table[i, :len(r)] = r
        lens = np.zeros((b2,), np.int32)
        lens[:len(seqs)] = lengths
        buds = np.zeros((b2,), np.int32)
        buds[:len(seqs)] = [budgets[s] for s in seqs]
        return jnp.asarray(table), jnp.asarray(lens), jnp.asarray(buds)

    @staticmethod
    def _stream_ids(seqs, b2: int):
        """[b2] int32 per-row sampling-stream ids: the sequence id,
        stable across requeue/re-prefill and independent of batch slot
        — the coordinate that makes sampled draws failover-
        reproducible (padding rows never sample; any id works)."""
        streams = np.zeros((b2,), np.int32)
        streams[:len(seqs)] = [int(s) & 0x7FFFFFFF for s in seqs]
        return jnp.asarray(streams)

    def horizon_batch(self, tokens: Dict[int, int],
                      budgets: Dict[int, int], horizon: int,
                      eos_id: Optional[int] = None,
                      sampling: Optional[SamplingConfig] = None,
                      _key=None) -> Dict[int, List[int]]:
        """Run one fused decode horizon over ``tokens`` ({seq: pending
        token}) and commit the appends.  ``budgets[s]`` caps how many
        tokens sequence ``s`` may produce (<= horizon); ``eos_id`` stops
        a sequence on device when it emits that token.  ``sampling``
        selects on-device greedy argmax (default) or temperature/top-p
        Gumbel sampling; ``_key`` overrides the pass PRNG key (the
        ``decode`` loop threads one per pass).  Returns
        {seq_id: emitted tokens} — one device->host transfer total.

        The traced horizon length is bucketed DOWN to a power of two
        (the ``decode`` loop covers the rest with further — smaller —
        pow2 horizons), so mixed tails neither retrace the program nor
        burn masked full-model steps.
        """
        sampling = sampling or GREEDY
        seqs = list(tokens)
        if _key is None:
            _key = jax.random.PRNGKey(sampling.seed)
        h_run = _pow2_floor(min(horizon, max(budgets[s] for s in seqs)))
        page_table, lengths, buds = self._plan_horizon(
            seqs, {s: min(budgets[s], h_run) for s in seqs})
        try:
            toks = np.zeros((lengths.shape[0],), np.int32)
            toks[:len(seqs)] = [tokens[s] for s in seqs]
            eos = np.int32(eos_id if eos_id is not None else -1)
            emitted, _, state = self._horizon_jit(
                self.params, self.store.device_state(),
                page_table, lengths, jnp.asarray(toks), buds,
                jnp.asarray(eos), _key,
                jnp.float32(sampling.temperature),
                jnp.float32(sampling.top_p),
                self._stream_ids(seqs, lengths.shape[0]),
                horizon=h_run)
            # THE one transfer of the horizon: [h_run, B] int32 tokens
            emitted = np.asarray(emitted)
            self.store.adopt(state)
            out = {}
            for i, s in enumerate(seqs):
                got = [int(t) for t in emitted[:, i] if t >= 0]
                out[s] = got
                if s in self._history:
                    self._history[s].extend(got)
                # committed appends == emitted tokens (each fused step
                # feeds one token and emits one); rollback the unused
                # tail of the reservation
                self.table.commit_horizon(s, len(got))
        except Exception:
            self._recover_store()
            # store intact (the failure was not a donated-buffer loss):
            # roll back every surviving sequence's unused reservation so
            # no data-less pages stay resident
            for s in seqs:
                if s in self._seqs:
                    self.table.commit_horizon(s, 0)
            raise
        finally:
            self.table.unpin_all()
        return out

    # -- one committed speculative pass ---------------------------------------

    def _host_can_draft(self, seq_id: int) -> bool:
        """Host-side mirror of the device drafter's match predicate:
        does the lookup window contain an earlier occurrence of the
        history's final ``SPEC_MIN_MATCH``-gram?  Used only for the
        adaptive fallback — when NO live sequence can draft, a
        speculative pass would burn an H-position forward for one token
        each, so the pass routes through the plain fused horizon
        instead."""
        h = self._history.get(seq_id)
        if h is None or len(h) < SPEC_MIN_MATCH + 1:
            return False
        a = np.asarray(h[-self.spec_lookup_window:], np.int64)
        if a.shape[0] < SPEC_MIN_MATCH + 1:
            return False
        m = np.ones((a.shape[0] - SPEC_MIN_MATCH,), bool)
        for j in range(SPEC_MIN_MATCH):
            lo, hi = SPEC_MIN_MATCH - 1 - j, a.shape[0] - 1 - j
            m &= a[lo:hi] == a[-1 - j]
        return bool(m.any())

    def spec_horizon_batch(self, tokens: Dict[int, int],
                           budgets: Dict[int, int], horizon: int,
                           eos_id: Optional[int] = None,
                           sampling: Optional[SamplingConfig] = None,
                           _key=None) -> Dict[int, List[int]]:
        """Run one speculative draft-verify pass (arguments as
        :meth:`horizon_batch`) and commit the accepted prefixes.

        The reservation is the same ``reserve_horizon`` ask the plain
        horizon makes; ``commit_horizon`` keeps only the accepted
        tokens + bonus and rolls the rejected tail's pages back, so
        accepted-length variance never changes device shapes (the jit
        cache is keyed on the pow2 horizon/batch/table buckets only).
        Two adaptive fallbacks hold adversarial (non-repetitive)
        workloads near plain-horizon throughput, both counted in
        ``spec_stats``: when no live sequence's history can produce a
        draft — or the bucketed horizon degenerates below 2 — the pass
        routes to :meth:`horizon_batch`; and when the rolling
        acceptance-rate EMA drops below ``spec_alpha_floor`` the gate
        closes and only every ``spec_probe_every``-th pass still
        speculates (a probe — if the workload turns repetitive the EMA
        recovers and the gate reopens).
        """
        sampling = sampling or GREEDY
        seqs = list(tokens)
        if _key is None:
            _key = jax.random.PRNGKey(sampling.seed)
        h_run = _pow2_floor(min(horizon, max(budgets[s] for s in seqs)))
        gated = self.spec_alpha_ema < self.spec_alpha_floor
        if gated:
            self._spec_probe_tick += 1
        if (h_run < 2 or
                (gated and self._spec_probe_tick % self.spec_probe_every)
                or not any(self._host_can_draft(s) for s in seqs)):
            self.spec_stats["fallback_passes"] += 1
            if gated:
                self.spec_stats["gated_passes"] += 1
            return self.horizon_batch(tokens, budgets, horizon,
                                      eos_id=eos_id, sampling=sampling,
                                      _key=_key)
        page_table, lengths, buds = self._plan_horizon(
            seqs, {s: min(budgets[s], h_run) for s in seqs})
        b2 = int(lengths.shape[0])
        w = self.spec_lookup_window
        hists = [self._history.get(s, [])[-w:] for s in seqs]
        # fixed-width table (pow2 of the lookup window): history growth
        # must never retrace mid-run, and the upload is a few KB anyway
        t2 = _pow2(w)
        hist = np.full((b2, t2), -1, np.int32)
        hlen = np.zeros((b2,), np.int32)
        for i, hh in enumerate(hists):
            hist[i, :len(hh)] = hh
            hlen[i] = len(hh)
        try:
            toks = np.zeros((b2,), np.int32)
            toks[:len(seqs)] = [tokens[s] for s in seqs]
            eos = np.int32(eos_id if eos_id is not None else -1)
            packed, state = self._spec_jit(
                self.params, self.store.device_state(), page_table,
                lengths, jnp.asarray(toks), buds, jnp.asarray(eos),
                jnp.asarray(hist), jnp.asarray(hlen), _key,
                jnp.float32(sampling.temperature),
                jnp.float32(sampling.top_p),
                self._stream_ids(seqs, b2), horizon=h_run)
            # THE one transfer of the pass: [h_run + 1, B] int32
            # (emitted rows + the drafted-count telemetry row)
            packed = np.asarray(packed)
            self.store.adopt(state)
            emitted, n_drafted = packed[:-1], packed[-1]
            out = {}
            st = self.spec_stats
            st["passes"] += 1
            for i, s in enumerate(seqs):
                got = [int(t) for t in emitted[:, i] if t >= 0]
                out[s] = got
                if s in self._history:
                    self._history[s].extend(got)
                # committed appends == accepted prefix + bonus; the
                # rejected tail of the reservation rolls back here
                self.table.commit_horizon(s, len(got))
                drafted = int(n_drafted[i])
                st["drafted"] += drafted
                st["accepted"] += max(0, min(len(got) - 1, drafted))
                st["emitted"] += len(got)
                hist_k = len(got)
                st["accepted_len_hist"][hist_k] = \
                    st["accepted_len_hist"].get(hist_k, 0) + 1
            # rolling acceptance EMA drives the adaptive gate: a pass
            # whose drafts mostly miss pushes the EMA toward closing it
            pass_drafted = int(n_drafted[:len(seqs)].sum())
            if pass_drafted:
                pass_acc = sum(
                    max(0, min(len(out[s]) - 1, int(n_drafted[i])))
                    for i, s in enumerate(seqs)) / pass_drafted
                # fast EMA: a hostile workload must close the gate
                # within a couple of failed passes, not a dozen
                self.spec_alpha_ema = (0.5 * self.spec_alpha_ema +
                                       0.5 * pass_acc)
        except Exception:
            self._recover_store()
            # store intact (the failure was not a donated-buffer loss):
            # roll back every surviving sequence's unused reservation so
            # no data-less pages stay resident
            for s in seqs:
                if s in self._seqs:
                    self.table.commit_horizon(s, 0)
            raise
        finally:
            self.table.unpin_all()
        return out

    def speculation_stats(self) -> Dict[str, object]:
        """Speculative telemetry: pass/fallback counts, drafted vs
        accepted candidates (``alpha`` = acceptance rate), and the
        emitted-length histogram {tokens_per_pass: passes}."""
        st = dict(self.spec_stats)
        st["accepted_len_hist"] = dict(st["accepted_len_hist"])
        st["alpha"] = (st["accepted"] / st["drafted"]
                       if st["drafted"] else 0.0)
        return st

    def reset_speculation_stats(self) -> None:
        """Zero the speculative counters and reopen the adaptive gate
        (EMA back to its optimistic start) — benchmark reps and tests
        that re-admit sequences on a warm server call this so one rep's
        acceptance history never gates the next."""
        self.spec_stats = {
            "passes": 0, "fallback_passes": 0, "gated_passes": 0,
            "drafted": 0, "accepted": 0, "emitted": 0,
            "accepted_len_hist": {}}
        self.spec_alpha_ema = 1.0
        self._spec_probe_tick = 0

    # -- decode loop ----------------------------------------------------------

    def decode(self, n_tokens: int, greedy: Optional[bool] = None,
               seqs: Optional[List[int]] = None, *,
               horizon: Optional[int] = None,
               eos_id: Optional[int] = None,
               budgets: Optional[Dict[int, int]] = None,
               sampling: Optional[SamplingConfig] = None,
               speculative: bool = False) -> Dict[int, list]:
        """Batched decode across live sequences (or a subset — the
        HBM window only needs to hold the *active* batch's working set;
        idle sequences spill to the flash tier).

        ``horizon=None`` is the per-token path: one host interaction
        (plan, jitted step, argmax transfer) per generated token.
        ``horizon=H`` runs the fused path: H tokens per host
        interaction, greedy outputs token-for-token identical.
        ``speculative=True`` runs draft-verify passes on the fused
        scaffold (defaults ``horizon`` to 8): up to H tokens per model
        forward, greedy outputs still token-identical.
        ``budgets``/``eos_id`` stop individual sequences early on both
        paths (on device inside a fused horizon; host-side between
        per-token steps); a sequence's entry stops growing once its
        budget is spent or it emits ``eos_id``.

        ``sampling`` is the token-selection config (``GREEDY`` when
        omitted).  ``greedy=`` is deprecated: it was the only selection
        switch before on-device sampling existed and survives as a
        shim."""
        if greedy is not None:
            warnings.warn(
                "decode(greedy=) is deprecated; pass "
                "sampling=SamplingConfig(temperature=...) instead",
                DeprecationWarning, stacklevel=2)
            if not greedy and sampling is None:
                raise ValueError(
                    "greedy=False no longer selects a sampler; pass "
                    "sampling=SamplingConfig(temperature=..., top_p=...)")
        sampling = sampling or GREEDY
        if speculative:
            if horizon is None:
                horizon = 8
            if horizon < 2:
                raise ValueError("speculative decoding needs horizon >= 2 "
                                 "(one fed token + >=1 draft candidate)")
        elif not sampling.greedy and horizon is None:
            # on-device sampling lives in the fused scaffold; run it at
            # H=1 (the per-token path's host argmax can't sample)
            horizon = 1
        active = self._seqs if seqs is None else seqs
        out = {s: [] for s in active}
        # page-in overlap model: pull any spilled pages of the activating
        # batch before the token loop starts
        for s in active:
            self.table.prefetch(s)
        # continue from the tokens pending after prefill
        cur = {s: self._pending.get(s, 0) for s in active}
        remaining = {s: min(n_tokens, budgets[s]) if budgets else n_tokens
                     for s in active}
        live = [s for s in active if remaining[s] > 0]
        if horizon is None:
            # per-token path: eos/budget stopping happens host-side (a
            # finished sequence leaves the batch and is never fed again
            # — the same append/commit trajectory as the fused path)
            while live:
                seqs, logits = self.step_batch({s: cur[s] for s in live})
                # one batched argmax + one device->host transfer per
                # token, not one per sequence
                nxt_arr = np.asarray(jnp.argmax(logits, axis=-1))
                for i, s in enumerate(seqs):
                    cur[s] = int(nxt_arr[i])
                    out[s].append(cur[s])
                    if s in self._history:
                        self._history[s].append(cur[s])
                    remaining[s] -= 1
                    if eos_id is not None and cur[s] == eos_id:
                        remaining[s] = 0
                live = [s for s in live if remaining[s] > 0]
            self._pending.update(cur)
            return out
        # ONE key from the sampling seed for every pass: draws are
        # keyed per (sequence id, absolute position) inside the device
        # program, so the key must NOT vary per pass — a requeued
        # sequence resuming mid-stream on another node (different pass
        # index, different batch) still re-derives the same draws
        base_key = jax.random.PRNGKey(sampling.seed)
        batch_fn = (self.spec_horizon_batch if speculative
                    else self.horizon_batch)
        while live:
            got = batch_fn(
                {s: cur[s] for s in live},
                {s: remaining[s] for s in live},
                min(horizon, max(remaining[s] for s in live)),
                eos_id=eos_id, sampling=sampling, _key=base_key)
            for s in live:
                out[s].extend(got[s])
                remaining[s] -= len(got[s])
                if got[s]:
                    cur[s] = got[s][-1]
                if eos_id is not None and got[s] and got[s][-1] == eos_id:
                    remaining[s] = 0          # stopped on device
            live = [s for s in live if remaining[s] > 0]
        self._pending.update(cur)
        return out

    # -- telemetry -----------------------------------------------------------

    def tier_stats(self) -> Dict[str, int]:
        agg = dict(vars(self.table.stats))
        agg["residency"] = self.table.residency()
        # dtype-aware: bytes counters already price quantized pages at
        # their code+scale size; expose the per-page constant and the
        # total tier traffic for the analytical model's wire/tier terms
        agg["page_bytes"] = self.store.page_bytes()
        agg["kv_bytes_moved"] = agg["bytes_in"] + agg["bytes_out"]
        return agg
