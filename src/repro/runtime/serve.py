"""Serving runtime.

Two paths:

  * ``make_serving_fns`` — production path: jitted prefill/decode with
    the D-Cache sharding rules (KV sequence-sharded over the ``model``
    axis = the storage pool; see runtime/sharding.py).  Used by
    ``launch/serve.py`` and the dry-run.
  * ``PagedServer`` — the paper's tiered mechanism made concrete on one
    device: per-layer **PagedKVCache** (HBM window + host "flash" tier,
    prefetch) consumed by the Pallas ``paged_attention`` kernel.  The
    layer loop runs in Python so each layer reads its own page table —
    this is the ISP-container serving loop of the case study.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.kv_tier import PagedKVCache
from repro.kernels import ops
from repro.models import layers as L
from repro.runtime import sharding as shd


def make_serving_fns(model, mesh=None):
    """Returns (prefill_fn, decode_fn), jitted; sharded when mesh given."""
    if mesh is None:
        return (jax.jit(model.prefill), jax.jit(model.decode_step,
                                                donate_argnums=(1,)))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.param_specs(mesh, params_shape))

    prefill = jax.jit(model.prefill, in_shardings=(pshard, None))

    def decode(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    decode_j = jax.jit(decode, donate_argnums=(1,),
                       in_shardings=(pshard, None, None))
    return prefill, decode_j


class PagedServer:
    """Tiered-KV serving for a TransformerLM on one device (demo scale).

    Each layer owns a PagedKVCache; decode attention goes through the
    Pallas paged_attention kernel against the HBM window, with next-step
    prefetch after every token (compute/page-in overlap model).
    """

    def __init__(self, model, params, *, page_size: int = 16,
                 hbm_pages_per_layer: int = 64, dtype=jnp.float32):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.dtype = dtype
        cfg = self.cfg
        self.caches = [
            PagedKVCache(page_size=page_size,
                         hbm_pages=hbm_pages_per_layer,
                         n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                         dtype=dtype)
            for _ in range(cfg.n_layers)]
        self._seqs: List[int] = []
        self._pending: Dict[int, int] = {}

    # -- request handling -------------------------------------------------------

    def add_request(self, seq_id: int, prompt: np.ndarray):
        """Prefill a prompt into the paged caches, token by token
        (teacher-forcing the pages; fine at demo scale)."""
        for cache in self.caches:
            cache.add_sequence(seq_id)
        self._seqs.append(seq_id)
        last = None
        for tok in prompt:
            last = self._step({seq_id: int(tok)})[seq_id]
        self._pending[seq_id] = int(jnp.argmax(last))
        return last

    def decode(self, n_tokens: int, greedy: bool = True,
               seqs: Optional[List[int]] = None) -> Dict[int, list]:
        """Batched decode across live sequences (or a subset — the HBM
        window only needs to hold the *active* batch's working set; idle
        sequences spill to the flash tier)."""
        active = self._seqs if seqs is None else seqs
        out = {s: [] for s in active}
        # continue from the tokens pending after prefill
        cur = {s: self._pending.get(s, 0) for s in active}
        for _ in range(n_tokens):
            logits = self._step(cur)
            for s in active:
                nxt = int(jnp.argmax(logits[s]))
                out[s].append(nxt)
                cur[s] = nxt
        self._pending.update(cur)
        return out

    # -- one batched token step through the layer loop ----------------------------

    def _step(self, tokens: Dict[int, int]) -> Dict[int, jnp.ndarray]:
        cfg = self.cfg
        seqs = list(tokens.keys())
        params = self.params
        tok = jnp.asarray([tokens[s] for s in seqs], jnp.int32)
        h = L.embed_tokens(params["embed"], tok[:, None], self.dtype)
        lengths_before = {s: self.caches[0].length(s) for s in seqs}
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            cache = self.caches[li]
            a = L.apply_norm(lp["attn_norm"], h, cfg.norm)
            q, k, v = L._qkv(lp["attn"], a, cfg)
            pos = jnp.asarray([[lengths_before[s]] for s in seqs], jnp.int32)
            if cfg.rope:
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
            # append the new kv into the paged tier
            for bi, s in enumerate(seqs):
                cache.append_token(s, k[bi, 0], v[bi, 0])
            k_pages, v_pages, page_table, lengths = cache.kernel_view(seqs)
            o = ops.paged_attention(q[:, 0].astype(self.dtype), k_pages,
                                    v_pages, page_table, lengths)
            h = h + (o.reshape(len(seqs), 1, -1) @
                     lp["attn"]["wo"].astype(h.dtype))
            m = L.apply_norm(lp["mlp_norm"], h, cfg.norm)
            if cfg.is_moe:
                mo, _ = L.apply_moe(lp["mlp"], m, cfg, no_drop=True)
            else:
                mo = L.apply_mlp(lp["mlp"], m, cfg.act)
            h = h + mo
            cache.prefetch(seqs[0])         # overlap next step's page-ins
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           cfg.tie_embeddings)[:, 0]
        return {s: logits[i] for i, s in enumerate(seqs)}

    # -- telemetry -----------------------------------------------------------------

    def tier_stats(self) -> Dict[str, int]:
        agg = {}
        for c in self.caches:
            for k, v in vars(c.stats).items():
                agg[k] = agg.get(k, 0) + v
        agg["residency"] = float(np.mean([c.residency()
                                          for c in self.caches]))
        return agg
