"""Roofline analysis from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip,
~50 GB/s/link ICI.

  compute term    = HLO_FLOPs            / (chips * PEAK_FLOPS)
  memory term     = HLO_bytes            / (chips * HBM_BW)
  collective term = collective_bytes     / (chips * ICI_BW)

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed
out of the (post-SPMD) compiled HLO text by summing output operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the compiled module.

    HLO lines look like ``%all-reduce.119 = f32[16,256,49155]{2,1,0}
    all-reduce(%x), ...`` — the *op* is the token on the right-hand side
    of ``=``; the left-hand side is the instruction name (which may also
    contain the op string), so we only scan the RHS.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        for kind in _COLLECTIVES:
            pos = rhs.find(f" {kind}(")
            if pos < 0:
                pos = rhs.find(f" {kind}-start(")
            if pos < 0:
                continue
            head = rhs[:pos + 1]
            nbytes = sum(_shape_bytes(dt, dims)
                         for dt, dims in _SHAPE_RE.findall(head))
            out[kind] += nbytes
            out["count"] += 1
            break
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float = 0.0           # 6*N(active)*D
    bytes_per_device: float = 0.0      # from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate = max of the three terms (perfect
        overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the estimated step
        time (== MFU bound when compute-dominated)."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops / self.step_time_s) / (self.chips * PEAK_FLOPS)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bottleneck=self.bottleneck,
                 step_time_s=self.step_time_s,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(compiled, lowered_text: Optional[str], arch: str, shape: str,
            mesh_name: str, chips: int, model_flops: float) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = compiled.as_text() if lowered_text is None else lowered_text
    coll = collective_bytes(text)
    total_coll = sum(v for k, v in coll.items() if k != "count")
    mem = compiled.memory_analysis()
    bpd = 0.0
    if mem is not None:
        bpd = (getattr(mem, "argument_size_in_bytes", 0) +
               getattr(mem, "output_size_in_bytes", 0) +
               getattr(mem, "temp_size_in_bytes", 0))
    return RooflineTerms(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                         hlo_flops=flops, hlo_bytes=nbytes,
                         coll_bytes=float(total_coll), coll_breakdown=coll,
                         model_flops=model_flops, bytes_per_device=bpd)


def model_flops_estimate(n_active_params: int, tokens: int,
                         kind: str) -> float:
    """6*N*D for training; 2*N*D for inference forward."""
    if kind == "train":
        return 6.0 * n_active_params * tokens
    return 2.0 * n_active_params * tokens
