"""Training step factory: grad-accum microbatching (compute/comm overlap:
the reduction of microbatch *i* overlaps the compute of *i+1* in the XLA
schedule), global-norm clipping, AdamW, optional gradient compression
with error feedback for the cross-pod reduction."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim.adamw import clip_by_global_norm
from repro.optim import compression as comp


def make_train_step(model, opt_update, *, grad_accum: int = 1,
                    clip: float = 1.0, compression: str = "none",
                    gather_dtype=None):
    """Returns train_step(params, opt_state[, residuals], batch).

    ``gather_dtype=jnp.bfloat16`` casts float matrices to bf16 *before*
    the loss (i.e. before the ZeRO all-gather), halving FSDP collective
    bytes — the optimizer still updates fp32 master weights."""

    def cast_for_compute(p):
        if gather_dtype is None:
            return p
        return jax.tree.map(
            lambda x: x.astype(gather_dtype)
            if (x.ndim >= 2 and x.dtype == jnp.float32) else x, p)

    def loss_fn(p, mb):
        loss, parts = model.loss(cast_for_compute(p), mb)
        return loss, parts

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return grads, loss, parts

        def split(x):
            # microbatch as the *minor* grouping so each data shard keeps
            # its own rows (no cross-shard resharding from the reshape)
            b = x.shape[0]
            r = x.reshape(b // grad_accum, grad_accum, *x.shape[1:])
            return jnp.moveaxis(r, 1, 0)

        mbs = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def mb_step(carry, mb):
            gsum, lsum = carry
            (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                gsum, g)
            return (gsum, lsum + l), None

        (grads, lsum), _ = lax.scan(mb_step, (g0, jnp.zeros(())), mbs)
        inv = 1.0 / grad_accum
        grads = jax.tree.map(lambda g: g * inv, grads)
        return grads, lsum * inv, {}

    if compression == "none":
        def train_step(params, opt_state, batch):
            grads, loss, _ = compute_grads(params, batch)
            grads, gn = clip_by_global_norm(grads, clip)
            params, opt_state = opt_update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, "grad_norm": gn}
        return train_step

    def train_step_c(params, opt_state, residuals, batch):
        grads, loss, _ = compute_grads(params, batch)
        grads, residuals = comp.compress_grads(grads, residuals, compression)
        grads, gn = clip_by_global_norm(grads, clip)
        params, opt_state = opt_update(grads, opt_state, params)
        return params, opt_state, residuals, {"loss": loss, "grad_norm": gn}

    return train_step_c
