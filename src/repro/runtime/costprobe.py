"""Probe-based roofline measurement (component probes).

XLA's HLO cost analysis (a) counts a ``while`` (lax.scan) body once and
(b) reports **per-device** numbers for SPMD modules.  The full-model
compile therefore cannot supply roofline terms.  Instead we compile
tiny *component* modules on the production mesh with pinned shardings
and compose:

  train:   ga * (L * layer_vjp + tail_vjp) + opt_update
  serve:   L * layer_fwd + tail_fwd            (prefill / decode)
  hybrid:  L_mamba * mamba_layer + N_attn * shared_attn + tail

Each component is a real compiled artifact: collectives included, remat
policy identical to the production step (vjp through jax.checkpoint).
All numbers are per-device; the roofline formulas divide by per-chip
peaks, which is equivalent to global/(chips*peak).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_arch
from repro.models import layers as LYR
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.optim.adamw import clip_by_global_norm
from repro.runtime import roofline, sharding as shd

KEYS = ("flops", "bytes", "coll")


def _measure(lowered) -> Dict[str, float]:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = roofline.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(v for k, v in coll.items() if k != "count")),
    }


def _sum(*costs, weights=None) -> Dict[str, float]:
    weights = weights or [1.0] * len(costs)
    return {k: sum(w * c[k] for w, c in zip(weights, costs)) for k in KEYS}


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _layer_param_probe(cfg, mesh, model, stacked):
    """(specs, shardings) for ONE layer's params from the stacked tree."""
    def strip(path, leaf):
        return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
    one = jax.tree_util.tree_map_with_path(strip, stacked)

    def spec_of(path, leaf):
        keys = ("layers",) + tuple(shd._key_of(p) for p in path)
        sp = shd.param_spec(mesh, keys, (1,) + leaf.shape)
        return P(*sp[1:])
    specs = jax.tree_util.tree_map_with_path(spec_of, one)
    return one, jax.tree.map(lambda s: _ns(mesh, s), specs)


def _h_sharding(mesh, b):
    ba = shd.batch_axes(mesh)
    sb = shd._ax(mesh, b, *ba)
    return _ns(mesh, P(sb, None, None))


# ---------------------------------------------------------------------------
# transformer probes
# ---------------------------------------------------------------------------


def _tfm_layer_train(model, mesh, b, s, opt: int = 0):
    cfg = model.cfg
    impl = model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    h_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    hs = _h_sharding(mesh, b)
    positions = None

    layer = impl._maybe_remat(lambda hh, lp: impl._layer(
        hh, lp, jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)))

    def cast(lp):
        if opt < 1:
            return lp
        # bf16 FSDP gathers: cast the sharded master weight BEFORE use
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if (x.ndim >= 2 and x.dtype == jnp.float32) else x, lp)

    def f(lp, h, ct):
        (y, aux), vjp = jax.vjp(
            lambda lp_, h_: layer(h_, cast(lp_)), lp, h)
        glp, gh = vjp((ct, jnp.ones((), jnp.float32)))
        return glp, gh

    lowered = jax.jit(f, in_shardings=(lp_shard, hs, hs),
                      out_shardings=(lp_shard, hs)).lower(
        lp_shape, h_spec, h_spec)
    return _measure(lowered)


def _tfm_tail_train(model, mesh, mb_specs):
    """0-layer model loss grad = embed + final norm + chunked CE."""
    cfg = model.cfg
    zero = dataclasses.replace(cfg, n_layers=0)
    zm = get_model(zero, compute_dtype=jnp.bfloat16, remat="full",
                   unroll_inner=True)
    params_shape = jax.eval_shape(zm.init, jax.random.PRNGKey(0))
    pshard = jax.tree.map(lambda sp: _ns(mesh, sp),
                          shd.param_specs(mesh, params_shape))
    bshard = shd.to_shardings(mesh, shd.batch_spec(mesh, mb_specs))

    def grad_fn(p, bb):
        (loss, _), g = jax.value_and_grad(zm.loss, has_aux=True)(p, bb)
        return g, loss

    lowered = jax.jit(grad_fn, in_shardings=(pshard, bshard),
                      out_shardings=(pshard, _ns(mesh, P()))).lower(
        params_shape, mb_specs)
    return _measure(lowered)


def _serve_tail(model, mesh, shape, kind, opt: int = 0):
    cfg = model.cfg
    zero = dataclasses.replace(cfg, n_layers=0,
                               attn_every=cfg.attn_every or 0)
    kw = ({"kv_quant": model.impl.kv_quant}
          if cfg.block_type == "transformer" else {})
    zm = get_model(zero, compute_dtype=jnp.bfloat16, unroll_inner=True, **kw)
    params_shape = jax.eval_shape(zm.init, jax.random.PRNGKey(0))
    if opt >= 1:
        params_shape = shd.cast_float_specs(params_shape, jnp.bfloat16)
        pshard = jax.tree.map(lambda sp: _ns(mesh, sp),
                              shd.serve_param_specs(mesh, params_shape))
    else:
        pshard = jax.tree.map(lambda sp: _ns(mesh, sp),
                              shd.param_specs(mesh, params_shape))
    in_specs = zm.input_specs(shape)
    if kind == "prefill":
        bshard = shd.to_shardings(mesh, shd.batch_spec(mesh, in_specs))
        if cfg.encoder_only:
            fn = lambda p, bb: zm.forward(p, bb)[0]
            lowered = jax.jit(fn, in_shardings=(pshard, bshard)).lower(
                params_shape, in_specs)
        else:
            lowered = jax.jit(
                zm.prefill, in_shardings=(pshard, bshard)).lower(
                params_shape, in_specs)
    else:
        cache_spec = in_specs["cache"]
        cshard = shd.to_shardings(mesh,
                                  shd.cache_spec_shardings(mesh, cache_spec))
        tshard = _ns(mesh, shd.decode_token_spec(mesh, shape.global_batch))
        lowered = jax.jit(zm.decode_step,
                          in_shardings=(pshard, cshard, tshard),
                          donate_argnums=(1,)).lower(
            params_shape, cache_spec, in_specs["tokens"])
    return _measure(lowered)


def _kv_shard(mesh, b, s):
    sb = shd._ax(mesh, b, "data")
    seq_axes = ("pod", "model") if "pod" in mesh.axis_names else ("model",)
    ss = shd._ax(mesh, s, *seq_axes)
    return _ns(mesh, P(sb, None, ss, None))


def _tfm_layer_prefill(model, mesh, b, s):
    cfg = model.cfg
    impl = model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    h_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    hs = _h_sharding(mesh, b)
    kv_spec = jax.ShapeDtypeStruct((b, cfg.n_kv_heads, s, cfg.hd),
                                   jnp.bfloat16)
    kvs = _kv_shard(mesh, b, s)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def f(lp, h):
        a = LYR.apply_norm(lp["attn_norm"], h, cfg.norm)
        q, k, v = LYR._qkv(lp["attn"], a, cfg)
        pos = positions.repeat(b, axis=0)
        if cfg.rope:
            q = LYR.apply_rope(q, pos, cfg.rope_theta)
            k = LYR.apply_rope(k, pos, cfg.rope_theta)
        o = LYR.chunked_attention(q, k, v, causal=cfg.causal,
                                  q_chunk=impl.q_chunk, unroll=True)
        h = h + o.reshape(b, s, -1) @ lp["attn"]["wo"].astype(h.dtype)
        m = LYR.apply_norm(lp["mlp_norm"], h, cfg.norm)
        if cfg.is_moe:
            mo, _ = LYR.apply_moe(lp["mlp"], m, cfg)
        else:
            mo = LYR.apply_mlp(lp["mlp"], m, cfg.act)
        kc = jnp.swapaxes(k, 1, 2).astype(jnp.bfloat16)
        vc = jnp.swapaxes(v, 1, 2).astype(jnp.bfloat16)
        return h + mo, kc, vc

    lowered = jax.jit(f, in_shardings=(lp_shard, hs),
                      out_shardings=(hs, kvs, kvs)).lower(lp_shape, h_spec)
    return _measure(lowered)


def _tfm_layer_decode(model, mesh, b, s, opt: int = 0):
    cfg = model.cfg
    impl = model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    if opt >= 1:   # bf16 weights, TP-only (no per-token FSDP gather)
        lp_shape = shd.cast_float_specs(lp_shape, jnp.bfloat16)
        fa = set(shd.fsdp_axes(mesh))

        def strip(spec):
            def keep(ax):
                if isinstance(ax, tuple):
                    kept = tuple(a for a in ax if a not in fa)
                    return (kept if len(kept) > 1 else
                            (kept[0] if kept else None))
                return None if ax in fa else ax
            return P(*(keep(ax) for ax in spec.spec))
        lp_shard = jax.tree.map(lambda ns: _ns(mesh, strip(ns)), lp_shard)
    q8 = opt >= 2 and impl.kv_quant == "int8"
    h_spec = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
    hs = _h_sharding(mesh, b)
    kv_dtype = jnp.int8 if q8 else jnp.bfloat16
    kv_spec = jax.ShapeDtypeStruct((b, cfg.n_kv_heads, s, cfg.hd), kv_dtype)
    kvs = _kv_shard(mesh, b, s)
    sc_spec = jax.ShapeDtypeStruct((b, cfg.n_kv_heads, s), jnp.float32)
    scs = _ns(mesh, P(shd._ax(mesh, b, "data"), None,
                      shd._ax(mesh, s, "model")))

    if q8:
        def f(lp, h, kc, vc, ksc, vsc, index):
            a = LYR.apply_norm(lp["attn_norm"], h, cfg.norm)
            o, kc, vc, ksc, vsc = LYR.decode_attention_q8(
                lp["attn"], a, cfg, kc, vc, ksc, vsc, index)
            h = h + o
            m = LYR.apply_norm(lp["mlp_norm"], h, cfg.norm)
            if cfg.is_moe:
                mo, _ = LYR.apply_moe(lp["mlp"], m, cfg, no_drop=True)
            else:
                mo = LYR.apply_mlp(lp["mlp"], m, cfg.act)
            return h + mo, kc, vc, ksc, vsc

        lowered = jax.jit(
            f, in_shardings=(lp_shard, hs, kvs, kvs, scs, scs,
                             _ns(mesh, P())),
            out_shardings=(hs, kvs, kvs, scs, scs),
            donate_argnums=(2, 3, 4, 5)).lower(
            lp_shape, h_spec, kv_spec, kv_spec, sc_spec, sc_spec,
            jax.ShapeDtypeStruct((), jnp.int32))
        return _measure(lowered)

    def f(lp, h, kc, vc, index):
        a = LYR.apply_norm(lp["attn_norm"], h, cfg.norm)
        o, kc, vc = LYR.decode_attention(lp["attn"], a, cfg, kc, vc, index)
        h = h + o
        m = LYR.apply_norm(lp["mlp_norm"], h, cfg.norm)
        if cfg.is_moe:
            mo, _ = LYR.apply_moe(lp["mlp"], m, cfg, no_drop=True)
        else:
            mo = LYR.apply_mlp(lp["mlp"], m, cfg.act)
        return h + mo, kc, vc

    lowered = jax.jit(
        f, in_shardings=(lp_shard, hs, kvs, kvs, _ns(mesh, P())),
        out_shardings=(hs, kvs, kvs), donate_argnums=(2, 3)).lower(
        lp_shape, h_spec, kv_spec, kv_spec,
        jax.ShapeDtypeStruct((), jnp.int32))
    return _measure(lowered)


# ---------------------------------------------------------------------------
# rwkv probes
# ---------------------------------------------------------------------------


def _rwkv_states(cfg, impl, b, kind):
    d = cfg.d_model
    return (jax.ShapeDtypeStruct((b, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((b, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((b, impl.n_heads, impl.dk, impl.dk),
                                 jnp.float32))


def _rwkv_state_shardings(mesh, b, impl):
    sb = shd._ax(mesh, b, "data")
    return (_ns(mesh, P(sb, "model")), _ns(mesh, P(sb, "model")),
            _ns(mesh, P(sb, None, None, None)))


def _rwkv_layer(model, mesh, b, s, train: bool):
    cfg, impl = model.cfg, model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    st_tm, st_cm, wkv = _rwkv_states(cfg, impl, b, "seq")
    st_sh = _rwkv_state_shardings(mesh, b, impl)
    h_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    hs = _h_sharding(mesh, b)

    def layer(hh, lp, a_tm, a_cm, a_wkv):
        x = LYR.apply_norm(lp["ln1"], hh, "layernorm")
        o, n_tm, n_wkv = impl._time_mix_seq(lp["time_mix"], x,
                                            a_tm.astype(x.dtype), a_wkv)
        hh = hh + o
        c = LYR.apply_norm(lp["ln2"], hh, "layernorm")
        o2, n_cm = impl._channel_mix_seq(lp["channel_mix"], c,
                                         a_cm.astype(c.dtype))
        return hh + o2, n_tm.astype(jnp.bfloat16), n_cm.astype(jnp.bfloat16), n_wkv

    if train:
        layer_r = jax.checkpoint(layer)

        def f(lp, h, ct, a_tm, a_cm, a_wkv):
            outs, vjp = jax.vjp(layer_r, h, lp, a_tm, a_cm, a_wkv)
            cts = (ct, jnp.zeros_like(outs[1]), jnp.zeros_like(outs[2]),
                   jnp.zeros_like(outs[3]))
            return vjp(cts)

        lowered = jax.jit(f, in_shardings=(lp_shard, hs, hs) + st_sh).lower(
            lp_shape, h_spec, h_spec, st_tm, st_cm, wkv)
    else:
        def f(lp, h, a_tm, a_cm, a_wkv):
            return layer(h, lp, a_tm, a_cm, a_wkv)
        lowered = jax.jit(f, in_shardings=(lp_shard, hs) + st_sh).lower(
            lp_shape, h_spec, st_tm, st_cm, wkv)
    return _measure(lowered)


def _rwkv_layer_decode(model, mesh, b):
    cfg, impl = model.cfg, model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    st_tm, st_cm, wkv = _rwkv_states(cfg, impl, b, "step")
    st_sh = _rwkv_state_shardings(mesh, b, impl)
    h_spec = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
    sb = shd._ax(mesh, b, "data")
    hs = _ns(mesh, P(sb, "model"))
    from repro.models.rwkv6 import wkv_step

    def f(lp, hh, a_tm, a_cm, a_wkv):
        a = LYR.apply_norm(lp["ln1"], hh, "layernorm")
        r, k, v, g, logw = impl._tm_proj(lp["time_mix"], a,
                                         a_tm.astype(a.dtype))
        o, n_wkv = wkv_step(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw,
                            lp["time_mix"]["u"].astype(jnp.float32), a_wkv)
        o = LYR.group_norm_heads(o.astype(a.dtype), lp["time_mix"]["ln_x"])
        o = (o.reshape(*hh.shape[:-1], -1) * g) @ lp["time_mix"]["wo"].astype(a.dtype)
        hh = hh + o
        c = LYR.apply_norm(lp["ln2"], hh, "layernorm")
        dx = a_cm.astype(c.dtype) - c
        xk = c + dx * lp["channel_mix"]["mu_k"].astype(c.dtype)
        xr = c + dx * lp["channel_mix"]["mu_r"].astype(c.dtype)
        kk = jnp.square(jax.nn.relu(xk @ lp["channel_mix"]["wk"].astype(c.dtype)))
        o2 = jax.nn.sigmoid(xr @ lp["channel_mix"]["wr"].astype(c.dtype)) * (
            kk @ lp["channel_mix"]["wv"].astype(c.dtype))
        return hh + o2, a.astype(jnp.bfloat16), c.astype(jnp.bfloat16), n_wkv

    lowered = jax.jit(f, in_shardings=(lp_shard, hs) + st_sh).lower(
        lp_shape, h_spec, st_tm, st_cm, wkv)
    return _measure(lowered)


# ---------------------------------------------------------------------------
# zamba (mamba2 hybrid) probes
# ---------------------------------------------------------------------------


def _zamba_components(model, mesh, b, s, kind):
    """Returns (mamba_cost, attn_cost) for seq (train fwd basis) or
    decode."""
    from repro.models import mamba2 as M
    cfg, impl = model.cfg, model.impl
    stacked = jax.eval_shape(
        lambda: jax.eval_shape(impl.init, jax.random.PRNGKey(0))["layers"])
    lp_shape, lp_shard = _layer_param_probe(cfg, mesh, model, stacked)
    d_inner, n_heads, conv_dim = M.mamba2_dims(cfg)
    sb = shd._ax(mesh, b, "data")
    conv_spec = jax.ShapeDtypeStruct((b, M.D_CONV - 1, conv_dim), jnp.float32)
    ssm_spec = jax.ShapeDtypeStruct((b, n_heads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32)
    conv_sh = _ns(mesh, P(sb, None, "model"))
    ssm_sh = _ns(mesh, P(sb, None, None, None))
    # shared attn params
    full_shape = jax.eval_shape(impl.init, jax.random.PRNGKey(0))
    sp_shape = full_shape["shared_attn"]
    sp_shard = jax.tree.map(
        lambda spc: _ns(mesh, spc),
        shd.param_specs(mesh, {"shared_attn": sp_shape}))["shared_attn"]

    if kind in ("train", "prefill"):
        h_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        hs = _h_sharding(mesh, b)
        # The mamba layer's cost is exactly linear in S (identical chunks,
        # no cross-chunk term): probe ONE chunk and scale by S/chunk to
        # keep the unrolled-vjp module small.
        s_probe = min(s, impl.chunk)
        mamba_scale = s / s_probe
        hm_spec = jax.ShapeDtypeStruct((b, s_probe, cfg.d_model),
                                       jnp.bfloat16)

        def mamba_f(lp, h, cs, ss):
            a = LYR.apply_norm(lp["norm"], h, "rmsnorm")
            o, ncs, nss = M.apply_mamba2_seq(lp["mamba"], a, cfg, cs, ss,
                                             chunk=impl.chunk, unroll=True)
            return h + o, ncs, nss

        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

        def attn_f(sp, h):
            return impl._shared_attn_seq(sp, h, positions.repeat(b, 0),
                                         jnp.bfloat16)

        if kind == "train":
            mamba_r = jax.checkpoint(mamba_f)

            def mg(lp, h, ct, cs, ss):
                outs, vjp = jax.vjp(mamba_r, lp, h, cs, ss)
                return vjp((ct, jnp.zeros_like(outs[1]),
                            jnp.zeros_like(outs[2])))
            lowered_m = jax.jit(mg, in_shardings=(lp_shard, hs, hs, conv_sh,
                                                  ssm_sh)).lower(
                lp_shape, hm_spec, hm_spec, conv_spec, ssm_spec)
            attn_r = jax.checkpoint(attn_f)

            def ag(sp, h, ct):
                (hh, (kc, vc)), vjp = jax.vjp(attn_r, sp, h)
                return vjp((ct, (jnp.zeros_like(kc), jnp.zeros_like(vc))))
            lowered_a = jax.jit(ag, in_shardings=(sp_shard, hs, hs)).lower(
                sp_shape, h_spec, h_spec)
            mc = _measure(lowered_m)
            mc = {k: v * mamba_scale for k, v in mc.items()}
            return mc, _measure(lowered_a)
        else:
            lowered_m = jax.jit(mamba_f,
                                in_shardings=(lp_shard, hs, conv_sh, ssm_sh)
                                ).lower(lp_shape, hm_spec, conv_spec,
                                        ssm_spec)
            lowered_a = jax.jit(attn_f, in_shardings=(sp_shard, hs)).lower(
                sp_shape, h_spec)
        mc = _measure(lowered_m)
        mc = {k: v * mamba_scale for k, v in mc.items()}
        return mc, _measure(lowered_a)

    # decode
    h_spec = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
    hs = _h_sharding(mesh, b)
    kv_spec = jax.ShapeDtypeStruct((b, cfg.n_kv_heads, s, cfg.hd),
                                   jnp.bfloat16)
    kvs = _kv_shard(mesh, b, s)

    def mamba_step(lp, h, cs, ss):
        a = LYR.apply_norm(lp["norm"], h, "rmsnorm")
        o, ncs, nss = M.apply_mamba2_step(lp["mamba"], a[:, 0], cfg, cs, ss)
        return h + o[:, None, :], ncs, nss

    lowered_m = jax.jit(mamba_step,
                        in_shardings=(lp_shard, hs, conv_sh, ssm_sh)).lower(
        lp_shape, h_spec, conv_spec, ssm_spec)

    def attn_step(sp, h, kc, vc, index):
        return impl._shared_attn_step(sp, h, kc, vc, index)

    lowered_a = jax.jit(attn_step,
                        in_shardings=(sp_shard, hs, kvs, kvs, _ns(mesh, P())),
                        donate_argnums=(2, 3)).lower(
        sp_shape, h_spec, kv_spec, kv_spec,
        jax.ShapeDtypeStruct((), jnp.int32))
    return _measure(lowered_m), _measure(lowered_a)


# ---------------------------------------------------------------------------
# optimizer probe
# ---------------------------------------------------------------------------


def _opt_probe(model, mesh):
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = jax.tree.map(lambda sp: _ns(mesh, sp),
                          shd.param_specs(mesh, params_shape))
    init_fn, upd_fn = adamw(lr=warmup_cosine(3e-4, 100, 10_000))
    opt_shape = jax.eval_shape(init_fn, params_shape)
    oshard = type(opt_shape)(step=_ns(mesh, P()), m=pshard, v=pshard)

    def step(g, o, p):
        g, gn = clip_by_global_norm(g, 1.0)
        p, o = upd_fn(g, o, p)
        return p, o, gn

    g_shape = jax.tree.map(
        lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32),
        params_shape)
    lowered = jax.jit(step, in_shardings=(pshard, oshard, pshard),
                      out_shardings=(pshard, oshard, _ns(mesh, P()))).lower(
        g_shape, opt_shape, params_shape)
    return _measure(lowered)


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------


def probe_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
               opt_level: int = 0) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    kv_quant = ("int8" if (opt_level >= 2 and shape.kind == "decode"
                           and cfg.block_type == "transformer") else "none")
    moe_impl = ("shardmap" if (opt_level >= 2 and cfg.is_moe
                               and shape.kind == "train") else "dense")
    model = get_model(cfg, compute_dtype=jnp.bfloat16, remat="full",
                      unroll_inner=True,
                      **({"kv_quant": kv_quant, "moe_impl": moe_impl}
                         if cfg.block_type == "transformer" else {}))
    L = cfg.n_layers
    fam = cfg.block_type

    if shape.kind == "train":
        from repro.launch.dryrun import grad_accum_for
        ga = grad_accum_for(cfg)
        b = shape.global_batch // ga
        mb_specs = jax.tree.map(
            lambda sds: jax.ShapeDtypeStruct((b,) + sds.shape[1:], sds.dtype),
            model.input_specs(shape))
        tail = _tfm_tail_train(model, mesh, mb_specs)
        opt = _opt_probe(model, mesh)
        if fam == "transformer":
            layer = _tfm_layer_train(model, mesh, b, shape.seq_len,
                                     opt=opt_level)
            per_step = _sum(layer, tail, weights=[L, 1.0])
        elif fam == "rwkv6":
            layer = _rwkv_layer(model, mesh, b, shape.seq_len, train=True)
            per_step = _sum(layer, tail, weights=[L, 1.0])
        else:
            mamba, attn = _zamba_components(model, mesh, b, shape.seq_len,
                                            "train")
            n_attn = len(model.impl.groups)
            per_step = _sum(mamba, attn, tail, weights=[L, n_attn, 1.0])
        total = _sum(per_step, opt, weights=[ga, 1.0])
        total["components"] = {"tail": tail, "opt": opt, "ga": ga}
        return total

    b, s = shape.global_batch, shape.seq_len
    tail = _serve_tail(model, mesh, shape, shape.kind, opt=opt_level)
    if fam == "transformer":
        if shape.kind == "prefill":
            layer = _tfm_layer_prefill(model, mesh, b, s)
        else:
            layer = _tfm_layer_decode(model, mesh, b, s, opt=opt_level)
        total = _sum(layer, tail, weights=[L, 1.0])
    elif fam == "rwkv6":
        if shape.kind == "prefill":
            layer = _rwkv_layer(model, mesh, b, s, train=False)
        else:
            layer = _rwkv_layer_decode(model, mesh, b)
        total = _sum(layer, tail, weights=[L, 1.0])
    else:
        mamba, attn = _zamba_components(model, mesh, b, s, shape.kind)
        n_attn = len(model.impl.groups)
        total = _sum(mamba, attn, tail, weights=[L, n_attn, 1.0])
    total["components"] = {"tail": tail}
    return total
