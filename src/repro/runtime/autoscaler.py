"""SLO-driven elastic pool autoscaling.

The control loop that makes pool node count a runtime variable: an
:class:`Autoscaler` ticks once per scheduler iteration (between decode
horizons — never inside one), watches queue depth and rolling p50/p99
TTFT/TPOT against a declared :class:`ServingSLO`, and moves the serving
set one node at a time:

  * **scale-up** on an SLO breach (latency tail over target, or queue
    depth over the backlog cap): ``StoragePool.grow_serving`` activates
    a parked shard / wires a fabric node to an unbacked one.  Zero
    retrace — the mesh programs were compiled once against the pow2
    capacity bucket (DESIGN.md §Elastic pool).
  * **scale-down** on sustained headroom (mostly-empty windows, empty
    queue, for ``sustain`` consecutive ticks):
    ``StoragePool.drain_serving_node`` runs the two-path zero-drop
    drain — warm device-to-device page migration, cold failover
    re-prefill — so scale-down never sheds a request.

Both directions respect a cooldown so one burst doesn't saw-tooth the
pool, and every decision is recorded (``decisions``) along with the
SLO-recovery latency (``recoveries``): the time from first breach until
the rolling tail is back under target — the headline number of the
autoscale benchmark cell.

The class is duck-typed against the router (``waiting`` / ``active`` /
``prefilling`` / ``finished``) and the pool frontend
(``grow_serving`` / ``drain_serving_node``), so decision logic is unit
testable without a device in sight.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class ServingSLO:
    """Declared service-level objectives.  ``inf`` disables a term;
    breach = ANY enabled term over target."""
    ttft_p50_s: float = float("inf")
    ttft_p99_s: float = float("inf")
    tpot_p50_s: float = float("inf")
    tpot_p99_s: float = float("inf")
    # backlog cap: more requests waiting than this is a breach even
    # before their latency shows up in the finished-request tail (the
    # early-warning signal — queue depth leads TTFT by construction)
    queue_depth: int = 1_000_000


@dataclasses.dataclass
class ScaleDecision:
    t: float                 # monotonic stamp
    tick: int
    kind: str                # "up" | "down"
    nodes: int               # serving set size AFTER the decision
    reason: str


class Autoscaler:
    """One-node-at-a-time elastic controller for a PoolRouter +
    StoragePool pair.

    ``window`` — freshness horizon in controller ticks: the percentile
    metrics cover requests that finished within the last ``window``
    ticks.  A tick horizon (not a last-N-finished tail) matters for the
    close of a breach: once a burst passes and traffic thins, its slow
    requests age out and the pool reads healthy — a count window would
    hold the burst in the percentiles indefinitely and pin the pool
    scaled up.  The age of the oldest *waiting* request also enters the
    TTFT samples: it is a lower bound on that request's eventual TTFT,
    so a wedged queue breaches before anything finishes.

    ``headroom_frac`` — scale-down arms when the pooled free-page
    fraction across the serving set exceeds this AND the queue is idle;
    it fires after ``sustain`` consecutive armed ticks.  A drain is
    attempted only when some surviving node's window can absorb the
    candidate's resident pages (the warm path stays warm); otherwise
    the controller waits — scale-down is an optimization, never worth a
    cold re-prefill storm.

    ``cooldown`` — minimum ticks between decisions in either direction.
    """

    def __init__(self, router, pool, *, slo: ServingSLO,
                 min_nodes: int = 1, max_nodes: Optional[int] = None,
                 window: int = 16, cooldown: int = 4,
                 headroom_frac: float = 0.6, sustain: int = 6):
        self.router = router
        self.pool = pool
        self.slo = slo
        self.min_nodes = min_nodes
        self.max_nodes = (max_nodes if max_nodes is not None
                          else router.server.n_nodes)
        self.window = window
        self.cooldown = cooldown
        self.headroom_frac = headroom_frac
        self.sustain = sustain
        self.tick_count = 0
        self.decisions: List[ScaleDecision] = []
        self.recoveries: List[Dict[str, float]] = []
        self._last_action_tick = -(10 ** 9)
        self._idle_ticks = 0
        self._breach_since: Optional[float] = None
        self._samples: List[tuple] = []      # (tick, ttft_s, tpot_s)
        self._seen = 0                       # finished already sampled

    # -- observation ---------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        """Tail metrics over the requests that finished within the last
        ``window`` ticks, plus the live queue."""
        now = time.monotonic()
        fin = self.router.finished
        for r in fin[self._seen:]:
            self._samples.append(
                (self.tick_count, r.t_first - r.t_arrive,
                 (r.t_done - r.t_first) / max(len(r.output) - 1, 1)))
        self._seen = len(fin)
        cut = self.tick_count - self.window
        self._samples = [s for s in self._samples if s[0] > cut]
        ttft = [s[1] for s in self._samples]
        tpot = [s[2] for s in self._samples]
        # the oldest waiting request's age is a floor on its eventual
        # TTFT — count it so saturation breaches without waiting for
        # the backlog to finish
        if self.router.waiting:
            ttft.append(max(now - r.t_arrive for r in self.router.waiting))

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return {"queue_depth": len(self.router.waiting),
                "p50_ttft_s": pct(ttft, 50), "p99_ttft_s": pct(ttft, 99),
                "p50_tpot_s": pct(tpot, 50), "p99_tpot_s": pct(tpot, 99)}

    def _breached(self, m: Dict[str, float]) -> Optional[str]:
        s = self.slo
        if m["queue_depth"] > s.queue_depth:
            return f"queue depth {m['queue_depth']} > {s.queue_depth}"
        for key, target in (("p50_ttft_s", s.ttft_p50_s),
                            ("p99_ttft_s", s.ttft_p99_s),
                            ("p50_tpot_s", s.tpot_p50_s),
                            ("p99_tpot_s", s.tpot_p99_s)):
            if m[key] > target:
                return f"{key} {m[key]:.4f} > {target:.4f}"
        return None

    # -- headroom / drain candidacy ------------------------------------------

    def _pool_headroom(self) -> float:
        srv = self.router.server
        alive = srv.alive_nodes()
        free = sum(srv.table.shard_free_pages(s) for s in alive)
        return free / max(len(alive) * srv.pages_per_node, 1)

    def _drain_candidate(self) -> Optional[int]:
        """The emptiest serving node, provided some other node's window
        can absorb its occupied pages (warm path guaranteed while
        nothing changes under us; the cold fallback still catches
        races)."""
        srv = self.router.server
        alive = srv.alive_nodes()
        if len(alive) <= self.min_nodes:
            return None
        cand = max(alive, key=lambda s: (srv.table.shard_free_pages(s), -s))
        occupied = srv.pages_per_node - srv.table.shard_free_pages(cand)
        best_other = max(srv.table.shard_free_pages(s)
                         for s in alive if s != cand)
        return cand if best_other >= occupied else None

    # -- the control loop ----------------------------------------------------

    def tick(self) -> Optional[ScaleDecision]:
        """One controller iteration; call between scheduler steps.
        Returns the decision taken, if any."""
        self.tick_count += 1
        now = time.monotonic()
        m = self.metrics()
        why = self._breached(m)
        srv = self.router.server
        active = len(srv.alive_nodes())

        if why is not None:
            self._idle_ticks = 0
            if self._breach_since is None:
                self._breach_since = now
            if (active < self.max_nodes and
                    self.tick_count - self._last_action_tick >=
                    self.cooldown):
                self.pool.grow_serving(active + 1)
                self._last_action_tick = self.tick_count
                d = ScaleDecision(now, self.tick_count, "up", active + 1,
                                  why)
                self.decisions.append(d)
                return d
            return None

        # SLO healthy again: close an open breach episode and record
        # how long the pool took to pull the tail back under target
        if self._breach_since is not None:
            self.recoveries.append(
                {"t": now, "recovery_s": now - self._breach_since,
                 "nodes": active})
            self._breach_since = None

        idle = (not self.router.waiting and not self.router.prefilling
                and self._pool_headroom() >= self.headroom_frac)
        self._idle_ticks = self._idle_ticks + 1 if idle else 0
        if (self._idle_ticks >= self.sustain and
                active > self.min_nodes and
                self.tick_count - self._last_action_tick >= self.cooldown):
            cand = self._drain_candidate()
            if cand is not None:
                rep = self.pool.drain_serving_node(cand)
                self._last_action_tick = self.tick_count
                self._idle_ticks = 0
                d = ScaleDecision(
                    now, self.tick_count, "down", active - 1,
                    f"sustained headroom ({self._pool_headroom():.2f} "
                    f"free, {len(rep['moved'])} seqs migrated warm, "
                    f"{len(rep['cold'])} cold)")
                self.decisions.append(d)
                return d
        return None
