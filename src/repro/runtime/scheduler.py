"""Continuous-batching request scheduler over the tiered PagedServer.

Production serving needs more than a static batch: requests arrive and
finish at different times.  This scheduler implements the standard
continuous-batching loop on top of the paper's tiered KV mechanism:

  * admission control — a request is admitted when the HBM window can
    pin its projected working set alongside the active batch
    (otherwise it waits; the flash tier holds preempted sequences);
  * iteration-level scheduling — every step decodes the current active
    set through one jitted ``decode_step``; finished sequences (EOS or
    max_tokens) free their pages immediately via the public
    ``free_sequence`` API and a waiting request takes the slot;
  * tail telemetry — per-request latency and the tier counters, the
    serving-side analogue of mini-docker's container monitoring.

The scheduler talks only to PagedServer's public surface (capacity
accounting, ``free_sequence``, the batched step) — page-table internals
stay owned by core.kv_tier.PageTableManager.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_tokens: int
    eos_id: Optional[int] = None
    # telemetry
    t_arrive: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return (len(self.output) >= self.max_tokens or
                (self.eos_id is not None and self.output and
                 self.output[-1] == self.eos_id))


class ContinuousBatcher:
    """Iteration-level scheduler for a PagedServer."""

    def __init__(self, server, *, max_active: int = 8):
        self.server = server
        self.max_active = max_active
        self.waiting: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request):
        req.t_arrive = time.monotonic()
        self.waiting.append(req)

    def _pages_needed(self, req: Request) -> int:
        return self.server.pages_needed(len(req.prompt) + req.max_tokens)

    def _window_has_room(self, req: Request) -> bool:
        pinned_now = sum(self._pages_needed(r) for r in self.active.values())
        return pinned_now + self._pages_needed(req) <= self.server.hbm_pages

    def _admit(self):
        while (self.waiting and len(self.active) < self.max_active and
               self._window_has_room(self.waiting[0])):
            req = self.waiting.popleft()
            last = self.server.add_request(req.rid, req.prompt)
            req.t_first = time.monotonic()
            req.output.append(int(np.argmax(np.asarray(last))))
            self.active[req.rid] = req

    # -- the serving loop -----------------------------------------------------

    def step(self) -> int:
        """One scheduler iteration: admit, decode the active set once,
        retire finished sequences.  Returns tokens produced."""
        self._admit()
        # retire anything already done from its prefill token
        self._retire()
        if not self.active:
            return 0
        out = self.server.decode(1, seqs=list(self.active))
        n = 0
        for rid, toks in out.items():
            self.active[rid].output.extend(toks)
            n += len(toks)
        self._retire()
        return n

    def _retire(self):
        for rid in [r for r, q in self.active.items() if q.done]:
            req = self.active.pop(rid)
            req.t_done = time.monotonic()
            self.finished.append(req)
            # every tier's pages come back in one call; the physical
            # slots are reusable by the next waiting request immediately
            self.server.free_sequence(rid)

    def run_to_completion(self, max_iters: int = 10_000) -> dict:
        it = 0
        while (self.waiting or self.active) and it < max_iters:
            self.step()
            it += 1
        lat = [r.t_done - r.t_arrive for r in self.finished]
        ttft = [r.t_first - r.t_arrive for r in self.finished]
        return {
            "requests": len(self.finished),
            "iters": it,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "tier": self.server.tier_stats(),
        }
