"""Continuous-batching request scheduler over the tiered PagedServer.

Production serving needs more than a static batch: requests arrive and
finish at different times.  This scheduler implements the standard
continuous-batching loop on top of the paper's tiered KV mechanism:

  * admission control — a request is admitted when the HBM window can
    pin its projected working set alongside the active batch
    (otherwise it waits; the flash tier holds preempted sequences);
  * iteration-level scheduling — every step decodes the current active
    set through one jitted ``decode_step``; finished sequences (EOS or
    max_tokens) free their pages immediately via the public
    ``free_sequence`` API and a waiting request takes the slot;
  * tail telemetry — per-request latency and the tier counters, the
    serving-side analogue of mini-docker's container monitoring.

The scheduler talks only to PagedServer's public surface (capacity
accounting, ``free_sequence``, the batched step) — page-table internals
stay owned by core.kv_tier.PageTableManager.

:class:`PoolRouter` generalizes the same loop to the storage pool
(``runtime.pool.PoolServer``): least-loaded placement across DockerSSD
nodes (optionally routed through the ``StoragePool`` frontend so the
decision rides Ether-oN control frames), per-node admission control,
and heartbeat-driven failover requeue.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_tokens: int
    eos_id: Optional[int] = None
    # telemetry — all stamps are time.monotonic(): latency/TTFT deltas
    # must survive wall-clock adjustment (NTP slew would make
    # time.time()-based tails negative)
    t_arrive: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    # failover bookkeeping: how many times this request lost its node
    # and re-entered the queue (bounded — see PoolRouter.max_requeues)
    requeues: int = 0
    reject_reason: Optional[str] = None
    # per-request deadline budget, seconds from arrival.  A request
    # still waiting for admission past its deadline is shed at the next
    # scheduler boundary with a recorded reason (the answer would
    # arrive too late to be useful); None = no deadline.  Requests
    # already decoding run to completion — their TTFT was met.
    deadline_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return (len(self.output) >= self.max_tokens or
                (self.eos_id is not None and self.output and
                 self.output[-1] == self.eos_id))


class ContinuousBatcher:
    """Iteration-level scheduler for a PagedServer.

    ``horizon=1`` (default) schedules per token: admit, one jitted
    decode step, retire.  ``horizon=H`` schedules on *horizon
    boundaries*: each iteration runs one fused H-token device loop
    (``PagedServer.decode(horizon=H)``) and joins/evicts between
    horizons.  Per-request EOS and ``max_tokens`` are enforced on
    device via budgets (plus host-side truncation when active requests
    disagree on ``eos_id``), so greedy outputs are token-for-token
    identical to the per-token schedule.

    ``speculative=True`` runs each horizon iteration as a draft-verify
    pass (``decode(speculative=True)``): an iteration now yields a
    *variable* number of tokens per request — whatever the acceptance
    mask kept — and budgets re-derive from actual output lengths, so
    the loop needs no other change.  ``sampling`` threads an on-device
    :class:`~repro.runtime.serve.SamplingConfig` through every decode
    call (greedy when None).
    """

    def __init__(self, server, *, max_active: int = 8, horizon: int = 1,
                 prefill_chunk: Optional[int] = None,
                 speculative: bool = False, sampling=None,
                 max_waiting: Optional[int] = None):
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        if speculative and horizon < 2:
            raise ValueError(
                f"speculative scheduling needs horizon >= 2, got {horizon}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.server = server
        self.max_active = max_active
        self.horizon = horizon
        self.speculative = speculative
        self.sampling = sampling
        # chunked admission: an admitted request prefills at most
        # ``prefill_chunk`` tokens per scheduler iteration (one jitted
        # chunk), interleaved with the active set's decode horizons, so
        # admission never stalls decode longer than one chunk.  None =
        # legacy blocking admission (the whole suffix in one chunk).
        self.prefill_chunk = prefill_chunk
        # explicit backpressure: submissions beyond this queue depth are
        # rejected up front instead of waiting unboundedly (None = no cap)
        self.max_waiting = max_waiting
        self.waiting: Deque[Request] = deque()
        self.prefilling: Dict[int, Request] = {}
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.rejected: List[Request] = []

    # -- admission -----------------------------------------------------------

    def _capacity_impossible(self, req: Request) -> Optional[str]:
        """Reason this request could NEVER be admitted, or None."""
        if self._pages_needed(req) > self.server.hbm_pages:
            return (f"needs {self._pages_needed(req)} pages; window has "
                    f"{self.server.hbm_pages}")
        return None

    def _reject(self, req: Request, reason: str):
        req.reject_reason = reason
        self.rejected.append(req)

    def submit(self, req: Request) -> bool:
        """Queue a request.  Returns False (and records the request on
        ``rejected`` with a reason) when it can never fit or the queue
        is at its backpressure cap — load is shed explicitly at the
        door, never dropped silently inside the loop."""
        req.t_arrive = time.monotonic()
        why = self._capacity_impossible(req)
        if why is not None:
            self._reject(req, why)
            return False
        if self.max_waiting is not None and \
                len(self.waiting) >= self.max_waiting:
            self._reject(req, f"queue full ({self.max_waiting} waiting)")
            return False
        self.waiting.append(req)
        return True

    def _pages_needed(self, req: Request) -> int:
        return self.server.pages_needed(len(req.prompt) + req.max_tokens)

    def _window_has_room(self, req: Request) -> bool:
        pinned_now = sum(self._pages_needed(r) for r in self.active.values())
        pinned_now += sum(self._pages_needed(r)
                          for r in self.prefilling.values())
        return pinned_now + self._pages_needed(req) <= self.server.hbm_pages

    def _prompt_of(self, req: Request) -> np.ndarray:
        """The tokens a (re-)prefill must write: the prompt plus any
        output already generated.  Fresh requests have no output, so
        this is the plain prompt; a failover-requeued request resumes by
        teacher-forcing its own history (greedy *and* sampled decode
        continue identically to the uninterrupted run — draws are keyed
        per (sequence id, absolute position), not per pass)."""
        if not req.output:
            return req.prompt
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.output, np.int32)])

    def _prefill(self, req: Request):
        """Blocking-admission hook — PoolRouter overrides to route the
        placement through the pool frontend."""
        return self.server.add_request(req.rid, self._prompt_of(req))

    def _begin_prefill(self, req: Request):
        """Chunked-admission hook: open the admission (prefix-cache
        match, no compute) — PoolRouter overrides to route the
        placement through the pool frontend."""
        self.server.begin_request(req.rid, self._prompt_of(req))

    def _release(self, rid: int):
        """Retirement hook — PoolRouter overrides to notify the owning
        node over Ether-oN before the pages come back."""
        self.server.free_sequence(rid)

    def _activate(self, req: Request, last):
        """Admission finished: seed the first output token — greedy
        argmax, or (temperature > 0) the identical per-(sequence,
        position) draw the device sampler would make at this position,
        so a failover-requeued request continues exactly like the
        uninterrupted sampled run."""
        from repro.runtime.serve import sampled_token

        if not req.output:          # requeues keep their first-token stamp
            req.t_first = time.monotonic()
        tok = sampled_token(np.asarray(last), self.sampling, req.rid,
                            len(req.prompt) + len(req.output))
        req.output.append(tok)
        self.server.set_pending(req.rid, tok)
        self.active[req.rid] = req

    def _admit(self):
        if self.prefill_chunk is None:
            while (self.waiting and len(self.active) < self.max_active and
                   self._window_has_room(self.waiting[0])):
                req = self.waiting.popleft()
                self._activate(req, self._prefill(req))
            return
        # chunked admission: open admissions eagerly (prefix match only
        # — zero compute), then run at most ONE jitted prefill chunk per
        # scheduler iteration, so the decode horizon between iterations
        # is never stalled by more than one chunk of admission work
        while (self.waiting and
               len(self.active) + len(self.prefilling) < self.max_active
               and self._window_has_room(self.waiting[0])):
            req = self.waiting.popleft()
            self._begin_prefill(req)
            self.prefilling[req.rid] = req
        if self.prefilling:
            rid, req = next(iter(self.prefilling.items()))
            last = self.server.prefill_chunk(rid, self.prefill_chunk)
            if last is not None:
                del self.prefilling[rid]
                self._activate(req, last)

    def _failover(self):
        """Failure-sync hook — PoolRouter overrides to requeue
        sequences lost to node deaths.  No-op on a single server."""

    def _shed_expired(self):
        """Deadline enforcement at the scheduler boundary: a request
        whose deadline budget expired while it waited is shed with a
        recorded reason before any pages are spent on it (extends the
        explicit load-shedding surface — capacity-impossible, queue
        cap, requeue storm)."""
        if not any(r.deadline_s is not None for r in self.waiting):
            return
        now = time.monotonic()
        keep: Deque[Request] = deque()
        for req in self.waiting:
            waited = now - req.t_arrive
            if req.deadline_s is not None and waited > req.deadline_s:
                self._reject(req, f"deadline {req.deadline_s:.3f}s "
                             f"exceeded after {waited:.3f}s in queue")
            else:
                keep.append(req)
        self.waiting = keep

    # -- the serving loop -----------------------------------------------------

    def step(self) -> int:
        """One scheduler iteration: admit, decode the active set once
        (one token, or one fused horizon), retire finished sequences.
        Returns tokens produced."""
        self._shed_expired()
        self._admit()
        # retire anything already done from its prefill token
        self._retire()
        # a node can die DURING admission/retirement (its control
        # frames tick a fault injector's crash schedule): re-sync the
        # active set before decoding, or the step would feed sequences
        # the server just dropped
        self._failover()
        if not self.active:
            return 0
        if self.horizon <= 1:
            out = self.server.decode(1, seqs=list(self.active),
                                     sampling=self.sampling)
            n = 0
            for rid, toks in out.items():
                self.active[rid].output.extend(toks)
                n += len(toks)
        else:
            n = self._horizon_step()
        self._retire()
        return n

    def _horizon_step(self) -> int:
        """Decode one fused horizon across the active set.  The device
        stops each sequence at its own budget (remaining max_tokens,
        capped by the horizon) and — when every active request agrees
        on one ``eos_id`` — at EOS; with mixed eos ids the surplus
        tokens are truncated host-side, so outputs match the per-token
        schedule either way.

        Speculative iterations return variable accepted lengths per
        request; budgets re-derive from output lengths each iteration,
        so variable progress needs no special accounting."""
        budgets = {rid: req.max_tokens - len(req.output)
                   for rid, req in self.active.items()}
        h = min(self.horizon, max(budgets.values()))
        eos_ids = {req.eos_id for req in self.active.values()}
        eos = eos_ids.pop() if len(eos_ids) == 1 else None
        out = self.server.decode(h, seqs=list(self.active), horizon=h,
                                 eos_id=eos, budgets=budgets,
                                 sampling=self.sampling,
                                 # a 1-token tail horizon has no room
                                 # for candidates: run it plain
                                 speculative=self.speculative and h >= 2)
        n = 0
        for rid, toks in out.items():
            req = self.active[rid]
            for t in toks:
                if req.done:          # mixed-eos truncation
                    break
                req.output.append(t)
                n += 1
        return n

    def _retire(self):
        for rid in [r for r, q in self.active.items() if q.done]:
            req = self.active.pop(rid)
            req.t_done = time.monotonic()
            self.finished.append(req)
            # every tier's pages come back in one call; the physical
            # slots are reusable by the next waiting request immediately
            self._release(rid)

    def run_to_completion(self, max_iters: int = 10_000) -> dict:
        it = 0
        while (self.waiting or self.prefilling or self.active) and \
                it < max_iters:
            self.step()
            it += 1
        lat = [r.t_done - r.t_arrive for r in self.finished]
        ttft = [r.t_first - r.t_arrive for r in self.finished]
        # time per output token after the first (the streaming rate a
        # user sees once tokens start arriving)
        tpot = [(r.t_done - r.t_first) / max(len(r.output) - 1, 1)
                for r in self.finished]

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return {
            "requests": len(self.finished),
            "rejected": len(self.rejected),
            "iters": it,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p99_latency_s": pct(lat, 99),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "p50_ttft_s": pct(ttft, 50),
            "p99_ttft_s": pct(ttft, 99),
            "mean_tpot_s": float(np.mean(tpot)) if tpot else 0.0,
            "p50_tpot_s": pct(tpot, 50),
            "p99_tpot_s": pct(tpot, 99),
            "tier": self.server.tier_stats(),
        }


class PoolRouter(ContinuousBatcher):
    """Pool-aware continuous batcher for a ``runtime.pool.PoolServer``.

    The same iteration loop as :class:`ContinuousBatcher`, generalized
    to a pool of DockerSSD nodes:

      * **placement** — an admitted request goes to the least-loaded
        node with room for its projected working set; when a
        :class:`~repro.core.storage_pool.StoragePool` frontend is bound,
        the placement is routed through it (the decision rides an
        Ether-oN control frame to the chosen node before the shard
        admits the pages);
      * **per-node admission control** — a request is admitted only
        when one node's window (placed policy) or every node's share of
        the striped extent fits alongside that node's active load;
      * **failover requeue** (placed policy) — each step polls the
        pool's heartbeats; sequences homed on a node that died are
        dropped by the server and re-enter the queue at the front,
        where the next admission re-prefills prompt+history on a
        surviving node (greedy and sampled decode both complete the
        output identically to an uninterrupted run — sampling draws are
        keyed per sequence/position).  A *striped* extent spans
        every node, so a node failure is unrecoverable within the job:
        the router raises immediately instead of requeueing work that
        could never re-admit (restart the pool job — DESIGN.md §Pool
        serving).
    """

    def __init__(self, server, pool=None, *, max_active: int = 8,
                 horizon: int = 1, prefill_chunk: Optional[int] = None,
                 speculative: bool = False, sampling=None,
                 max_waiting: Optional[int] = None,
                 max_requeues: int = 3):
        super().__init__(server, max_active=max_active, horizon=horizon,
                         prefill_chunk=prefill_chunk,
                         speculative=speculative, sampling=sampling,
                         max_waiting=max_waiting)
        self.pool = pool
        self.requeues = 0
        # per-request failover cap: when nodes die faster than
        # re-prefill recovers, the storm sheds the unlucky requests
        # explicitly instead of cycling them through the queue forever
        self.max_requeues = max_requeues
        self._target_node: Optional[int] = None

    def _suspect_shards(self) -> set:
        return self.pool.suspect_shards() if self.pool is not None \
            else set()

    # -- per-node admission ---------------------------------------------------

    def _capacity_impossible(self, req: Request) -> Optional[str]:
        srv = self.server
        need = self._pages_needed(req)
        cap = srv.pages_per_node
        if srv.policy == "placed":
            if need > cap:
                return (f"needs {need} pages; a node's window has {cap}")
            return None
        share = max(self._striped_share(need, s, srv.n_nodes)
                    for s in range(srv.n_nodes))
        if share > cap:
            return (f"striped share is {share} pages/node; a node's "
                    f"window has {cap}")
        return None

    @staticmethod
    def _striped_share(n_pages: int, node: int, n_nodes: int) -> int:
        """Pages of an ``n_pages`` striped extent that land on ``node``."""
        return len(range(node, n_pages, n_nodes))

    def _node_load(self) -> Dict[int, int]:
        """Projected pinned pages per alive node from the active set
        (in-flight chunked admissions hold pages too)."""
        srv = self.server
        load = {s: 0 for s in srv.alive_nodes()}
        for r in list(self.active.values()) + list(
                self.prefilling.values()):
            need = self._pages_needed(r)
            if srv.policy == "placed":
                s = srv.node_of(r.rid)
                if s in load:
                    load[s] += need
            else:
                for s in load:
                    load[s] += self._striped_share(need, s, srv.n_nodes)
        return load

    def node_headroom(self) -> Dict[int, int]:
        """Free window pages per alive node given the active set — the
        admission surface shared with the analytics
        :class:`~repro.runtime.offload.OffloadPlanner` (serving and
        in-storage analytics run on the same DockerSSDs; one accounting
        decides who gets a node)."""
        cap = self.server.pages_per_node
        return {s: cap - n for s, n in self._node_load().items()}

    def _window_has_room(self, req: Request) -> bool:
        srv = self.server
        cap = srv.pages_per_node
        need = self._pages_needed(req)
        load = self._node_load()
        if not load:
            return False
        if srv.policy == "placed":
            fits = [s for s in load if load[s] + need <= cap]
            # prefer the fitting node that already holds the request's
            # prefix (zero prefill compute there); else least-loaded
            self._target_node = None
            if fits:
                # suspect shards are last resort: a warm prefix on a
                # straggler is slower than a cold prefill elsewhere
                good = [s for s in fits
                        if s not in self._suspect_shards()] or fits
                pn, hit = srv.best_prefix_node(self._prompt_of(req))
                self._target_node = pn if (hit and pn in good) else \
                    min(good, key=lambda s: (load[s], s))
            return bool(fits)
        self._check_striped_alive()
        return all(load[s] + self._striped_share(need, s, srv.n_nodes) <= cap
                   for s in load)

    def _check_striped_alive(self):
        if self.server._dead:
            raise RuntimeError(
                f"striped pool lost node(s) {sorted(self.server._dead)}: "
                "a striped extent spans every node, so the job cannot "
                "continue degraded — restart the pool (DESIGN.md §Pool "
                "serving)")

    def _route(self, req: Request, prompt) -> Optional[int]:
        """Placement for one admission (placed policy): the node the
        admission check chose — prefix-owning when possible — routed
        through the pool frontend's Ether-oN control frame when a
        StoragePool is bound."""
        node = self._target_node
        if self.pool is not None:
            node = self.pool.place_sequence(
                req.rid, len(req.prompt) + req.max_tokens, node=node,
                prompt=prompt)
        return node

    def _prefill(self, req: Request):
        srv = self.server
        prompt = self._prompt_of(req)
        if srv.policy != "placed":
            return srv.add_request(req.rid, prompt)
        return srv.add_request(req.rid, prompt,
                               node=self._route(req, prompt))

    def _begin_prefill(self, req: Request):
        srv = self.server
        prompt = self._prompt_of(req)
        if srv.policy != "placed":
            srv.begin_request(req.rid, prompt)
            return
        srv.begin_request(req.rid, prompt, node=self._route(req, prompt))

    def _release(self, rid: int):
        if self.pool is not None:
            self.pool.retire_sequence(rid)
        else:
            self.server.free_sequence(rid)

    # -- failover -------------------------------------------------------------

    def _failover(self):
        if self.pool is None:
            return
        self.pool.check_heartbeats()
        victims = self.pool.take_requeued()
        if victims and self.server.policy != "placed":
            self._check_striped_alive()         # unrecoverable: fail fast
        for rid in reversed(victims):           # keep original order at front
            req = self.active.pop(rid, None)
            if req is None:                     # admission was in flight
                req = self.prefilling.pop(rid, None)
            if req is not None:
                req.requeues += 1
                if req.requeues > self.max_requeues:
                    # requeue storm: shed this request explicitly
                    self._reject(req, f"lost its node "
                                 f"{req.requeues} times")
                    continue
                self.requeues += 1
                self.waiting.appendleft(req)

    def step(self) -> int:
        self._failover()
        return super().step()
