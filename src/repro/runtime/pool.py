"""Pool-sharded serving — the distributed decode path over DockerSSDs.

``PoolServer`` turns the single-device :class:`~repro.runtime.serve.
PagedServer` into one distributed system spanning the storage pool
(the paper's preferred offloading mode, Fig 8b): the jitted decode /
prefill steps are ``shard_map``-ped over a device mesh whose ``model``
axis is the pool — **shard i's slice of the PageStore pages axis is
DockerSSD node i's HBM window** (``runtime/sharding.pool_store_spec``).
One jitted step per token serves every sequence in the pool, wherever
its pages live.

Placement policies (``PageTableManager.shard_of``):

  * ``"placed"`` — each sequence's extent lives wholly on one node,
    chosen least-loaded by the pool frontend (StoragePool routes the
    admission over Ether-oN control frames).  Node failure only costs
    that node's sequences; the router re-prefills them elsewhere.
  * ``"striped"`` — a sequence's logical pages stripe round-robin
    across all nodes (the D-Cache sequence-sharded extent of
    DESIGN.md / runtime/sharding.cache_spec_shardings).  Maximum
    bandwidth for one long context; a node failure costs the pool.

Both run through the same device program, because the decode body is
ownership-driven: every node computes q/k/v for the new tokens (each
DockerSSD stores the full model in its flash), the owner of the tail
page appends via a masked scatter, every node runs paged attention over
*its own* pages only, and the per-node online-softmax partials
``(acc, m, l)`` are merged exactly with one ``pmax`` + two ``psum``
log-sum-exp collectives.  Control traffic (admission / placement /
free) rides Ether-oN frames; only these collectives ride the jax mesh —
the split DESIGN.md §Pool serving documents.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding

from repro.core.kv_tier import PageStore, PageTableManager
from repro.jax_compat import shard_map_unchecked
from repro.models import layers as L
from repro.runtime import sharding as shd
# the partial-softmax device contract lives with the serving bodies now
# (the single-node fused horizon shares it); re-exported here for the
# pool-facing name
from repro.runtime.serve import (NEG_INF, PagedServer,  # noqa: F401
                                 combine_partials, paged_attention_partial)

POOL_AXIS = "model"


class PoolServer(PagedServer):
    """Mesh-sharded tiered-KV serving across the storage pool.

    Same public surface as :class:`PagedServer` (the router and the
    StoragePool frontend talk to it identically) plus the pool surface:
    per-node capacity (``node_free_pages``), placement
    (``least_loaded_node``, ``add_request(..., node=)``), failure
    (``fail_node``) and per-node telemetry (``node_tier_stats``).

    The page-table manager allocates per shard (each node tiers against
    its own window and flash), the store's pages axis is laid out over
    the mesh, and the jitted steps are built by shard_mapping the
    ownership-aware bodies below with ``pool_step_specs``.
    """

    def __init__(self, model, params, *, n_nodes: Optional[int] = None,
                 active: Optional[int] = None,
                 mesh: Optional[Mesh] = None, page_size: int = 16,
                 hbm_pages_per_node: int = 32, dtype=jnp.float32,
                 policy: str = "placed", prefix_cache: bool = True,
                 page_dtype: str = "fp32",
                 hbm_bytes_per_node: Optional[int] = None):
        if policy not in ("placed", "striped"):
            raise ValueError(f"unknown placement policy {policy!r}")
        if active is not None and policy != "placed":
            raise ValueError(
                "elastic pools (active=) need the placed policy — a "
                "striped extent spans every node by construction, so "
                "membership cannot change under it")
        if mesh is None:
            n = n_nodes if n_nodes is not None else len(jax.devices())
            if active is not None:
                # elastic capacity compiles against the pow2 mesh
                # bucket: membership changes inside the bucket reuse
                # every compiled program (zero retrace), growing past
                # it means provisioning a new server
                n = shd.mesh_bucket(n)
            mesh = shd.pool_mesh(n)
        if POOL_AXIS not in mesh.axis_names:
            raise ValueError(f"pool mesh needs a {POOL_AXIS!r} axis")
        self.mesh = mesh
        self.n_nodes = int(mesh.shape[POOL_AXIS])
        if active is not None and not (1 <= active <= self.n_nodes):
            raise ValueError(f"active={active} must be in "
                             f"[1, {self.n_nodes}]")
        # elastic membership: shards beyond the initially-active count
        # start parked — their windows exist (the mesh and store are
        # sized for the full bucket) but placement skips them until a
        # join activates them
        self._parked: set = (set(range(active, self.n_nodes))
                             if active is not None else set())
        if hbm_bytes_per_node is not None:
            # per-node byte budget -> dtype-aware page count (same
            # capacity knob as PagedServer's hbm_bytes, per DockerSSD)
            pb = PageStore.stacked_page_bytes(
                n_layers=model.cfg.n_layers, page_size=page_size,
                n_kv_heads=model.cfg.n_kv_heads, head_dim=model.cfg.hd,
                dtype=dtype, page_dtype=page_dtype)
            hbm_pages_per_node = max(1, int(hbm_bytes_per_node) // pb)
        self.pages_per_node = hbm_pages_per_node
        self.policy = policy
        self._placement: Dict[int, int] = {}
        self._dead: set = set()
        super().__init__(model, params, page_size=page_size,
                         hbm_pages=self.n_nodes * hbm_pages_per_node,
                         dtype=dtype, prefix_cache=prefix_cache,
                         page_dtype=page_dtype)
        in_specs, out_specs = shd.pool_step_specs(self.quantized)
        self._sharded_decode = shard_map_unchecked(
            self._decode_body, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs)
        chunk_in, chunk_out = shd.pool_chunk_specs(self.quantized)
        self._sharded_chunk = shard_map_unchecked(
            self._chunk_body, mesh=mesh, in_specs=chunk_in,
            out_specs=chunk_out)
        # shard_map'd horizon / speculative bodies, one per (static)
        # horizon length — bounded by the pow2 bucketing in
        # ``horizon_batch`` / ``spec_horizon_batch``
        self._sharded_horizons: Dict[int, object] = {}
        self._sharded_specs: Dict[int, object] = {}

    # -- store / table factories ---------------------------------------------

    def _new_store(self) -> PageStore:
        store = super()._new_store()
        store.place({k: NamedSharding(self.mesh, s) for k, s in
                     shd.pool_state_spec(store.quantized).items()})
        return store

    def _new_table(self) -> PageTableManager:
        table = PageTableManager(self.store, n_shards=self.n_nodes,
                                 shard_of=self._shard_of)
        for s in self._dead:
            table.disable_shard(s)
        for s in self._parked:
            table.park_shard(s)
        return table

    def _shard_of(self, seq_id: int, page_idx: int) -> int:
        if self.policy == "placed":
            return self._placement[seq_id]
        return page_idx % self.n_nodes

    # -- pool placement surface ----------------------------------------------

    def alive_nodes(self) -> List[int]:
        """Nodes placement may target: not failed, not parked."""
        return [s for s in range(self.n_nodes)
                if s not in self._dead and s not in self._parked]

    def parked_nodes(self) -> List[int]:
        return sorted(self._parked)

    @property
    def active_count(self) -> int:
        return len(self.alive_nodes())

    def node_free_pages(self) -> List[int]:
        return [self.table.shard_free_pages(s) for s in range(self.n_nodes)]

    def least_loaded_node(self) -> int:
        alive = self.alive_nodes()
        if not alive:
            raise RuntimeError("no alive pool nodes")
        return max(alive, key=lambda s: (self.table.shard_free_pages(s), -s))

    def best_prefix_node(self, prompt):
        """(node, tokens): the alive node whose per-shard prefix index
        covers the longest prefix of ``prompt`` — the placement signal
        that routes a request to where its prefix KV already lives
        (placed policy; a striped extent matches per page across every
        node by construction).  (None, 0) when nothing matches."""
        best, best_n = None, 0
        for s in self.alive_nodes():
            n = self.table.prefix_tokens_on_shard(prompt, s)
            if n > best_n:
                best, best_n = s, n
        return best, best_n

    def pick_prefix_node(self, prompt, n_tokens: Optional[int] = None):
        """THE prefix-placement policy (one copy — the StoragePool
        frontend and direct ``begin_request`` both route through it):
        the prefix-owning node wins only while its window has room for
        the request's whole ``n_tokens`` extent (default: the prompt —
        conservative, since shares need no new pages, but the fallback
        must never wedge an admission).  None -> caller falls back to
        least-loaded."""
        node, hit = self.best_prefix_node(prompt)
        if not hit:
            return None
        need = self.pages_needed(n_tokens if n_tokens is not None
                                 else len(prompt))
        if self.table.shard_free_pages(node) < need:
            return None
        return node

    def begin_request(self, seq_id: int, prompt, *,
                      node: Optional[int] = None) -> int:
        """Open an admission onto the pool.  ``node`` pins the placement
        (the StoragePool frontend routes it there); default prefers the
        node already holding the prompt's prefix, else least-loaded.
        Striped policy ignores ``node`` — the extent spans every node by
        construction."""
        if self.policy == "placed" and seq_id not in self._placement:
            if node is None:
                node = self.pick_prefix_node(prompt)
            target = self.least_loaded_node() if node is None else int(node)
            if target in self._dead:
                raise RuntimeError(f"node {target} is dead")
            self._placement[seq_id] = target
        try:
            return super().begin_request(seq_id, prompt)
        except Exception:
            self._placement.pop(seq_id, None)
            raise

    def add_request(self, seq_id: int, prompt, *,
                    node: Optional[int] = None,
                    chunk: Optional[int] = None):
        """Blocking admission: placement + cached-prefix match + chunked
        prefill of the uncached suffix (see PagedServer.add_request)."""
        self.begin_request(seq_id, prompt, node=node)
        logits = None
        while logits is None:
            logits = self.prefill_chunk(seq_id, chunk)
        return logits

    def free_sequence(self, seq_id: int) -> int:
        freed = super().free_sequence(seq_id)
        self._placement.pop(seq_id, None)
        return freed

    def node_of(self, seq_id: int) -> Optional[int]:
        return self._placement.get(seq_id)

    def fail_node(self, node: int) -> List[int]:
        """Simulated DockerSSD failure: the node's HBM window and flash
        tier are gone.  Every sequence with pages homed there is dropped
        (its ids are returned so the router can re-prefill them on the
        survivors) and the shard is taken out of allocation."""
        victims = set(self.table.sequences_on_shard(node))
        # an admission opened here whose first chunk hasn't allocated
        # pages yet is homed here too (placement is recorded at
        # begin_request, pages only at the first prefill chunk) — it
        # must requeue with the rest, not prefill onto a dead shard
        victims |= {s for s, n in self._placement.items() if n == node}
        victims = sorted(victims)
        self._dead.add(node)
        self._parked.discard(node)
        for s in victims:
            self.free_sequence(s)
        self.table.disable_shard(node)
        return victims

    # -- elastic membership (join / drain) ------------------------------------

    def activate_node(self, node: int):
        """Join a parked node into the serving set.  Zero retrace: the
        shard_map programs were compiled once against the full pow2
        mesh bucket, and an inactive shard simply owned no pages (its
        attention partials are the LSE identity), so activation is pure
        host-side bookkeeping — the very next decode step may place
        pages there."""
        if node in self._dead:
            raise RuntimeError(
                f"node {node} is dead (window lost); a failed node "
                "cannot rejoin the serving set")
        if not (0 <= node < self.n_nodes):
            raise ValueError(f"node {node} outside mesh bucket "
                             f"[0, {self.n_nodes})")
        self._parked.discard(node)
        self.table.unpark_shard(node)

    def _drain_dst(self, need: int, exclude: int) -> Optional[int]:
        """Pick the warm-migration destination: the least-loaded alive
        node (excluding the drainee) whose window has room for ``need``
        pages.  None -> the caller takes the cold path."""
        cand = [s for s in self.alive_nodes() if s != exclude]
        if not cand:
            return None
        best = max(cand, key=lambda s: (self.table.shard_free_pages(s), -s))
        return best if self.table.shard_free_pages(best) >= need else None

    def drain_node(self, node: int, on_migrate=None) -> Dict:
        """Two-path zero-drop drain: remove ``node`` from the serving
        set while every request keeps decoding.

        Warm path (preferred): each victim sequence's resident pages
        move device-to-device onto a surviving node's window
        (``PageTableManager.migrate_page`` — exact bytes, so sampling
        streams and logits are untouched and outputs stay
        token-identical).  ``on_migrate(seq_id, page_idx, src, dst)``
        fires per moved page — the StoragePool frontend announces each
        one with a MIGRATE frame for cost accounting.

        Cold path (fallback): a victim whose pages don't fit anywhere
        (or whose destination dies mid-migration) is freed and reported
        in ``cold`` — the caller requeues it through the PR-2 failover
        machinery, which teacher-forces the already-emitted tokens, so
        outputs stay token-identical there too.

        Shared prefix pages migrate once; every sharer's mapping
        follows the copy.  A sharer later re-placed elsewhere keeps
        reading the moved page — the merged attention is
        ownership-agnostic, so only *new* appends land on the sharer's
        own node.  Runs between scheduler steps (no pages pinned).
        """
        if self.policy != "placed":
            raise RuntimeError("striped pools cannot drain a node — the "
                               "extent spans every node by construction")
        if node in self._dead:
            raise RuntimeError(f"node {node} is dead; drain is for "
                               "planned removal of a live node")
        if len(self.alive_nodes()) <= 1:
            raise RuntimeError("cannot drain the last active node")
        # park first so concurrent placement and destination picking
        # exclude the drainee
        self._parked.add(node)
        self.table.park_shard(node)
        victims = set(self.table.sequences_on_shard(node))
        victims |= {s for s, n in self._placement.items() if n == node}
        victims = sorted(victims)
        migrated, cold, moved = 0, [], {}
        for seq in victims:
            try:
                res = self.table.resident_on_shard(seq, node)
                dst = self._drain_dst(len(res), node)
                if dst is None:
                    self.free_sequence(seq)
                    cold.append(seq)
                    continue
                for pi, phys in res:
                    self.table.migrate_page(phys, dst)
                    migrated += 1
                    if on_migrate is not None:
                        on_migrate(seq, pi, node, dst)
                self._placement[seq] = dst
                moved[seq] = dst
            except Exception:
                # destination lost mid-migration (its failover already
                # requeued whatever reached it) — cold path for this
                # victim, survivors re-pick a destination
                self.free_sequence(seq)
                cold.append(seq)
        self.table.release_shard_cache(node)
        return {"victims": victims, "migrated_pages": migrated,
                "cold": cold, "moved": moved}

    # -- per-node telemetry ---------------------------------------------------

    def node_tier_stats(self) -> List[Dict[str, int]]:
        """One stats dict per node — the aggregate ``tier_stats`` is the
        field-wise sum of these (each node owns its window and tier)."""
        return [dict(vars(ss)) for ss in self.table.shard_stats]

    # -- device programs (shard-local bodies) ---------------------------------

    def decode_step(self, params, state, page_table, lengths, tokens):
        return self._sharded_decode(params, state, page_table, lengths,
                                    tokens)

    def prefill_chunk_step(self, params, state, page_row, tokens, start,
                           n_valid):
        return self._sharded_chunk(params, state, page_row, tokens,
                                   start, n_valid)

    def _pool_hooks(self, n_local: int, page_table):
        """The two scaffold hooks every pool body shares: rebase global
        physical ids into this node's window (the append sentinel drops
        non-owned writes) and run ownership-masked attention partials
        merged across the pool axis.  ``page_table`` may be a [B, pps]
        batch table (decode/horizon) or a broadcast [C, pps] chunk
        table."""
        base = lax.axis_index(POOL_AXIS) * n_local
        local_table = page_table - base
        col_owned = (local_table >= 0) & (local_table < n_local)

        def append_target(phys, valid):
            local_new = phys - base
            owned = valid & (local_new >= 0) & (local_new < n_local)
            return jnp.where(owned, local_new, n_local)

        def attention(q, st, new_lengths):
            # quantized stores dequantize in the partial itself (the
            # same multiply on every node), so the LSE merge stays
            # device-invariant across pool shards
            acc, m, l = paged_attention_partial(
                q, st["k"], st["v"], local_table, col_owned, new_lengths,
                k_scale=st.get("ks"), v_scale=st.get("vs"))
            return combine_partials(acc, m, l, POOL_AXIS).astype(self.dtype)

        return append_target, attention

    def _decode_body(self, params, state, page_table, lengths, tokens):
        """Per-node slice of one pool decode step — the shared horizon
        scaffold at H=1 (same unification as ``PagedServer.decode_step``)
        with the pool hooks plugged in: physical page ids are global,
        each node maps them into its own window (append and attention
        masked to owned pages) and the attention partials are merged
        across the pool axis."""
        append_target, attention = self._pool_hooks(state["k"].shape[1],
                                                    page_table)
        _, logits, state = self._fused_horizon_scan(
            params, state, page_table, lengths, tokens,
            (lengths > 0).astype(jnp.int32), jnp.int32(-1), horizon=1,
            append_target=append_target, attention=attention)
        return logits, state

    # -- fused decode horizon (sharded) ---------------------------------------

    def decode_horizon_step(self, params, state, page_table, lengths,
                            tokens, budget, eos_id, key=None,
                            temperature=None, top_p=None, streams=None,
                            *, horizon: int):
        if key is None:
            # shard_map specs are positional: materialize the sampling
            # quad (greedy ignores the values inside the traced
            # switch, so this costs nothing and keeps one spec set)
            key = jax.random.PRNGKey(0)
            temperature = jnp.float32(0.0)
            top_p = jnp.float32(1.0)
        if streams is None:
            streams = jnp.zeros(lengths.shape, jnp.int32)
        fn = self._sharded_horizons.get(horizon)
        if fn is None:
            in_specs, out_specs = shd.pool_horizon_specs(self.quantized)
            fn = shard_map_unchecked(
                lambda *a: self._horizon_body(*a, horizon=horizon),
                mesh=self.mesh, in_specs=in_specs, out_specs=out_specs)
            self._sharded_horizons[horizon] = fn
        return fn(params, state, page_table, lengths, tokens, budget,
                  eos_id, key, temperature, top_p, streams)

    def _horizon_body(self, params, state, page_table, lengths,
                      tokens, budget, eos_id, key, temperature, top_p,
                      streams, *, horizon: int):
        """Per-node slice of one fused decode horizon.

        The shared ``_fused_horizon_scan`` scaffold with the pool's two
        hooks plugged in: the append target rebases physical ids into
        this node's window (non-owned appends drop via the sentinel),
        and attention runs ownership-masked partials merged across the
        pool axis per layer.  The merged logits' argmax — identical on
        every node — drives the next step, so control (lengths,
        budgets, EOS) stays replicated arithmetic: H tokens cost zero
        host interactions and exactly 3 collectives per layer per
        token, same as the per-token path.

        Ownership of every logical page in the horizon's reservation is
        fixed for the whole horizon (the table covers the pre-reserved
        extent; only the append *target* advances).
        """
        append_target, attention = self._pool_hooks(state["k"].shape[1],
                                                    page_table)
        return self._fused_horizon_scan(
            params, state, page_table, lengths, tokens,
            budget, eos_id, key, temperature, top_p, streams,
            horizon=horizon,
            append_target=append_target, attention=attention)

    # -- speculative draft-verify (sharded) -----------------------------------

    def decode_spec_step(self, params, state, page_table, lengths,
                         tokens, budget, eos_id, hist, hist_len, key,
                         temperature, top_p, streams=None, *,
                         horizon: int):
        if streams is None:
            streams = jnp.zeros(lengths.shape, jnp.int32)
        fn = self._sharded_specs.get(horizon)
        if fn is None:
            in_specs, out_specs = shd.pool_spec_specs(self.quantized)
            fn = shard_map_unchecked(
                lambda *a: self._spec_body(*a, horizon=horizon),
                mesh=self.mesh, in_specs=in_specs, out_specs=out_specs)
            self._sharded_specs[horizon] = fn
        return fn(params, state, page_table, lengths, tokens, budget,
                  eos_id, hist, hist_len, key, temperature, top_p,
                  streams)

    def _spec_body(self, params, state, page_table, lengths, tokens,
                   budget, eos_id, hist, hist_len, key, temperature,
                   top_p, streams, *, horizon: int):
        """Per-node slice of one speculative draft-verify pass.

        The shared ``_spec_verify_scan`` scaffold with the pool hooks:
        the drafter reads the replicated history table (every node
        computes the identical candidates — no cross-node traffic for
        drafting), each node appends/attends only its owned pages with
        the per-position causal lengths, the LSE partials merge across
        the pool axis, and acceptance + sampling run on the *merged*
        logits with the replicated key — so the packed emission block
        is bit-identical on every node (the determinism
        tests/test_speculative.py pins against a 1-node PagedServer).
        """
        append_target, attention = self._pool_hooks(
            state["k"].shape[1], jnp.repeat(page_table, horizon, axis=0))
        return self._spec_verify_scan(
            params, state, page_table, lengths, tokens, budget, eos_id,
            hist, hist_len, key, temperature, top_p, streams,
            horizon=horizon,
            append_target=append_target, attention=attention)

    def _chunk_body(self, params, state, page_row, tokens, start,
                    n_valid):
        """Per-node slice of one prefill chunk: the shared chunk
        scaffold with the pool hooks — every node runs the layer stack
        on the chunk (replicated; each DockerSSD stores the full model),
        writes only the chunk K/V pages it owns via the masked scatter,
        attends over its own pages and merges the LSE partials, so the
        chunk's queries see the whole cached prefix wherever its pages
        live in the pool."""
        append_target, attention = self._pool_hooks(
            state["k"].shape[1], jnp.broadcast_to(
                page_row[None, :], (tokens.shape[1], page_row.shape[0])))

        return self._prefill_chunk_scan(
            params, state, page_row, tokens, start, n_valid,
            append_target=append_target,
            attention=lambda q, st, table, lengths:
                attention(q, st, lengths))

    def step_reference(self, tokens):
        raise NotImplementedError(
            "the pool path is validated against a 1-node PagedServer "
            "running the same workload (tests/test_pool.py, "
            "benchmarks/run.py pool)")
