"""Sharding rules: map param/activation pytrees to PartitionSpecs.

Axis roles:
  * ``model`` — tensor parallelism (heads/ffn/vocab/experts) and, for
    decode, the **KV-cache sequence dimension**: each model shard is one
    "DockerSSD" of the computing-enabled storage pool, owning a
    contiguous KV extent (the paper's D-Cache placement).
  * ``data`` (+ ``pod`` when present) — batch data parallelism and
    ZeRO-3-style FSDP of the weights.

Every axis assignment is divisibility-guarded: if a dim does not divide
by the axis size the next-smaller axis subset (or replication) is used,
so the same rules serve all 10 archs and both production meshes.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh):
    return fsdp_axes(mesh)


def _axes_size(mesh: Mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def axes_if_div(mesh: Mesh, dim: int, axes) -> Optional[tuple]:
    """Largest prefix-subset of ``axes`` whose product divides ``dim``."""
    axes = tuple(axes)
    while axes:
        if dim % _axes_size(mesh, axes) == 0:
            return axes
        axes = axes[:-1]
    return None


def _ax(mesh: Mesh, dim: int, *axes) -> Any:
    got = axes_if_div(mesh, dim, axes)
    if got is None:
        return None
    return got if len(got) > 1 else got[0]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "w", "wr", "wg",
                 "in_proj", "lora_a", "wa"}
_ROW_PARALLEL = {"wo", "w_down", "wv_cm", "out_proj", "wb"}
_REPLICATED = {"scale", "bias", "b_up", "b_down", "bq", "bk", "bv",
               "router", "w0", "u", "ln_x", "a_log", "d_skip", "dt_bias",
               "conv_b", "lora_b", "mu_x", "mu_w", "mu_k", "mu_v", "mu_r",
               "mu_g"}


def param_spec(mesh: Mesh, path: Sequence[str], shape) -> P:
    """Spec for one parameter leaf given its key path and shape."""
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    fa = fsdp_axes(mesh)
    stacked = "layers" in path            # leading layer dim from scan-stack
    core = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    def done(*spec):
        return P(*(lead + spec))

    # --- special cases -----------------------------------------------------
    if parent == "embed" and name == "table":
        v, d = core
        s0 = _ax(mesh, v, "model")
        if s0 is not None:
            return done(s0, _ax(mesh, d, *fa))
        return done(None, _ax(mesh, d, "model"))
    if parent == "lm_head" and name == "w":
        d, v = core
        s1 = _ax(mesh, v, "model")
        if s1 is not None:
            return done(_ax(mesh, d, *fa), s1)
        return done(_ax(mesh, d, "model"), None)
    if parent == "mlp" and len(core) == 3:            # MoE expert weights
        e = core[0]
        se = _ax(mesh, e, "model")
        return done(se, _ax(mesh, core[1], *fa), None)
    if name == "conv_w":                              # [D_CONV, conv_dim]
        return done(None, _ax(mesh, core[-1], "model"))
    # rwkv channel-mix wv is row-parallel [d_ff, d]
    if name == "wv" and parent == "channel_mix":
        return done(_ax(mesh, core[0], "model"), _ax(mesh, core[1], *fa))
    if name in _REPLICATED or len(core) < 2:
        return done(*([None] * len(core)))
    if name in _ROW_PARALLEL:
        return done(_ax(mesh, core[0], "model"), _ax(mesh, core[1], *fa))
    if name in _COL_PARALLEL:
        return done(_ax(mesh, core[0], *fa), _ax(mesh, core[1], "model"))
    # default: replicate
    return done(*([None] * len(core)))


def _key_of(entry) -> str:
    return getattr(entry, "key", getattr(entry, "name", str(entry)))


def param_specs(mesh: Mesh, params) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs
    or concrete arrays)."""
    def visit(path, leaf):
        keys = tuple(_key_of(p) for p in path)
        return param_spec(mesh, keys, leaf.shape)
    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(mesh: Mesh, params):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(mesh, params))


def serve_param_specs(mesh: Mesh, params) -> Any:
    """Serving-time param specs: TP (model axis) only — no ZeRO/FSDP
    sharding over the data axes.  Decode reads every weight once per
    token; FSDP would force a full parameter all-gather per step (the
    dominant collective in the baseline measurement, EXPERIMENTS.md
    §Perf).  Serving replicates over data/pod and shards over model."""
    fa = set(fsdp_axes(mesh))

    def strip(spec):
        def keep(ax):
            if ax is None:
                return None
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if a not in fa)
                return kept if len(kept) > 1 else (kept[0] if kept else None)
            return None if ax in fa else ax
        return P(*(keep(ax) for ax in spec))

    return jax.tree.map(strip, param_specs(mesh, params),
                        is_leaf=lambda x: isinstance(x, P))


def cast_float_specs(tree, dtype):
    """ShapeDtypeStruct tree with float leaves cast (serving stores bf16)."""
    import jax.numpy as jnp

    def one(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(l.shape, dtype)
        return l

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# activation / batch / cache specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, specs) -> Any:
    """Specs for a train/prefill input batch dict."""
    ba = batch_axes(mesh)

    def one(leaf):
        b = leaf.shape[0]
        s0 = _ax(mesh, b, *ba)
        rest = [None] * (len(leaf.shape) - 1)
        if len(leaf.shape) == 3:  # embeds [B,S,d] — shard d over model
            rest[-1] = _ax(mesh, leaf.shape[-1], "model")
        return P(s0, *rest)

    return jax.tree.map(one, specs)


def cache_spec_shardings(mesh: Mesh, cache_specs, multi_pod_seq: bool = True):
    """Specs for a decode cache pytree.

    KV tensors [L, B, Hkv, S, D]: batch -> data axes, **sequence -> model
    (+ pod)** — the D-Cache storage-pool placement.  SSM/conv/shift states:
    batch -> data axes, feature dim -> model when divisible.
    """
    ba = batch_axes(mesh)
    seq_axes = ("pod", "model") if ("pod" in mesh.axis_names and
                                    multi_pod_seq) else ("model",)

    def one(path, leaf):
        keys = tuple(_key_of(p) for p in path)
        shape = leaf.shape
        if keys and keys[-1] in ("k", "v") and len(shape) == 5:
            l, b, hkv, s, d = shape
            sb = _ax(mesh, b, "data")
            ss = _ax(mesh, s, *seq_axes)
            return P(None, sb, None, ss, None)
        if keys and keys[-1] in ("k_scale", "v_scale") and len(shape) == 4:
            l, b, hkv, s = shape
            return P(None, _ax(mesh, b, "data"), None,
                     _ax(mesh, s, *seq_axes))
        if keys and keys[-1] == "index":
            return P()
        # states: [L, B, ...feature...]
        if len(shape) >= 3:
            sb = _ax(mesh, shape[1], *ba)
            rest = [None] * (len(shape) - 2)
            rest[-1] = _ax(mesh, shape[-1], "model")
            return P(None, sb, *rest)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, cache_specs)


def decode_token_spec(mesh: Mesh, batch: int) -> P:
    return P(_ax(mesh, batch, "data"))


# ---------------------------------------------------------------------------
# pool serving specs (PoolServer: one DockerSSD node per ``model`` shard)
# ---------------------------------------------------------------------------


def mesh_bucket(n: int) -> int:
    """Pow2 capacity bucket for an elastic pool mesh.

    Elastic pools compile their shard_map programs ONCE, against a mesh
    of ``mesh_bucket(n)`` devices; scaling inside the bucket is a pure
    membership change (shards park/unpark, no retrace — see DESIGN.md
    §Elastic pool), and crossing the bucket means provisioning a new
    server.  Pow2 keeps the bucket count logarithmic in pool size, the
    same bound the horizon/batch pow2 bucketing gives compiled-program
    count."""
    if n < 1:
        raise ValueError(f"pool capacity must be >= 1, got {n}")
    b = 1
    while b < n:
        b <<= 1
    return b


def pool_mesh(capacity: int, devices=None) -> Mesh:
    """Build the pool mesh over ``capacity`` devices (one DockerSSD per
    ``model`` shard).  Raises with the CPU-simulation hint when the
    process doesn't expose enough devices — the count is bound at jax
    import, which is why every pool size runs in its own process in the
    benchmarks/tests."""
    devs = list(jax.devices()) if devices is None else list(devices)
    if capacity > len(devs):
        raise ValueError(
            f"{capacity} pool nodes need {capacity} devices but only "
            f"{len(devs)} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={capacity} before "
            f"importing jax to simulate the pool on CPU")
    return Mesh(np.asarray(devs[:capacity]), ("model",))


def pool_store_spec() -> P:
    """Spec for the stacked PageStore arrays
    ``[n_layers, hbm_pages, page, Hkv, D]``: the *pages* axis is sharded
    over ``model`` — shard i's contiguous physical range is node i's HBM
    window, the D-Cache placement at page granularity.  Layers, page
    interior, heads stay local to the node."""
    return P(None, "model", None, None, None)


def pool_state_spec(quantized: bool = False) -> dict:
    """Spec dict for the ``PageStore.device_state`` pytree the jitted
    pool steps carry: the page arrays shard over ``model`` along the
    pages axis, and for quantized stores the per-slot scale arrays
    ``[n_layers, hbm_pages, page, Hkv]`` shard along the *same* pages
    axis — a node owns its pages' codes AND their scales, so dequant is
    entirely node-local."""
    st = {"k": pool_store_spec(), "v": pool_store_spec()}
    if quantized:
        st["ks"] = P(None, "model", None, None)
        st["vs"] = P(None, "model", None, None)
    return st


def pool_step_specs(quantized: bool = False):
    """(in_specs, out_specs) for the shard_mapped pool decode step
    ``(params, state, page_table, lengths, tokens) -> (logits, state)``.
    Params and the control tensors are replicated — every node runs the
    full layer stack (each DockerSSD stores the whole model in its
    flash; the pool parallelism is over the KV extent, per DESIGN.md),
    only the page windows (and their scale windows) are split."""
    store = pool_state_spec(quantized)
    return ((P(), store, P(), P(), P()),
            (P(), store))


def pool_chunk_specs(quantized: bool = False):
    """(in_specs, out_specs) for the shard_mapped prefill chunk
    ``(params, state, page_row, tokens, start, n_valid) ->
    (logits, state)``.  Same replication story as
    :func:`pool_step_specs`: the chunk's page row / tokens / scalars are
    replicated control, the logits come out identical on every node
    (each merges the same LSE partials), only the page windows are
    split."""
    store = pool_state_spec(quantized)
    return ((P(), store, P(), P(), P(), P()),
            (P(), store))


def pool_horizon_specs(quantized: bool = False):
    """(in_specs, out_specs) for the shard_mapped fused decode horizon
    ``(params, state, page_table, lengths, tokens, budget, eos_id, key,
    temperature, top_p, streams) -> (emitted, logits, state)``.  Same
    replication story as :func:`pool_step_specs` — only the page
    windows are split; the control-plane carries (lengths / budgets /
    tokens / PRNG key / sampling params / stream ids) are replicated
    arithmetic, and the emitted token stack / final-step logits are
    device-invariant because every node selects from the *merged*
    logits with the same key-derived randomness."""
    store = pool_state_spec(quantized)
    return ((P(), store, P(), P(), P(), P(), P(), P(), P(), P(), P()),
            (P(), P(), store))


def pool_spec_specs(quantized: bool = False):
    """(in_specs, out_specs) for the shard_mapped speculative
    draft-verify pass ``(params, state, page_table, lengths, tokens,
    budget, eos_id, hist, hist_len, key, temperature, top_p, streams)
    -> (packed, state)``.  The drafter's history table rides replicated
    like the page table (host->device control), the PRNG key, sampling
    scalars and stream ids are replicated so every node derives the
    identical candidates, acceptance mask and samples from the merged
    logits, and only the page windows are split."""
    store = pool_state_spec(quantized)
    return ((P(), store, P(), P(), P(), P(), P(), P(), P(), P(), P(),
             P(), P()),
            (P(), store))


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, P))
