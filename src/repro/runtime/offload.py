"""Offload planner — Host vs D-VirtFW per analytics request.

The paper's Fig 11 verdict is an *average*: in-storage processing wins
on I/O-intensive workloads (pattern, rocksdb-read) and loses when the
reduction ratio is poor or the job is compute-bound (the 2.2 GHz
frontend pays ``ssd_slowdown``).  A production pool therefore decides
*per request*, from the same calibrated cost constants the Fig-3/11
models use (``core.isp_perf.IspCosts``):

  Host      = host-IO per-page + host-bandwidth transfer of the whole
              extent + host-syscall system path + host-speed compute
  D-VirtFW  = internal flash IO/bandwidth + function-call syscalls +
              SSD-speed compute + Ether-oN frames for the job and the
              *reduced* aggregate only

Jobs that plan onto the device are **batched per node** (one JOB frame,
one container run, one RESULTS frame per node) and run across the
``StoragePool`` alongside serving: when a :class:`~repro.runtime.
scheduler.PoolRouter` is attached, the planner shares its admission
surface — a serving node with no window headroom left falls back to the
host path instead of stealing the node (shared nodes, one admission
truth).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.container import from_jsonable
from repro.core.ether_on import MTU, EtherONError
from repro.core.extent_store import AnalyticsJob, project
from repro.core.isp_perf import IspCosts
from repro.kernels import ops
from repro.kernels.isp_scan import REDUCE_ROWS, topk_pad


@dataclasses.dataclass
class OffloadEstimate:
    """Modeled latencies (seconds) for one job, both placements."""
    node_ip: str
    bytes_scanned: int
    result_bytes: int
    host_s: float
    dvirtfw_s: float

    @property
    def choice(self) -> str:
        return "device" if self.dvirtfw_s < self.host_s else "host"

    @property
    def modeled_speedup(self) -> float:
        return self.host_s / self.dvirtfw_s


class OffloadPlanner:
    """Decide, batch and execute analytics jobs over a StoragePool.

    ``scan_gbs`` is the host-speed effective scan rate of the reduce
    kernel (bytes through the predicate+fold per second) — the one
    constant not in ``IspCosts`` because it belongs to the operator,
    not the platform.  ``io_bytes`` is the per-IO granularity the cost
    model charges ``host_io_us``/``flash_io_us`` against.
    """

    def __init__(self, pool, costs: IspCosts = IspCosts(), *,
                 router=None, scan_gbs: float = 8.0,
                 io_bytes: int = 128 * 1024):
        self.pool = pool
        self.costs = costs
        self.router = router
        self.scan_gbs = scan_gbs
        self.io_bytes = io_bytes

    # -- cost model ------------------------------------------------------------

    def estimate(self, job: AnalyticsJob) -> OffloadEstimate:
        ip = self.pool.locate_extent(job.extent)
        if ip is None:
            raise KeyError(f"extent {job.extent!r} not found on any "
                           f"alive node")
        store = self.pool.nodes[ip].extents
        ext = store.extents[job.extent]
        nbytes = ext.nbytes
        ios = max(1, -(-nbytes // self.io_bytes))
        # system path: submit/complete syscalls per IO plus the handful
        # of opens/walks around the scan
        n_sys = 8 + 2 * ios
        # per-request operator intensity: the job's hint wins over the
        # planner default, so one compute-bound request among
        # I/O-intensive ones flips to the host on its own
        compute_s = nbytes / 1e9 / (job.scan_gbs or self.scan_gbs)
        c = self.costs

        host_s = (ios * c.host_io_us * 1e-6 +
                  nbytes / 1e9 / c.host_bw_gbs +
                  n_sys * c.host_syscall_us * 1e-6 +
                  2 * c.path_walk_us * 1e-6 +
                  compute_s)

        # topk returns its own tile-padded block; everything else
        # returns the store-width aggregate
        out_cols = topk_pad(job.k) if job.reduce == "topk" else store.n_cols
        result_bytes = REDUCE_ROWS * out_cols * 4
        frames = 1 + max(1, -(-result_bytes // MTU))     # job + result
        dvirtfw_s = (ios * c.flash_io_us * 1e-6 +
                     nbytes / 1e9 / c.flash_bw_gbs +
                     n_sys * c.virtfw_call_us * 1e-6 +
                     2 * c.virtfw_walk_us * 1e-6 +
                     compute_s * c.ssd_slowdown +
                     frames * c.etheron_pkt_us * 1e-6)
        return OffloadEstimate(ip, nbytes, result_bytes, host_s, dvirtfw_s)

    def plan(self, jobs: List[AnalyticsJob]) -> List[OffloadEstimate]:
        return [self.estimate(j) for j in jobs]

    # -- shared admission with the serving router --------------------------------

    def _node_admits(self, ip: str) -> bool:
        """A serving node with no free window pages is off limits to
        analytics — the router's admission accounting is the one truth
        for shared nodes."""
        if self.router is None or self.pool._server is None:
            return True
        serve_ips = self.pool.serving_ips()
        if ip not in serve_ips:
            return True
        shard = serve_ips.index(ip)
        headroom = self.router.node_headroom()
        return headroom.get(shard, 0) > 0

    # -- execution --------------------------------------------------------------

    def execute(self, jobs: List[AnalyticsJob],
                force: Optional[str] = None) -> List[dict]:
        """Run every job where the cost model says it belongs
        (``force`` pins all jobs to ``"host"``/``"device"``).  Device
        jobs are batched per node into one JOB frame each; host jobs
        fetch the extent over the tunnel and fold with the bit-identical
        reference path.  Returns one record per job, input order."""
        ests = self.plan(jobs)
        records: List[Optional[dict]] = [None] * len(jobs)
        batches: Dict[str, List[int]] = {}
        for i, (job, est) in enumerate(zip(jobs, ests)):
            where = force or est.choice
            if force is None and where == "device":
                # an explicit force="device" is a pin, never rerouted
                if self.pool.nodes[est.node_ip].suspect:
                    where = "host-suspect"     # straggler: no new jobs
                elif not self._node_admits(est.node_ip):
                    where = "host-admission"   # serving owns the node now
            if where == "device":
                batches.setdefault(est.node_ip, []).append(i)
            else:
                try:
                    records[i] = self._run_host(job, est, where)
                except EtherONError:
                    self.pool.mark_unreachable(est.node_ip)
                    records[i] = self._retry_elsewhere(job, est)
        for ip, idxs in batches.items():
            payload = [jobs[i].to_dict() for i in idxs]
            try:
                out = from_jsonable(self.pool.driver.submit_jobs(
                    ip, payload))
            except EtherONError:
                # the node vanished between placement and submission —
                # each job retries on a healthy replica or the host
                self.pool.mark_unreachable(ip)
                for i in idxs:
                    records[i] = self._retry_elsewhere(jobs[i], ests[i])
                continue
            for i, block in zip(idxs, out):
                records[i] = {"job": jobs[i], "where": "device",
                              "est": ests[i], "block": block,
                              "result": project(block, jobs[i])}
        return records

    def _retry_elsewhere(self, job: AnalyticsJob,
                         est: OffloadEstimate) -> dict:
        """Degradation ladder for a job whose node became unreachable:
        resubmit on the best surviving replica; if its RESULTS never
        arrive either, fetch the extent and fold on the host
        (bit-identical to the in-storage reduce); only when every
        replica's node is gone does the job fail."""
        while True:
            ip = self.pool.locate_extent(job.extent)   # prefers healthy
            if ip is None:
                raise EtherONError(
                    f"extent {job.extent!r} unreachable: every replica's "
                    f"node is dead")
            est2 = dataclasses.replace(est, node_ip=ip)
            try:
                out = from_jsonable(self.pool.driver.submit_jobs(
                    ip, [job.to_dict()]))
                return {"job": job, "where": "device-retry", "est": est2,
                        "block": out[0], "result": project(out[0], job)}
            except EtherONError:
                pass
            try:
                return self._run_host(job, est2, "host-fallback")
            except EtherONError:
                self.pool.mark_unreachable(ip)

    def _run_host(self, job: AnalyticsJob, est: OffloadEstimate,
                  where: str) -> dict:
        store = self.pool.nodes[est.node_ip].extents
        data = self.pool.driver.fetch_extent(est.node_ip, job.extent)
        # fold at store width (narrow extents are zero-padded on device
        # pages) so the block matches the in-storage result bit-for-bit
        if data.shape[1] < store.n_cols:
            data = np.pad(data, ((0, 0), (0, store.n_cols - data.shape[1])))
        if job.reduce == "topk":
            block = np.asarray(ops.topk_scan_host(
                jnp.asarray(data), jnp.asarray(
                    job.padded_query(store.n_cols)),
                page_rows=store.page_rows, k=job.k, metric=job.metric))
        else:
            block = np.asarray(ops.scan_filter_reduce_host(
                jnp.asarray(data), job.threshold, page_rows=store.page_rows,
                filter_col=job.filter_col, filter_op=job.filter_op))
        return {"job": job, "where": where, "est": est, "block": block,
                "result": project(block, job)}
