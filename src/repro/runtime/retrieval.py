"""RetrievalFrontend — in-storage vector retrieval feeding serving.

The RAG loop the paper's disaggregation pitch implies, run end to end
on the node fabric:

  1. corpus embeddings live as an :class:`~repro.core.extent_store.
     ExtentStore` extent on a DockerSSD ("flash");
  2. each query becomes an :class:`~repro.core.extent_store.
     AnalyticsJob` with ``reduce="topk"`` — the scored scan runs *in
     storage* and only k (id, score) pairs ride the RESULTS frame back
     (the 980x wire-reduction story applied to retrieval).  The
     :class:`~repro.runtime.offload.OffloadPlanner` prices it next to
     decode: a serving node with no window headroom routes scoring to
     the host fallback instead of stalling in-flight horizons;
  3. top-k ids map to context token blocks through ONE batched
     ``embed_gather`` launch (no host-side per-request loop);
  4. the assembled prompt — template ++ retrieved chunks (rank order)
     ++ query tokens — goes to ``begin_request``/``add_request``, where
     the shared-prefix cache absorbs the repeated template and repeated
     retrieved chunks across requests (warm TTFT);
  5. on a pool, placement prefers the node that owns BOTH the embedding
     extent and the prompt's cached prefix pages: the first admission
     seeds the prefix on the extent-owning shard, and every later
     prefix hit routes back there.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.extent_store import AnalyticsJob
from repro.kernels import ops
from repro.runtime.offload import OffloadPlanner


class RetrievalFrontend:
    """Query -> in-storage top-k -> assembled prompt -> admission.

    ``pool`` is the :class:`~repro.core.storage_pool.StoragePool`
    holding the embedding extent; ``server`` is a ``PagedServer`` or
    ``PoolServer`` (pass None for retrieve-only use).
    ``corpus_tokens`` is the [n_docs, chunk_tokens] int32 table mapping
    a document id to its context token block; ``template`` is the
    shared instruction prefix prepended to every prompt.  For
    ``metric="cosine"`` pre-normalize queries (ranking is invariant to
    query scale; the fold normalizes rows only).
    """

    def __init__(self, pool, server=None, *, corpus_tokens,
                 extent: str = "corpus-embed", k: int = 4,
                 metric: str = "dot", template=None, planner=None,
                 router=None):
        self.pool = pool
        self.server = server
        self.corpus_tokens = jnp.asarray(np.asarray(corpus_tokens,
                                                    np.int32))
        if self.corpus_tokens.ndim != 2:
            raise ValueError("corpus_tokens must be [n_docs, chunk_tokens]")
        self.extent = extent
        self.k = k
        self.metric = metric
        self.template = (np.asarray(template, np.int32)
                         if template is not None
                         else np.zeros((0,), np.int32))
        self.planner = planner or OffloadPlanner(pool, router=router)
        #: where scoring actually ran, by planner verdict (degraded
        #: modes — suspect reroute, unreachable-node retry, host
        #: fallback — get their own buckets)
        self.stats: Dict[str, int] = {"device": 0, "host": 0,
                                      "host-admission": 0,
                                      "host-suspect": 0,
                                      "device-retry": 0,
                                      "host-fallback": 0}

    # -- corpus ---------------------------------------------------------------

    def ingest(self, embeddings, node_ip: Optional[str] = None,
               replicas: int = 1) -> List[str]:
        """Place the corpus embedding matrix ([n_docs, d] — one row per
        ``corpus_tokens`` block) as a node-resident extent on
        ``replicas`` distinct alive nodes (``replicas > 1`` is what
        keeps retrieval bit-identical through a node loss: the planner
        retries on the surviving copy).  Returns the chosen ips."""
        embeddings = np.asarray(embeddings, np.float32)
        if embeddings.shape[0] != self.corpus_tokens.shape[0]:
            raise ValueError(
                f"{embeddings.shape[0]} embedding rows but "
                f"{self.corpus_tokens.shape[0]} corpus token blocks")
        alive = self.pool.alive_nodes()
        if replicas > len(alive):
            raise ValueError(f"asked for {replicas} replicas; only "
                             f"{len(alive)} nodes alive")
        first = node_ip or alive[0]
        ips = [first] + [ip for ip in alive if ip != first][:replicas - 1]
        for ip in ips:
            self.pool.nodes[ip].extents.put(self.extent, embeddings)
        return ips

    # -- retrieval ------------------------------------------------------------

    def retrieve(self, queries, force: Optional[str] = None) -> List[dict]:
        """Score every query against the extent (in storage when the
        planner and serving admission allow) and return per-query hit
        dicts ``{"ids", "scores", "where"}``, best-first."""
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        jobs = [AnalyticsJob(extent=self.extent, reduce="topk",
                             query=[float(x) for x in q], k=self.k,
                             metric=self.metric, job_id=i)
                for i, q in enumerate(queries)]
        out = []
        for rec in self.planner.execute(jobs, force=force):
            where = rec["where"]
            self.stats[where] = self.stats.get(where, 0) + 1
            pairs = rec["result"]
            out.append({"ids": [int(i) for i, _ in pairs],
                        "scores": [float(s) for _, s in pairs],
                        "where": where})
        return out

    def build_prompts(self, queries, query_tokens,
                      force: Optional[str] = None):
        """Retrieve for every query and assemble the serving prompts:
        template ++ retrieved chunks (rank order) ++ query tokens.
        The id->tokens mapping is one batched ``embed_gather`` over the
        whole query batch.  Returns (prompts, hits)."""
        if len(np.atleast_2d(np.asarray(queries))) != len(query_tokens):
            raise ValueError("one query_tokens sequence per query")
        hits = self.retrieve(queries, force=force)
        idx = np.zeros((len(hits), self.k), np.int32)
        for i, h in enumerate(hits):
            idx[i, :len(h["ids"])] = h["ids"]
        blocks = np.asarray(ops.embed_gather(self.corpus_tokens, idx))
        prompts = []
        for i, (h, qt) in enumerate(zip(hits, query_tokens)):
            chunks = blocks[i, :len(h["ids"])].reshape(-1)
            prompts.append(np.concatenate(
                [self.template, chunks.astype(np.int32),
                 np.asarray(qt, np.int32)]))
        return prompts, hits

    # -- placement ------------------------------------------------------------

    def preferred_node(self, prompt,
                       n_tokens: Optional[int] = None) -> Optional[int]:
        """Pool placement for an assembled prompt: the prefix-owning
        node when one exists (capacity-guarded, via the server's own
        policy); otherwise seed on the shard whose DockerSSD holds the
        embedding extent — so prefix pages and extent co-reside and
        every later prefix hit routes back to the same node.  None ->
        caller falls back to least-loaded."""
        srv = self.server
        if srv is None or not hasattr(srv, "pick_prefix_node"):
            return None                      # single-node PagedServer
        node = srv.pick_prefix_node(prompt, n_tokens)
        if node is not None:
            return node
        if self.pool._server is None:
            return None
        serve_ips = self.pool.serving_ips()
        ip = self.pool.locate_extent(self.extent)
        if ip not in serve_ips:
            return None
        shard = serve_ips.index(ip)
        need = srv.pages_needed(n_tokens if n_tokens is not None
                                else len(prompt))
        if (shard in srv.alive_nodes()
                and srv.table.shard_free_pages(shard) >= need):
            return shard
        return None

    # -- end to end -----------------------------------------------------------

    def submit(self, seq_id: int, query, query_tokens, *,
               force: Optional[str] = None, gen_tokens: int = 0):
        """One RAG admission: retrieve, assemble, admit (blocking).
        Returns (logits, prompt, hit)."""
        prompts, hits = self.build_prompts([query], [query_tokens],
                                           force=force)
        prompt = prompts[0]
        if self.server is None:
            raise RuntimeError("RetrievalFrontend has no server attached")
        if hasattr(self.server, "n_nodes"):
            node = self.preferred_node(prompt, len(prompt) + gen_tokens)
            logits = self.server.add_request(seq_id, prompt, node=node)
        else:
            logits = self.server.add_request(seq_id, prompt)
        return logits, prompt, hits[0]
