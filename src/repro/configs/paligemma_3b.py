"""PaliGemma-3B — SigLIP + Gemma-2B decoder backbone [arXiv:2407.07726; hf].

[vlm] 18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216.
Gemma uses head_dim=256 (8 x 256 = 2048), GeGLU MLP, RMSNorm.
The SigLIP vision frontend is a STUB per task spec: ``input_specs()``
provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257_216,
    head_dim=256,
    act="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    frontend="vision",
    source="arXiv:2407.07726; hf",
)
