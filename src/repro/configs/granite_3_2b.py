"""Granite-3.0-2B — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base].

[dense] 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
head_dim = 2048/32 = 64; tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49_155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
