"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447].

[audio] 48L d_model=1280 16H (kv=16, MHA) d_ff=5120 vocab=504
(k-means target units). Same backbone as wav2vec2.  Encoder-only:
no decode step; decode-family shapes are skipped.  The CNN feature
extractor is a STUB per task spec: ``input_specs()`` provides
precomputed frame embeddings.  Non-causal; LayerNorm + plain GeLU MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
    norm="layernorm",
    rope=False,
    encoder_only=True,
    causal=False,
    frontend="audio",
    source="arXiv:2106.07447",
)
