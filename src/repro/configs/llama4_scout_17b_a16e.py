"""Llama-4 Scout 17B-active / 16 experts — MoE decoder, early-fusion VLM
[hf:meta-llama/Llama-4-Scout-17B-16E].

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 16 experts top-1.  The early-fusion vision frontend is a STUB per
task spec (text path exercised; ``input_specs`` are token ids).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=16,
    top_k=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
