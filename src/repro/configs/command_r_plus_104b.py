"""Command-R+ 104B — dense GQA decoder, no biases
[hf:CohereForAI/c4ai-command-r-v01].

[dense] 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
head_dim = 128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33_792,
    vocab_size=256_000,
    head_dim=128,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
