"""Zamba2-1.2B — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

[hybrid] 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  One *shared* (weight-tied) attention+MLP block is
applied every ``attn_every`` Mamba2 blocks, following the Zamba2
design.  Sub-quadratic decode state -> runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    block_type="mamba2_hybrid",
    source="arXiv:2411.15242; hf",
)
