"""Architecture + input-shape configuration registry.

Every assigned architecture is a frozen ``ArchConfig``; every assigned
input shape is a ``ShapeConfig``.  ``cells()`` enumerates the runnable
(arch x shape) grid with skip annotations (encoder-only archs have no
decode step; ``long_500k`` only runs for sub-quadratic families).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Iterator, Optional

# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | geglu | gelu (gelu = non-gated)
    rope: bool = True
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0              # hybrid: shared attn block period
    # structure
    block_type: str = "transformer"  # transformer | rwkv6 | mamba2_hybrid
    encoder_only: bool = False
    causal: bool = True
    frontend: Optional[str] = None   # vision | audio (stubbed per task spec)
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Archs whose decode state does not grow O(seq * d): SSM/hybrid."""
        return self.block_type in ("rwkv6", "mamba2_hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def reduced(self) -> "ArchConfig":
        """CPU-smoke-test configuration of the same family (tiny dims)."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else max(2, self.attn_every)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128,
            vocab_size=128,
            head_dim=16,
            n_experts=4 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.block_type in ("rwkv6", "mamba2_hybrid") else self.ssm_head_dim,
            attn_every=2 if self.attn_every else 0,
        )


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "paligemma_3b",
    "hubert_xlarge",
    "qwen2_72b",
    "phi3_mini_3_8b",
    "granite_3_2b",
    "command_r_plus_104b",
    "llama4_scout_17b_a16e",
    "phi3_5_moe_42b_a6_6b",
    "rwkv6_3b",
    "zamba2_1_2b",
]

_REGISTRY: dict = {}


def get_arch(name: str) -> ArchConfig:
    """Look up an ArchConfig by id (accepts '-' or '_' separators)."""
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        mod = importlib.import_module(f"repro.configs.{key}")
        _REGISTRY[key] = mod.CONFIG
    return _REGISTRY[key]


def all_archs() -> list:
    return [get_arch(a) for a in ARCH_IDS]


def cell_status(arch: ArchConfig, shape: ShapeConfig) -> str:
    """'run' or a 'skip:<reason>' marker for an (arch, shape) cell."""
    if shape.kind == "decode" and not arch.has_decode:
        return "skip:encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return "skip:long_500k requires sub-quadratic attention (full-attention arch)"
    return "run"


def cells(runnable_only: bool = True) -> Iterator[tuple]:
    """Yield (arch, shape, status) over the 10 x 4 assigned grid."""
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in SHAPES.values():
            status = cell_status(arch, shape)
            if runnable_only and status != "run":
                continue
            yield arch, shape, status
