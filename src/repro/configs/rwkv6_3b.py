"""RWKV-6 'Finch' 3B — attention-free RNN with data-dependent decay
[arXiv:2404.05892; hf].

[ssm] 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.
Head size 64 -> 40 heads; decode state is O(1) in sequence length,
so this arch runs the long_500k shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # d_model / ssm_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    ssm_head_dim=64,
    rope=False,
    norm="layernorm",
    block_type="rwkv6",
    source="arXiv:2404.05892; hf",
)
