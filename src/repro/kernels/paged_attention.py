"""Paged decode attention — Pallas TPU kernel.

This is the TPU-native analogue of DockerSSD's in-storage KV processing:
the KV cache lives in fixed-size *pages* (flash blocks -> HBM pages), a
page table maps each sequence's logical extent to physical pages, and
the kernel streams pages HBM->VMEM via scalar-prefetch index maps,
accumulating an online softmax *at the page* — compute moves to the
data, the data never moves to the query.

Grid: (batch, kv_heads, pages_per_seq); the page axis is sequential so
the per-(b,h) accumulators persist in VMEM scratch.  Pages whose start
offset is beyond the sequence length are skipped entirely (pl.when), so
work scales with actual context length, not table capacity.

Calling convention: the batched serving path holds *stacked* pages
``[n_layers, hbm_pages, page, Hkv, D]`` (core.kv_tier.PageStore) and
calls this kernel once per layer from inside a jitted ``lax.scan`` over
layers — each scan step feeds the layer's ``[hbm_pages, page, Hkv, D]``
slice plus the (shared) page-table row block.  The kernel itself is
layer-agnostic; ``paged_attention`` below is safe to trace inside an
enclosing jit (runtime/serve.py fuses append-scatter + attention + FFN
into one step).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, n_pages_per_seq: int,
                  sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[b]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(pi * page < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                     # [G, page]
        pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == n_pages_per_seq - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _paged_q8_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     o_ref, acc_ref, m_ref, l_ref, *, page: int,
                     n_pages_per_seq: int, sm_scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[b]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(pi * page < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, D]
        # int8 pages stream HBM->VMEM; dequant happens in-register —
        # HBM traffic is the int8 bytes (the §Perf opt2 realization)
        kq = k_ref[0, :, 0, :].astype(jnp.float32)           # [page, D]
        vq = v_ref[0, :, 0, :].astype(jnp.float32)
        ks = ks_ref[0, :, 0].astype(jnp.float32)             # [page]
        vs = vs_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kq, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * ks[None, :] * sm_scale                       # fold k scale
        pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        pw = p * vs[None, :]                                 # fold v scale
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pw, vq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == n_pages_per_seq - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_attention_q8(q, k_pages, v_pages, k_scale, v_scale, page_table,
                       lengths, *, interpret: bool = False):
    """int8-KV paged decode attention.

    q: [B, H, D] float; k_pages/v_pages: int8 [n_pages, page, Hkv, D];
    k_scale/v_scale: f32 [n_pages, page, Hkv]; page_table: [B, pps] int32;
    lengths: [B].  Returns [B, H, D]."""
    b, h, d = q.shape
    n_phys, page, hkv, _ = k_pages.shape
    pps = page_table.shape[1]
    g = h // hkv
    sm_scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_paged_q8_kernel, page=page,
                               n_pages_per_seq=pps, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, pi, pt, ln: (bb, hh, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh, 0)),
            pl.BlockSpec((1, page, 1),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh)),
            pl.BlockSpec((1, page, 1),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, hh, pi, pt, ln: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_attention_q8",
    )(page_table, lengths, qg, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(b, h, d)


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    interpret: bool = False):
    """q: [B, H, D]; k_pages/v_pages: [n_pages, page, Hkv, D];
    page_table: [B, pages_per_seq] int32; lengths: [B] int32.
    Returns [B, H, D]."""
    b, h, d = q.shape
    n_phys, page, hkv, _ = k_pages.shape
    pps = page_table.shape[1]
    g = h // hkv
    sm_scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_paged_kernel, page=page,
                               n_pages_per_seq=pps, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, pi, pt, ln: (bb, hh, 0, 0)),
            # physical page id comes from the prefetched page table
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bb, hh, pi, pt, ln: (pt[bb, pi], 0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, hh, pi, pt, ln: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_attention",
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, h, d)
